//! Workload generation: arrival processes + synthetic corpora (paper §6).
//!
//! Substitutions (DESIGN.md §3): FinQA -> a financial-question generator
//! with matched length spread; Azure LLM traces -> a two-class trace with
//! the >90% branch imbalance §6.1 reports; SWE-bench -> coding-task
//! prompts with configurable failure/retry behaviour (failures come from
//! the test-harness tool, not the corpus).

use std::time::Duration;

use crate::util::rng::Rng;

/// Open-loop Poisson arrival process: exponential inter-arrival gaps at
/// `rate` requests/second (wall clock).
pub struct Arrivals {
    rng: Rng,
    rate: f64,
}

impl Arrivals {
    pub fn new(rate: f64, seed: u64) -> Self {
        assert!(rate > 0.0);
        Arrivals { rng: Rng::new(seed), rate }
    }

    /// Next inter-arrival gap.
    pub fn next_gap(&mut self) -> Duration {
        Duration::from_secs_f64(self.rng.exp(self.rate))
    }

    /// All arrival offsets within `duration`.
    pub fn schedule(&mut self, duration: Duration) -> Vec<Duration> {
        let mut out = Vec::new();
        let mut t = Duration::ZERO;
        loop {
            t += self.next_gap();
            if t >= duration {
                return out;
            }
            out.push(t);
        }
    }
}

/// Parse an RPS sweep spec: either a comma list (`"20,40,80"`) or a range
/// (`"20:120:20"` = start:end:step, inclusive). Used by `nalar loadgen
/// --rps`. Returns `None` on malformed specs, non-positive rates, or
/// ranges expanding past [`MAX_SWEEP_POINTS`] (each point is a full
/// measurement window — a tiny step is always a mistake, and without the
/// cap a sub-epsilon step would loop forever).
pub fn parse_rps_sweep(spec: &str) -> Option<Vec<f64>> {
    let parse_rate = |s: &str| -> Option<f64> {
        let v: f64 = s.trim().parse().ok()?;
        (v > 0.0 && v.is_finite()).then_some(v)
    };
    if let Some((start, rest)) = spec.split_once(':') {
        let (end, step) = rest.split_once(':')?;
        let (start, end, step) = (parse_rate(start)?, parse_rate(end)?, parse_rate(step)?);
        if end < start {
            return None;
        }
        let mut out = Vec::new();
        let mut r = start;
        while r <= end + 1e-9 {
            if out.len() >= MAX_SWEEP_POINTS {
                return None;
            }
            out.push(r);
            r += step;
        }
        return Some(out);
    }
    let rates: Option<Vec<f64>> = spec.split(',').map(parse_rate).collect();
    rates.filter(|r| !r.is_empty() && r.len() <= MAX_SWEEP_POINTS)
}

/// Most sweep points a single `--rps` spec may expand to.
pub const MAX_SWEEP_POINTS: usize = 256;

/// Two-class trace with time-shifting imbalance, following the Azure agent
/// traces' shape (§6.1: "imbalance can exceed 90%"). Phase 1 is chat-heavy,
/// phase 2 flips toward coding — the router workflow's stress case.
pub fn azure_like_class(progress: f64, rng: &mut Rng) -> &'static str {
    let p_coder = if progress < 0.5 { 0.05 } else { 0.75 };
    if rng.bool_with(p_coder) {
        "coder"
    } else {
        "chat"
    }
}

/// FinQA-flavoured financial questions (drives the stateful analyst
/// workflow; lengths spread like short analyst queries).
pub fn finqa_question(rng: &mut Rng) -> String {
    const SUBJECTS: &[&str] = &[
        "net interest margin", "free cash flow", "operating leverage",
        "bond ladder duration", "dividend payout ratio", "EBITDA growth",
        "working capital turns", "treasury yield spread", "capex intensity",
    ];
    const FRAMES: &[&str] = &[
        "How did {s} change year over year, and what drove it?",
        "Compare {s} against the sector median for the last 3 quarters.",
        "What is the impact of rate cuts on {s} for this portfolio?",
        "Summarize the risk to {s} if revenue declines 10%.",
        "Given the 10-K excerpts, compute {s} and explain the trend.",
    ];
    let s = rng.choice(SUBJECTS);
    let mut q = rng.choice(FRAMES).replace("{s}", s);
    // occasional long, multi-part analyst question (heavy tail)
    if rng.bool_with(0.2) {
        q.push_str(
            " Then reconcile with the cash flow statement and flag any anomalies in footnotes.",
        );
    }
    q
}

/// Follow-up question in an ongoing session (human-in-the-loop step 11).
pub fn finqa_followup(rng: &mut Rng) -> String {
    const FOLLOW: &[&str] = &[
        "Can you break that down by segment?",
        "What about the previous fiscal year?",
        "Redo that assuming a 50bp rate hike.",
        "Which line items are you least confident about?",
    ];
    rng.choice(FOLLOW).to_string()
}

/// SWE-bench-flavoured coding tasks (drives the recursive SWE workflow).
pub fn swe_task(rng: &mut Rng) -> String {
    const TASKS: &[&str] = &[
        "Enable OAuth login for the website",
        "Fix the race condition in the job scheduler's requeue path",
        "Add pagination to the /orders REST endpoint",
        "Migrate the session store from memcached to redis",
        "Support unicode filenames in the upload handler",
        "Add exponential backoff to the webhook dispatcher",
        "Fix the off-by-one in the report date-range filter",
    ];
    rng.choice(TASKS).to_string()
}

/// Chat prompts for the router workflow's conversational branch.
pub fn chat_prompt(rng: &mut Rng) -> String {
    const PROMPTS: &[&str] = &[
        "Explain the difference between threads and processes",
        "Draft a polite reply declining the meeting",
        "What are good interview questions for an SRE role?",
        "Summarize the attached doc in three bullet points",
    ];
    rng.choice(PROMPTS).to_string()
}

/// Seed documents for the documentation vector store (SWE workflow).
pub fn seed_docs() -> Vec<String> {
    [
        "OAuth2 authorization code flow: redirect the user to the provider, exchange the code for tokens, validate the state parameter.",
        "Session middleware API: session.get(key), session.set(key, value), session.regenerate() on privilege change.",
        "REST pagination conventions: limit/offset query params, Link headers for next/prev, stable sort keys.",
        "Redis client: connection pooling, pipelining, SETEX for TTL keys, MULTI/EXEC transactions.",
        "Webhook retry guidance: exponential backoff with jitter, idempotency keys, dead-letter queues after N attempts.",
        "Unicode handling: normalize NFC on input, percent-encode filenames in content-disposition headers.",
        "Date-range filters: half-open intervals [start, end), timezone-normalize to UTC before comparison.",
        "Job scheduler requeue semantics: visibility timeout, at-least-once delivery, fencing tokens against double-run.",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_match_rate() {
        let mut a = Arrivals::new(100.0, 1);
        let sched = a.schedule(Duration::from_secs(10));
        // ~1000 arrivals expected; allow wide tolerance
        assert!((800..1200).contains(&sched.len()), "{}", sched.len());
        // monotonic
        for w in sched.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn azure_imbalance_flips() {
        let mut rng = Rng::new(2);
        let early: usize = (0..1000)
            .filter(|_| azure_like_class(0.2, &mut rng) == "coder")
            .count();
        let late: usize = (0..1000)
            .filter(|_| azure_like_class(0.8, &mut rng) == "coder")
            .count();
        assert!(early < 120, "phase 1 must be chat-heavy: {early}");
        assert!(late > 600, "phase 2 must be coder-heavy: {late}");
    }

    #[test]
    fn corpora_nonempty_and_vary() {
        let mut rng = Rng::new(3);
        let qs: std::collections::HashSet<String> =
            (0..50).map(|_| finqa_question(&mut rng)).collect();
        assert!(qs.len() > 10, "questions should vary");
        assert!(!swe_task(&mut rng).is_empty());
        assert!(!chat_prompt(&mut rng).is_empty());
        assert!(!finqa_followup(&mut rng).is_empty());
        assert!(seed_docs().len() >= 8);
    }

    #[test]
    fn rps_sweep_specs() {
        assert_eq!(parse_rps_sweep("20,40,80"), Some(vec![20.0, 40.0, 80.0]));
        assert_eq!(parse_rps_sweep("80"), Some(vec![80.0]));
        assert_eq!(
            parse_rps_sweep("20:100:40"),
            Some(vec![20.0, 60.0, 100.0]),
            "range is inclusive"
        );
        assert!(parse_rps_sweep("").is_none());
        assert!(parse_rps_sweep("0,40").is_none());
        assert!(parse_rps_sweep("100:20:10").is_none());
        assert!(parse_rps_sweep("a,b").is_none());
        // point-count cap: tiny steps (incl. sub-epsilon non-advancing
        // ones) are rejected instead of hanging
        assert!(parse_rps_sweep("1:1000000:1").is_none());
        assert!(parse_rps_sweep("20:160:0.000000000000001").is_none());
    }

    #[test]
    fn arrivals_deterministic_by_seed() {
        let s1 = Arrivals::new(10.0, 7).schedule(Duration::from_secs(5));
        let s2 = Arrivals::new(10.0, 7).schedule(Duration::from_secs(5));
        assert_eq!(s1, s2);
    }
}
