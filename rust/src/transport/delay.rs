//! Delayed-delivery timer thread for cross-node latency injection.
//!
//! A single background thread owns a deadline-ordered queue; `deliver_after`
//! enqueues and wakes it. FIFO per (deadline, seq) keeps per-edge ordering
//! for equal latencies — matching TCP/gRPC in-order delivery. One thread
//! for the whole bus (not one per message) keeps the §Perf hot path free of
//! thread spawns.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::Message;

struct Item {
    due: Instant,
    seq: u64,
    tx: mpsc::Sender<Message>,
    msg: Message,
}

// Order by (due, seq) — BinaryHeap is a max-heap, so wrap in Reverse at use.
impl PartialEq for Item {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Item {}
impl PartialOrd for Item {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Item {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.due, self.seq).cmp(&(other.due, other.seq))
    }
}

#[derive(Default)]
struct Shared {
    heap: Mutex<BinaryHeap<Reverse<Item>>>,
    cv: Condvar,
}

/// Handle to the timer thread (spawned lazily on first delayed send).
pub(super) struct DelayLine {
    shared: Arc<Shared>,
    seq: std::sync::atomic::AtomicU64,
    started: std::sync::Once,
}

impl DelayLine {
    pub fn new() -> Self {
        DelayLine {
            shared: Arc::new(Shared::default()),
            seq: std::sync::atomic::AtomicU64::new(0),
            started: std::sync::Once::new(),
        }
    }

    pub fn deliver_after(&self, delay: Duration, tx: mpsc::Sender<Message>, msg: Message) {
        self.started.call_once(|| {
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name("nalar-netdelay".into())
                .spawn(move || run(shared))
                .expect("spawn delay thread");
        });
        let item = Item {
            due: Instant::now() + delay,
            seq: self.seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tx,
            msg,
        };
        self.shared.heap.lock().unwrap().push(Reverse(item));
        self.shared.cv.notify_one();
    }
}

fn run(shared: Arc<Shared>) {
    let mut heap = shared.heap.lock().unwrap();
    loop {
        let now = Instant::now();
        // Deliver everything due.
        while heap.peek().map(|Reverse(i)| i.due <= now).unwrap_or(false) {
            let Reverse(item) = heap.pop().unwrap();
            let _ = item.tx.send(item.msg); // receiver may be gone: drop
        }
        match heap.peek() {
            Some(Reverse(next)) => {
                let wait = next.due.saturating_duration_since(Instant::now());
                let (g, _) = shared.cv.wait_timeout(heap, wait).unwrap();
                heap = g;
            }
            None => {
                // Idle: park until a new item arrives (checked periodically
                // so the daemon thread can't deadlock a shutdown).
                let (g, _) = shared
                    .cv
                    .wait_timeout(heap, Duration::from_millis(100))
                    .unwrap();
                heap = g;
            }
        }
    }
}
