//! Ingress: the open-loop serving front door.
//!
//! Everything before this subsystem ran workflows *closed-loop*: the
//! harness spawned one caller thread per request and each driver blocked
//! its caller — no queueing, no admission, no way to reproduce the paper's
//! capacity claim ("sustains 80 RPS where baselines fail", §6). Ingress is
//! the missing front of the pipeline:
//!
//! * [`Ingress::submit`] accepts a workflow request asynchronously,
//!   stamps its [`RequestId`]/[`SessionId`] at admission, and enqueues it
//!   into a per-workflow bounded queue instead of blocking the caller —
//!   the returned [`Ticket`] is the caller's completion handle.
//! * an [`AdmissionController`] per queue decides accept-vs-shed
//!   ([`AdmissionPolicy`]: unbounded / bounded / token bucket); shed
//!   requests fail fast with a retryable [`Error::Shed`].
//! * an **event-driven scheduler** multiplexes admitted requests over a
//!   small fixed thread pool: each request is a resumable
//!   [`crate::workflow::Driver`] polled until it suspends, then *parked*
//!   in an in-flight table — occupying no thread — until a
//!   [`crate::futures::FutureCell`] waker pushes it back onto the ready
//!   queue. `ingress.workers` bounds *threads*; `ingress.max_in_flight`
//!   bounds concurrent requests (the multiplexing factor in-flight ÷
//!   threads is published as telemetry). Deadlines are enforced on parked
//!   and queued work by a periodic sweep, again without a thread per
//!   request.
//! * queue depth and accept/shed/complete counters are pushed into the
//!   node store (`ingress/{workflow}`), where
//!   [`crate::coordinator::GlobalController::collect`] aggregates them so
//!   overload-aware policies (e.g.
//!   [`crate::coordinator::policies::OverloadProvision`]) can react.
//!
//! [`loadgen`] drives this front door with a Poisson arrival process to
//! produce the `BENCH_rps_sweep.json` saturation curve.

pub mod admission;
pub mod loadgen;

pub use admission::{AdmissionController, AdmissionPolicy};

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::IngressMetrics;
use crate::error::{Error, Result};
use crate::futures::{FutureCell, Value};
use crate::ids::{NodeId, RequestId, SessionId};
use crate::nodestore::keys;
use crate::server::Deployment;
use crate::workflow::{driver_for, Driver, Env, Step, WorkflowKind};

/// Completion slot shared between a [`Ticket`] and the scheduler.
struct TicketCell {
    slot: Mutex<TicketState>,
    cv: Condvar,
}

struct TicketState {
    done: bool,
    result: Option<Result<Value>>,
    /// Submit-to-completion latency, set exactly once at fulfilment.
    latency: Option<Duration>,
}

impl TicketCell {
    fn new() -> Arc<TicketCell> {
        Arc::new(TicketCell {
            slot: Mutex::new(TicketState { done: false, result: None, latency: None }),
            cv: Condvar::new(),
        })
    }

    fn fulfil(&self, result: Result<Value>, latency: Duration) {
        let mut g = self.slot.lock().unwrap();
        if !g.done {
            g.done = true;
            g.result = Some(result);
            g.latency = Some(latency);
        }
        self.cv.notify_all();
    }
}

/// The caller's handle for an admitted request. `submit` returns it
/// immediately; the request runs whenever the scheduler picks it up.
pub struct Ticket {
    pub request: RequestId,
    pub session: SessionId,
    cell: Arc<TicketCell>,
}

impl Ticket {
    /// Block until the request finishes or `timeout` passes. Consumes the
    /// result: a second `wait` after a successful one errors.
    pub fn wait(&self, timeout: Duration) -> Result<Value> {
        let deadline = Instant::now() + timeout;
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if g.done {
                return g
                    .result
                    .take()
                    .unwrap_or_else(|| Err(Error::Msg("ticket result already taken".into())));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Deadline(timeout));
            }
            let (g2, _) = self.cell.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }

    /// Submit-to-completion latency, once the request finished.
    pub fn latency(&self) -> Option<Duration> {
        self.cell.slot.lock().unwrap().latency
    }
}

/// One admitted request waiting to start (no driver built yet).
struct Queued {
    session: SessionId,
    request: RequestId,
    input: Value,
    submitted: Instant,
    deadline: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
}

/// One started request: a stored continuation, not a thread's stack. This
/// is the representation the two-level control plane needs for everything
/// downstream — it can be parked, re-enqueued, expired, (eventually)
/// cancelled or migrated, all without owning a thread.
struct InFlight {
    idx: usize,
    request: RequestId,
    driver: Box<dyn Driver>,
    env: Env,
    submitted: Instant,
    deadline: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
    /// Futures this request already holds a waker on: each is subscribed
    /// at most once per request, so a join pending through many wake
    /// cycles doesn't accumulate duplicate wakers (and their spurious
    /// re-polls) on its slowest futures.
    subscribed: HashSet<u64>,
}

/// A request whose deadline expired before completion, collected by the
/// sweep for fulfilment outside the scheduler lock.
struct Lapsed {
    idx: usize,
    submitted: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
    /// True if it never started (still in the admission queue) —
    /// `expired_in_queue`, not an execution failure.
    in_queue: bool,
}

/// Scheduler state under one lock: admission queues feed the in-flight
/// table; wakers move parked continuations to the ready queue.
struct SchedState {
    /// One deque per entry of `kinds`; contention is negligible at
    /// front-door rates and a single lock keeps pop-fairness trivial.
    queues: Vec<VecDeque<Queued>>,
    /// Runnable continuations (woken or freshly admitted).
    ready: VecDeque<InFlight>,
    /// Suspended continuations keyed by `RequestId.0`, waiting on wakers.
    parked: HashMap<u64, InFlight>,
    /// Wakeups that arrived while their request was being polled (it was
    /// neither parked nor ready); consumed when the poll finishes.
    woken: HashSet<u64>,
    /// Parked continuations with nothing to subscribe to (a
    /// shouldn't-happen): the next sweep re-polls them — a bounded 0..5ms
    /// backoff instead of a hot requeue loop.
    nudge: Vec<u64>,
    /// Every started-but-unfinished request id (ready + parked + polling).
    live: HashSet<u64>,
    /// Started-but-unfinished count per workflow (the `in_flight` gauge).
    in_flight: Vec<usize>,
    /// Next deadline sweep over parked + queued work.
    next_sweep: Instant,
}

impl SchedState {
    fn total_in_flight(&self) -> usize {
        self.live.len()
    }
}

/// What one scheduler iteration decided to do.
enum Task {
    /// Re-poll a woken continuation.
    Poll(InFlight),
    /// Start a freshly admitted request (build its driver, first poll).
    Admit(usize, Queued),
}

/// Sizing for the event-driven scheduler.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerOpts {
    /// OS threads multiplexing the in-flight table.
    pub workers: usize,
    /// Concurrent started requests (the backpressure bound: admission
    /// queues only drain while in-flight is below this).
    pub max_in_flight: usize,
}

/// Telemetry publish throttle — same cadence as the component
/// controllers' `maybe_push_telemetry`, so the hot path pays at most one
/// store write per queue per period instead of one per event.
const PUBLISH_PERIOD: Duration = Duration::from_millis(20);

/// Deadline-sweep cadence: bounds how stale an expired parked/queued
/// request can get before it is failed fast. Also the idle wait, so a
/// missed notify never stalls the pool longer than this.
const SWEEP_PERIOD: Duration = Duration::from_millis(5);

struct IngressInner {
    d: Deployment,
    kinds: Vec<WorkflowKind>,
    sched: Mutex<SchedState>,
    cv: Condvar,
    admission: Vec<AdmissionController>,
    completed: Vec<AtomicU64>,
    failed: Vec<AtomicU64>,
    /// Deadline expiries that never started a driver (satellite metric:
    /// distinguishable from execution failures in the sweep schema).
    expired_in_queue: Vec<AtomicU64>,
    workers: usize,
    max_in_flight: usize,
    last_publish: Vec<Mutex<Instant>>,
    stop: AtomicBool,
}

impl IngressInner {
    fn kind_index(&self, kind: WorkflowKind) -> Option<usize> {
        self.kinds.iter().position(|k| *k == kind)
    }

    /// One queue's telemetry snapshot (shared by [`Ingress::metrics`] and
    /// the node-store publish path — one construction site).
    fn snapshot(&self, idx: usize) -> IngressMetrics {
        let adm = &self.admission[idx];
        let (depth, in_flight) = {
            let s = self.sched.lock().unwrap();
            (s.queues[idx].len(), s.in_flight[idx])
        };
        IngressMetrics {
            workflow: self.kinds[idx].name().to_string(),
            depth,
            in_flight,
            workers: self.workers,
            cap: adm.policy().cap(),
            policy: adm.policy().name().to_string(),
            accepted: adm.accepted.load(Ordering::Relaxed),
            shed: adm.shed.load(Ordering::Relaxed),
            completed: self.completed[idx].load(Ordering::Relaxed),
            failed: self.failed[idx].load(Ordering::Relaxed),
            expired_in_queue: self.expired_in_queue[idx].load(Ordering::Relaxed),
        }
    }

    /// Push this queue's telemetry into the node store (node 0 hosts the
    /// front door — it is "the" ingress node of the emulated cluster).
    fn publish(&self, idx: usize) {
        let m = self.snapshot(idx);
        let key = keys::ingress(&m.workflow);
        self.d.stores().node(NodeId(0)).put(&key, m);
    }

    /// Throttled [`Self::publish`]: at most one store write per queue per
    /// [`PUBLISH_PERIOD`]. Lifecycle edges (start/stop) publish directly.
    fn maybe_publish(&self, idx: usize) {
        {
            let mut last = self.last_publish[idx].lock().unwrap();
            if last.elapsed() < PUBLISH_PERIOD {
                return;
            }
            *last = Instant::now();
        }
        self.publish(idx);
    }

    /// Scheduler worker: multiplexes the in-flight table. Priority order
    /// per iteration: overdue deadline sweep, then woken continuations,
    /// then admission (bounded by `max_in_flight`), else park on the
    /// condvar until an event or the next sweep is due.
    fn worker_loop(self: Arc<Self>, worker: usize) {
        let nkinds = self.kinds.len();
        let mut rot = worker; // stagger the admission scan start per worker
        loop {
            let mut lapsed = Vec::new();
            let task = {
                let mut s = self.sched.lock().unwrap();
                if self.stop.load(Ordering::Relaxed) {
                    return;
                }
                let now = Instant::now();
                if now >= s.next_sweep {
                    s.next_sweep = now + SWEEP_PERIOD;
                    Self::collect_lapsed(&mut s, now, &mut lapsed);
                    // re-poll continuations that had nothing to subscribe
                    // to (bounded backoff; see `SchedState::nudge`)
                    let nudge: Vec<u64> = s.nudge.drain(..).collect();
                    for rid in nudge {
                        if let Some(f) = s.parked.remove(&rid) {
                            s.ready.push_back(f);
                        }
                    }
                }
                if let Some(f) = s.ready.pop_front() {
                    Some(Task::Poll(f))
                } else {
                    let mut admitted = None;
                    if s.total_in_flight() < self.max_in_flight {
                        for i in 0..nkinds {
                            let idx = (rot + i) % nkinds;
                            if let Some(job) = s.queues[idx].pop_front() {
                                admitted = Some((idx, job));
                                break;
                            }
                        }
                    }
                    match admitted {
                        Some((idx, job)) => {
                            rot = rot.wrapping_add(1);
                            s.live.insert(job.request.0);
                            s.in_flight[idx] += 1;
                            Some(Task::Admit(idx, job))
                        }
                        None => {
                            // idle, or at the in-flight cap: park until a
                            // submit/waker/capacity event or the next sweep
                            // — unless this iteration collected lapsed
                            // work, which must be failed fast first
                            if lapsed.is_empty() {
                                let _ = self.cv.wait_timeout(s, SWEEP_PERIOD).unwrap();
                            }
                            None
                        }
                    }
                }
            };
            self.fail_lapsed(lapsed);
            match task {
                Some(Task::Poll(f)) => Self::run_poll(&self, f),
                Some(Task::Admit(idx, job)) => Self::admit(&self, idx, job),
                None => {}
            }
        }
    }

    /// Collect every queued/parked request whose deadline has passed
    /// (fulfilment happens outside the lock, in [`Self::fail_lapsed`]).
    fn collect_lapsed(s: &mut SchedState, now: Instant, out: &mut Vec<Lapsed>) {
        for (idx, q) in s.queues.iter_mut().enumerate() {
            if q.iter().all(|j| j.deadline > now) {
                continue;
            }
            let mut kept = VecDeque::with_capacity(q.len());
            for job in q.drain(..) {
                if job.deadline <= now {
                    out.push(Lapsed {
                        idx,
                        submitted: job.submitted,
                        timeout: job.timeout,
                        cell: job.cell,
                        in_queue: true,
                    });
                } else {
                    kept.push_back(job);
                }
            }
            *q = kept;
        }
        let overdue: Vec<u64> =
            s.parked.iter().filter(|(_, f)| f.deadline <= now).map(|(k, _)| *k).collect();
        for rid in overdue {
            let f = s.parked.remove(&rid).expect("collected above");
            s.live.remove(&rid);
            s.woken.remove(&rid);
            s.in_flight[f.idx] -= 1;
            out.push(Lapsed {
                idx: f.idx,
                submitted: f.submitted,
                timeout: f.timeout,
                cell: f.cell,
                in_queue: false,
            });
        }
    }

    /// Fail expired work fast: queued expiries count as `expired_in_queue`
    /// (the driver never ran), parked expiries as execution failures.
    fn fail_lapsed(&self, lapsed: Vec<Lapsed>) {
        for l in lapsed {
            if l.in_queue {
                self.expired_in_queue[l.idx].fetch_add(1, Ordering::Relaxed);
            } else {
                self.failed[l.idx].fetch_add(1, Ordering::Relaxed);
            }
            l.cell.fulfil(Err(Error::Deadline(l.timeout)), l.submitted.elapsed());
            self.maybe_publish(l.idx);
        }
    }

    /// Start one admitted request: build its resumable driver and poll it.
    /// (`this` instead of a receiver: wakers need the `Arc` to clone.)
    fn admit(this: &Arc<Self>, idx: usize, job: Queued) {
        if Instant::now() >= job.deadline {
            // expired while queued: fail fast, never build the driver
            this.expired_in_queue[idx].fetch_add(1, Ordering::Relaxed);
            {
                let mut s = this.sched.lock().unwrap();
                s.live.remove(&job.request.0);
                s.in_flight[idx] -= 1;
            }
            job.cell.fulfil(Err(Error::Deadline(job.timeout)), job.submitted.elapsed());
            this.maybe_publish(idx);
            this.cv.notify_one(); // in-flight capacity freed
            return;
        }
        let env = Env::with_request(&this.d, job.session, job.request);
        let driver = driver_for(this.kinds[idx], &job.input);
        Self::run_poll(
            this,
            InFlight {
                idx,
                request: job.request,
                driver,
                env,
                submitted: job.submitted,
                deadline: job.deadline,
                timeout: job.timeout,
                cell: job.cell,
                subscribed: HashSet::new(),
            },
        );
    }

    /// Poll one continuation: advance it as far as readiness allows, then
    /// either finish it or park it under waker subscriptions.
    fn run_poll(this: &Arc<Self>, mut f: InFlight) {
        if Instant::now() >= f.deadline {
            let timeout = f.timeout;
            this.finish(f, Err(Error::Deadline(timeout)));
            return;
        }
        match f.driver.poll(&f.env) {
            Step::Done(result) => this.finish(f, result),
            Step::Pending { waiting_on } => {
                let rid = f.request.0;
                // Resolve the not-yet-subscribed cells *before* parking:
                // once parked, another worker may take the continuation at
                // any moment. Already-subscribed futures keep their
                // original waker (one per future per request).
                let mut cells: Vec<Arc<FutureCell>> = Vec::new();
                let mut can_wake = false;
                for id in &waiting_on {
                    if f.subscribed.contains(&id.0) {
                        can_wake = true;
                        continue;
                    }
                    if let Some(cell) = this.d.table().get(*id) {
                        f.subscribed.insert(id.0);
                        cells.push(cell);
                        can_wake = true;
                    }
                }
                {
                    let mut s = this.sched.lock().unwrap();
                    if s.woken.remove(&rid) {
                        // a waker fired mid-poll: run again rather than
                        // risk a lost wakeup
                        s.ready.push_back(f);
                    } else {
                        s.parked.insert(rid, f);
                        if !can_wake {
                            // nothing is subscribable (a shouldn't-happen:
                            // stubs register every future) — let the next
                            // sweep re-poll it instead of hot-spinning
                            s.nudge.push(rid);
                        }
                    }
                }
                // Subscribe after parking: a future that resolved in the
                // gap fires the waker inline, which finds the parked entry
                // and moves it to ready — no wakeup is lost.
                for cell in cells {
                    let inner = this.clone();
                    cell.subscribe(Box::new(move || inner.wake(rid)));
                }
            }
        }
    }

    /// Waker target: move a parked continuation to the ready queue. Fired
    /// by future resolution from component-controller threads.
    fn wake(&self, rid: u64) {
        let mut s = self.sched.lock().unwrap();
        if let Some(f) = s.parked.remove(&rid) {
            s.ready.push_back(f);
            drop(s);
            self.cv.notify_one();
        } else if s.live.contains(&rid) {
            // being polled right now: record the wakeup for the poller
            s.woken.insert(rid);
        }
        // else: the request already finished — stale waker, nothing to do
    }

    /// Account and fulfil one finished request.
    fn finish(&self, f: InFlight, result: Result<Value>) {
        match &result {
            Ok(_) => self.completed[f.idx].fetch_add(1, Ordering::Relaxed),
            Err(_) => self.failed[f.idx].fetch_add(1, Ordering::Relaxed),
        };
        {
            let mut s = self.sched.lock().unwrap();
            s.live.remove(&f.request.0);
            s.woken.remove(&f.request.0);
            s.in_flight[f.idx] -= 1;
        }
        f.cell.fulfil(result, f.submitted.elapsed());
        self.maybe_publish(f.idx);
        self.cv.notify_one(); // in-flight capacity freed: admit more
    }
}

/// See module docs.
pub struct Ingress {
    inner: Arc<IngressInner>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Ingress {
    /// Start a front door for `kinds` using the deployment's configured
    /// admission settings (`DeploymentConfig.ingress`).
    pub fn start(d: &Deployment, kinds: &[WorkflowKind]) -> Ingress {
        let s = &d.cfg().ingress;
        Self::start_with(d, kinds, AdmissionPolicy::from_settings(s), s.workers)
    }

    /// Start with an explicit admission policy and scheduler thread count
    /// (`max_in_flight` comes from the deployment config).
    pub fn start_with(
        d: &Deployment,
        kinds: &[WorkflowKind],
        policy: AdmissionPolicy,
        workers: usize,
    ) -> Ingress {
        let max_in_flight = d.cfg().ingress.max_in_flight;
        Self::start_with_opts(d, kinds, policy, SchedulerOpts { workers, max_in_flight })
    }

    /// Start with explicit scheduler sizing.
    pub fn start_with_opts(
        d: &Deployment,
        kinds: &[WorkflowKind],
        policy: AdmissionPolicy,
        opts: SchedulerOpts,
    ) -> Ingress {
        assert!(!kinds.is_empty(), "ingress needs at least one workflow");
        let workers = opts.workers.max(1);
        let inner = Arc::new(IngressInner {
            d: d.clone(),
            kinds: kinds.to_vec(),
            sched: Mutex::new(SchedState {
                queues: kinds.iter().map(|_| VecDeque::new()).collect(),
                ready: VecDeque::new(),
                parked: HashMap::new(),
                woken: HashSet::new(),
                nudge: Vec::new(),
                live: HashSet::new(),
                in_flight: vec![0; kinds.len()],
                next_sweep: Instant::now() + SWEEP_PERIOD,
            }),
            cv: Condvar::new(),
            admission: kinds.iter().map(|_| AdmissionController::new(policy.clone())).collect(),
            completed: kinds.iter().map(|_| AtomicU64::new(0)).collect(),
            failed: kinds.iter().map(|_| AtomicU64::new(0)).collect(),
            expired_in_queue: kinds.iter().map(|_| AtomicU64::new(0)).collect(),
            workers,
            max_in_flight: opts.max_in_flight.max(1),
            last_publish: kinds.iter().map(|_| Mutex::new(Instant::now())).collect(),
            stop: AtomicBool::new(false),
        });
        let joins = (0..workers)
            .map(|w| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("nalar-ingress-{w}"))
                    .spawn(move || inner.worker_loop(w))
                    .expect("spawn ingress worker")
            })
            .collect();
        for idx in 0..kinds.len() {
            inner.publish(idx); // make the queue visible to policies at once
        }
        Ingress { inner, joins: Mutex::new(joins) }
    }

    /// Accept or shed one request. Non-blocking: on acceptance the request
    /// is queued and the caller gets a [`Ticket`]; on shed the caller gets
    /// a retryable [`Error::Shed`] immediately. `session: None` opens a
    /// fresh session. `timeout` is the request's end-to-end deadline,
    /// counted from admission.
    pub fn submit(
        &self,
        kind: WorkflowKind,
        session: Option<SessionId>,
        input: Value,
        timeout: Duration,
    ) -> Result<Ticket> {
        let inner = &self.inner;
        let idx = inner
            .kind_index(kind)
            .ok_or_else(|| Error::Config(format!("ingress does not serve `{}`", kind.name())))?;
        let verdict = {
            let mut s = inner.sched.lock().unwrap();
            // Checked under the scheduler lock: `stop` drains the queues
            // under this same lock after setting the flag, so a submit
            // either lands before the drain (and is failed by it) or
            // observes the flag here — no ticket is ever left unfulfilled.
            if inner.stop.load(Ordering::Relaxed) {
                return Err(Error::Shed(kind.name().into(), "ingress stopped".into()));
            }
            match inner.admission[idx].admit(s.queues[idx].len()) {
                Ok(()) => {
                    let session = session.unwrap_or_else(|| inner.d.new_session());
                    let request = inner.d.new_request_id();
                    let cell = TicketCell::new();
                    let now = Instant::now();
                    s.queues[idx].push_back(Queued {
                        session,
                        request,
                        input,
                        submitted: now,
                        deadline: now + timeout,
                        timeout,
                        cell: cell.clone(),
                    });
                    Ok(Ticket { request, session, cell })
                }
                Err(reason) => Err(Error::Shed(kind.name().into(), reason)),
            }
        };
        if verdict.is_ok() {
            inner.cv.notify_one();
        }
        inner.maybe_publish(idx);
        verdict
    }

    /// Current depth of a workflow's admission queue (requests not yet
    /// started; started work is [`Self::in_flight`]).
    pub fn depth(&self, kind: WorkflowKind) -> usize {
        match self.inner.kind_index(kind) {
            Some(idx) => self.inner.sched.lock().unwrap().queues[idx].len(),
            None => 0,
        }
    }

    /// Started-but-unfinished requests for a workflow (the multiplexing
    /// gauge: in-flight ÷ workers is how many requests each thread is
    /// carrying).
    pub fn in_flight(&self, kind: WorkflowKind) -> usize {
        match self.inner.kind_index(kind) {
            Some(idx) => self.inner.sched.lock().unwrap().in_flight[idx],
            None => 0,
        }
    }

    /// Telemetry snapshot for one workflow queue (same struct the global
    /// controller aggregates).
    pub fn metrics(&self, kind: WorkflowKind) -> Option<IngressMetrics> {
        Some(self.inner.snapshot(self.inner.kind_index(kind)?))
    }

    /// Stop the scheduler: workers finish the poll they are executing;
    /// everything queued or parked fails fast (reported, not masked — §5).
    /// Idempotent; also runs on drop.
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.cv.notify_all();
        for j in self.joins.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        // Drain under the scheduler lock (pairs with the stop check in
        // `submit`), fulfil outside it.
        let (queued, inflight): (Vec<(usize, Queued)>, Vec<InFlight>) = {
            let mut s = self.inner.sched.lock().unwrap();
            let mut queued = Vec::new();
            for (i, dq) in s.queues.iter_mut().enumerate() {
                for j in dq.drain(..) {
                    queued.push((i, j));
                }
            }
            let mut inflight: Vec<InFlight> = s.ready.drain(..).collect();
            inflight.extend(s.parked.drain().map(|(_, f)| f));
            for f in &inflight {
                s.live.remove(&f.request.0);
                s.in_flight[f.idx] -= 1;
            }
            s.woken.clear();
            s.nudge.clear();
            (queued, inflight)
        };
        for (idx, job) in queued {
            self.inner.failed[idx].fetch_add(1, Ordering::Relaxed);
            let kind = self.inner.kinds[idx].name().to_string();
            let waited = job.submitted.elapsed();
            job.cell.fulfil(Err(Error::Shed(kind, "ingress stopped".into())), waited);
        }
        for f in inflight {
            self.inner.failed[f.idx].fetch_add(1, Ordering::Relaxed);
            let kind = self.inner.kinds[f.idx].name().to_string();
            let waited = f.submitted.elapsed();
            f.cell.fulfil(Err(Error::Shed(kind, "ingress stopped".into())), waited);
        }
        for idx in 0..self.inner.kinds.len() {
            self.inner.publish(idx);
        }
    }
}

impl Drop for Ingress {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn fast_router() -> Deployment {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        cfg.control.global_period_ms = 10;
        Deployment::launch(cfg).unwrap()
    }

    fn router_input() -> Value {
        json!({"prompt": "hello", "class": "chat"})
    }

    #[test]
    fn submits_complete_through_the_scheduler() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 4);
        let timeout = Duration::from_secs(20);
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| ing.submit(WorkflowKind::Router, None, router_input(), timeout).unwrap())
            .collect();
        for t in &tickets {
            let out = t.wait(timeout).unwrap();
            assert!(!out.is_null());
            assert!(t.latency().unwrap() > Duration::ZERO);
        }
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.accepted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.shed, 0);
        assert_eq!(m.in_flight, 0, "everything drained");
        assert_eq!(m.workers, 4);
        // distinct request ids were stamped at admission
        let mut ids: Vec<u64> = tickets.iter().map(|t| t.request.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn bounded_queue_sheds_fast_and_never_exceeds_cap() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.002; // slow enough that a tiny scheduler falls behind
        let d = Deployment::launch(cfg).unwrap();
        let cap = 4;
        // One thread, two in-flight slots: the queue must back up and shed.
        let ing = Ingress::start_with_opts(
            &d,
            &[WorkflowKind::Router],
            AdmissionPolicy::Bounded { cap },
            SchedulerOpts { workers: 1, max_in_flight: 2 },
        );
        let timeout = Duration::from_secs(30);
        let mut tickets = Vec::new();
        let mut sheds = 0;
        for _ in 0..40 {
            match ing.submit(WorkflowKind::Router, None, router_input(), timeout) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    // fails fast with a retryable shed error
                    assert!(matches!(e, Error::Shed(..)), "{e}");
                    assert!(e.retryable());
                    sheds += 1;
                }
            }
            assert!(ing.depth(WorkflowKind::Router) <= cap, "bounded queue exceeded its cap");
        }
        assert!(sheds > 0, "a 2-slot scheduler must fall behind a 40-request burst");
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.shed, sheds);
        assert_eq!(m.cap, cap);
        for t in &tickets {
            let _ = t.wait(timeout); // accepted work still drains
        }
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn expired_deadline_fails_fast_without_running() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let t = ing
            .submit(WorkflowKind::Router, None, router_input(), Duration::ZERO)
            .unwrap();
        let err = t.wait(Duration::from_secs(5)).unwrap_err();
        assert!(matches!(err, Error::Deadline(..)), "{err}");
        assert!(err.retryable());
        // counted as an in-queue expiry, NOT an execution failure
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.expired_in_queue, 1);
        assert_eq!(m.failed, 0);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn telemetry_lands_in_global_controller_view() {
        let d = fast_router();
        let ing = Ingress::start_with(
            &d,
            &[WorkflowKind::Router],
            AdmissionPolicy::Bounded { cap: 64 },
            2,
        );
        let timeout = Duration::from_secs(20);
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| ing.submit(WorkflowKind::Router, None, router_input(), timeout).unwrap())
            .collect();
        for t in &tickets {
            t.wait(timeout).unwrap();
        }
        // publishes are throttled on the hot path; stop() flushes the
        // final state, which the global controller then aggregates.
        ing.stop();
        let view = d.global().collect();
        let ingress = view
            .ingress
            .iter()
            .find(|i| i.workflow == "router")
            .expect("ingress telemetry missing from cluster view");
        assert_eq!(ingress.accepted, 4);
        assert_eq!(ingress.completed, 4);
        assert_eq!(ingress.policy, "bounded");
        assert_eq!(ingress.cap, 64);
        assert_eq!(ingress.workers, 2, "thread gauge must reach policies");
        assert_eq!(ingress.expired_in_queue, 0);
        d.shutdown();
    }

    #[test]
    fn stop_fails_queued_work_and_rejects_new_submits() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.002;
        let d = Deployment::launch(cfg).unwrap();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let timeout = Duration::from_secs(30);
        let tickets: Vec<Ticket> = (0..10)
            .map(|_| ing.submit(WorkflowKind::Router, None, router_input(), timeout).unwrap())
            .collect();
        ing.stop();
        let failures = tickets
            .iter()
            .filter(|t| t.wait(Duration::from_secs(1)).is_err())
            .count();
        assert!(failures >= 1, "queued work must fail fast at shutdown");
        assert!(ing
            .submit(WorkflowKind::Router, None, router_input(), timeout)
            .is_err());
        d.shutdown();
    }

    #[test]
    fn unserved_workflow_is_a_config_error() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let err = ing
            .submit(WorkflowKind::Swe, None, json!({"task": "t"}), Duration::from_secs(1))
            .unwrap_err();
        assert!(matches!(err, Error::Config(..)), "{err}");
        ing.stop();
        d.shutdown();
    }
}
