//! End-to-end numerics of the AOT path: the HLO artifacts executed through
//! PJRT from Rust must behave like a real LM runtime — deterministic
//! logits, prefill/decode consistency, working embeddings.
//!
//! (Cross-checking exact values against jax happens in the python suite;
//! here we verify the runtime-visible *invariants* of the same artifacts.)
//!
//! Gating: the whole suite compiles only with `--features pjrt`, and each
//! test skips cleanly when `artifacts/` is missing or the PJRT backend is
//! the offline stub. Set `NALAR_REQUIRE_ARTIFACTS=1` to turn those skips
//! into hard failures (for environments that promise a real backend).
#![cfg(feature = "pjrt")]

use nalar::engine::tokenizer::{argmax, Tokenizer};
use nalar::runtime::{KvBatch, PjrtModel};

fn artifacts() -> Option<PjrtModel> {
    let required = std::env::var("NALAR_REQUIRE_ARTIFACTS").is_ok();
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        assert!(!required, "NALAR_REQUIRE_ARTIFACTS set but artifacts/ is missing");
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    match PjrtModel::load(dir) {
        Ok(m) => Some(m),
        Err(e) => {
            assert!(!required, "NALAR_REQUIRE_ARTIFACTS set but PJRT load failed: {e}");
            eprintln!("skipping: PJRT backend unavailable ({e})");
            None
        }
    }
}

#[test]
fn prefill_decode_consistency() {
    let Some(model) = artifacts() else { return };
    let dims = model.dims();
    let tok = Tokenizer::new(&dims);

    // Prefill a prompt, then: decoding the argmax token must equal
    // prefilling the prompt+token (same invariant as python/tests).
    let prompt = tok.encode("the quick brown fox", 16);
    let out = model.prefill(&[prompt.clone()]).unwrap();
    assert_eq!(out.logits[0].len(), dims.vocab);
    let next = argmax(&out.logits[0]);

    // decode path
    let seq = out.kv.gather(&dims, 0, prompt.len());
    let mut kvb = KvBatch::zeros(&dims, 1);
    kvb.scatter(&dims, 0, &seq);
    let dec = model
        .decode(&[next], &[prompt.len() as i32], kvb)
        .unwrap();

    // extended prefill path
    let mut ext = prompt.clone();
    ext.push(next);
    let out2 = model.prefill(&[ext]).unwrap();

    let a = &dec.logits[0];
    let b = &out2.logits[0];
    let mut max_diff = 0f32;
    for (x, y) in a.iter().zip(b.iter()) {
        max_diff = max_diff.max((x - y).abs());
    }
    assert!(
        max_diff < 2e-3,
        "decode vs extended-prefill logits diverge: {max_diff}"
    );
}

#[test]
fn prefill_deterministic_and_batch_consistent() {
    let Some(model) = artifacts() else { return };
    let dims = model.dims();
    let tok = Tokenizer::new(&dims);
    let p1 = tok.encode("hello world", 8);
    let p2 = tok.encode("pay down the bond ladder", 8);

    let single = model.prefill(&[p1.clone()]).unwrap();
    let again = model.prefill(&[p1.clone()]).unwrap();
    assert_eq!(single.logits[0], again.logits[0], "prefill must be deterministic");

    // batch-of-2 must match per-sequence results
    let batched = model.prefill(&[p1.clone(), p2.clone()]).unwrap();
    let solo2 = model.prefill(&[p2]).unwrap();
    let diff = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max)
    };
    assert!(diff(&batched.logits[0], &single.logits[0]) < 1e-3);
    assert!(diff(&batched.logits[1], &solo2.logits[0]) < 1e-3);
}

#[test]
fn multi_step_generation_terminates() {
    let Some(model) = artifacts() else { return };
    let dims = model.dims();
    let tok = Tokenizer::new(&dims);
    let prompt = tok.encode("generate", 32);
    let out = model.prefill(&[prompt.clone()]).unwrap();
    let mut kv = out.kv;
    let mut t = argmax(&out.logits[0]);
    let mut pos = prompt.len() as i32;
    for _ in 0..8 {
        let dec = model.decode(&[t], &[pos], kv).unwrap();
        t = argmax(&dec.logits[0]);
        kv = dec.kv;
        pos += 1;
        assert!(dec.logits[0].iter().all(|x| x.is_finite()));
    }
}

#[test]
fn embeddings_unit_norm_and_discriminative() {
    let Some(model) = artifacts() else { return };
    let dims = model.dims();
    let tok = Tokenizer::new(&dims);
    let a = tok.encode("market analysis of bond yields", 1);
    let b = tok.encode("market analysis of bond yields", 1);
    let c = tok.encode("zzzzzz totally unrelated !!!", 1);
    let embs = model.embed(&[a, b, c]).unwrap();
    assert_eq!(embs.len(), 3);
    for e in &embs {
        assert_eq!(e.len(), dims.d_model);
        let n: f32 = e.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-3, "norm {n}");
    }
    let dot = |x: &[f32], y: &[f32]| -> f32 { x.iter().zip(y).map(|(a, b)| a * b).sum() };
    let same = dot(&embs[0], &embs[1]);
    let diffr = dot(&embs[0], &embs[2]);
    assert!(same > 0.999, "identical texts must embed identically ({same})");
    assert!(same > diffr, "identical texts must be closer than unrelated");
}
