"""Pure-jnp reference oracles for the L1 Pallas attention kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance across shapes, lengths and dtypes
(see python/tests/test_kernel.py, which sweeps with hypothesis).

The oracles are deliberately naive — full score matrices, explicit masks —
so they are easy to audit against the standard attention definition.
"""

import jax.numpy as jnp

NEG_INF = -1e30  # large-negative mask value (not -inf: avoids NaN on all-masked rows)


def attention_prefill_ref(q, k, v, length):
    """Causal + padding-masked multi-head attention (one batch element).

    Args:
      q, k, v: ``[H, T, Dh]`` float arrays.
      length:  scalar int — number of valid (non-pad) positions; positions
               ``>= length`` are masked out as keys.

    Returns:
      ``[H, T, Dh]`` attention output. Rows at/after ``length`` attend only
      to valid keys so they stay finite; consumers ignore them.
    """
    h, t, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    scores = jnp.einsum("htd,hsd->hts", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    ti = jnp.arange(t)
    causal = ti[:, None] >= ti[None, :]  # query i sees key j iff j <= i
    valid = ti[None, :] < length  # key j must be a real token
    mask = jnp.logical_and(causal, valid)[None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hts,hsd->htd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_decode_ref(q, k, v, pos):
    """Single-position decode attention over a KV cache (one batch element).

    Args:
      q:   ``[H, Dh]`` query for the token at position ``pos``.
      k,v: ``[H, S, Dh]`` KV cache; entries at positions ``> pos`` are stale.
      pos: scalar int — index of the current token (attends to ``0..=pos``).

    Returns:
      ``[H, Dh]`` attention output.
    """
    h, s, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    scores = jnp.einsum("hd,hsd->hs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hs,hsd->hd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
