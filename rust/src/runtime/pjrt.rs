//! The PJRT executor thread: compile once, serve prefill/decode/embed.
//!
//! `PjrtModel::load` spawns the thread, which builds a CPU `PjRtClient`,
//! uploads the weights blob as literals, compiles every manifest entry
//! (HLO text -> `HloModuleProto::from_text_file` -> `client.compile`), and
//! then loops on a channel serving execution requests. The public handle
//! is `Clone + Send` so multiple engine instances can share one device.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::runtime::kv::KvBatch;
use crate::runtime::manifest::{Manifest, ModelDims};
use crate::runtime::xla;

/// Prefill result: next-token logits per sequence + the batched KV tensor.
pub struct PrefillOut {
    pub logits: Vec<Vec<f32>>,
    pub kv: KvBatch,
}

/// Decode result: logits per sequence + updated KV tensor.
pub struct DecodeOut {
    pub logits: Vec<Vec<f32>>,
    pub kv: KvBatch,
}

enum Cmd {
    Prefill {
        tokens: Vec<Vec<i32>>, // padded to max_seq by the thread
        lengths: Vec<i32>,
        reply: mpsc::Sender<Result<PrefillOut>>,
    },
    Decode {
        token: Vec<i32>,
        pos: Vec<i32>,
        kv: KvBatch,
        reply: mpsc::Sender<Result<DecodeOut>>,
    },
    Embed {
        tokens: Vec<Vec<i32>>,
        lengths: Vec<i32>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
}

/// Cloneable, `Send` handle to the executor thread.
#[derive(Clone)]
pub struct PjrtModel {
    tx: mpsc::Sender<Cmd>,
    dims: ModelDims,
    // Serializes callers so replies pair with requests (the device is a
    // single serial executor anyway).
    call_lock: Arc<Mutex<()>>,
}

impl PjrtModel {
    /// Load artifacts and start the executor thread. Fails fast if the
    /// manifest is missing or any entry fails to compile.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?; // parse on caller thread: fail early
        let dims = manifest.dims;
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        std::thread::Builder::new()
            .name("nalar-pjrt".into())
            .spawn(move || executor_thread(manifest, rx, ready_tx))
            .map_err(|e| Error::Runtime(e.to_string()))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("pjrt thread died during init".into()))??;
        Ok(PjrtModel { tx, dims, call_lock: Arc::new(Mutex::new(())) })
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    /// Prefill a batch of token prompts (unpadded); returns per-sequence
    /// next-token logits and the batched KV (batch = compiled variant size,
    /// callers use the first `tokens.len()` slots).
    pub fn prefill(&self, tokens: &[Vec<i32>]) -> Result<PrefillOut> {
        let lengths: Vec<i32> = tokens.iter().map(|t| t.len().max(1) as i32).collect();
        let padded = tokens
            .iter()
            .map(|t| self.pad(t))
            .collect::<Result<Vec<_>>>()?;
        let _g = self.call_lock.lock().unwrap();
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Prefill { tokens: padded, lengths, reply })
            .map_err(|_| Error::Runtime("pjrt thread gone".into()))?;
        rx.recv().map_err(|_| Error::Runtime("pjrt thread gone".into()))?
    }

    /// One decode step. `kv` must come from a prior prefill/decode with the
    /// same batch size.
    pub fn decode(&self, token: &[i32], pos: &[i32], kv: KvBatch) -> Result<DecodeOut> {
        let _g = self.call_lock.lock().unwrap();
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Decode { token: token.to_vec(), pos: pos.to_vec(), kv, reply })
            .map_err(|_| Error::Runtime("pjrt thread gone".into()))?;
        rx.recv().map_err(|_| Error::Runtime("pjrt thread gone".into()))?
    }

    /// Mean-pooled unit-norm embeddings (vector-store path).
    pub fn embed(&self, tokens: &[Vec<i32>]) -> Result<Vec<Vec<f32>>> {
        let lengths: Vec<i32> = tokens.iter().map(|t| t.len().max(1) as i32).collect();
        let padded = tokens
            .iter()
            .map(|t| self.pad(t))
            .collect::<Result<Vec<_>>>()?;
        let _g = self.call_lock.lock().unwrap();
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Embed { tokens: padded, lengths, reply })
            .map_err(|_| Error::Runtime("pjrt thread gone".into()))?;
        rx.recv().map_err(|_| Error::Runtime("pjrt thread gone".into()))?
    }

    fn pad(&self, t: &[i32]) -> Result<Vec<i32>> {
        if t.len() > self.dims.max_seq {
            return Err(Error::Engine(format!(
                "prompt of {} tokens exceeds max_seq {}",
                t.len(),
                self.dims.max_seq
            )));
        }
        let mut out = vec![self.dims.pad; self.dims.max_seq];
        out[..t.len()].copy_from_slice(t);
        Ok(out)
    }
}

// ---------------------------------------------------------------- thread

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    phase: String,
}

fn executor_thread(manifest: Manifest, rx: mpsc::Receiver<Cmd>, ready: mpsc::Sender<Result<()>>) {
    let state = match init(&manifest) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let (client, params, compiled) = state;
    let dims = manifest.dims;
    let _ = &client; // keep alive for the executables' lifetime

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Prefill { tokens, lengths, reply } => {
                let _ = reply.send(run_prefill(&dims, &params, &compiled, tokens, lengths));
            }
            Cmd::Decode { token, pos, kv, reply } => {
                let _ = reply.send(run_decode(&dims, &params, &compiled, token, pos, kv));
            }
            Cmd::Embed { tokens, lengths, reply } => {
                let _ = reply.send(run_embed(&dims, &params, &compiled, tokens, lengths));
            }
        }
    }
}

type InitState = (xla::PjRtClient, Vec<xla::Literal>, Vec<Compiled>);

fn init(manifest: &Manifest) -> Result<InitState> {
    let client = xla::PjRtClient::cpu()?;
    // Upload weights once, in param_spec order.
    let mut params = Vec::with_capacity(manifest.params.len());
    for p in &manifest.params {
        let slice = &manifest.weights[p.offset..p.offset + p.len];
        let lit = xla::Literal::vec1(slice).reshape(&p.shape)?;
        params.push(lit);
    }
    // Compile every entry (HLO text interchange — see aot.py docstring).
    let mut compiled = Vec::new();
    for e in &manifest.entries {
        let path = manifest.dir.join(&e.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        compiled.push(Compiled { exe, batch: e.batch(), phase: e.phase().to_string() });
    }
    Ok((client, params, compiled))
}

fn pick<'a>(compiled: &'a [Compiled], phase: &str, n: usize) -> Result<&'a Compiled> {
    compiled
        .iter()
        .filter(|c| c.phase == phase && c.batch >= n)
        .min_by_key(|c| c.batch)
        .ok_or_else(|| Error::Runtime(format!("no compiled `{phase}` variant for batch {n}")))
}

/// Execute with weights + data args, unwrap the 1-tuple-of-N output.
fn exec(
    params: &[xla::Literal],
    exe: &xla::PjRtLoadedExecutable,
    data: Vec<xla::Literal>,
) -> Result<Vec<xla::Literal>> {
    let mut args: Vec<&xla::Literal> = params.iter().collect();
    args.extend(data.iter());
    let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
    Ok(result.to_tuple()?)
}

fn run_prefill(
    dims: &ModelDims,
    params: &[xla::Literal],
    compiled: &[Compiled],
    mut tokens: Vec<Vec<i32>>,
    mut lengths: Vec<i32>,
) -> Result<PrefillOut> {
    let n = tokens.len();
    let c = pick(compiled, "prefill", n)?;
    // pad batch with dummy single-BOS rows
    while tokens.len() < c.batch {
        let mut row = vec![dims.pad; dims.max_seq];
        row[0] = dims.bos;
        tokens.push(row);
        lengths.push(1);
    }
    let flat: Vec<i32> = tokens.concat();
    let tok_lit = xla::Literal::vec1(&flat).reshape(&[c.batch as i64, dims.max_seq as i64])?;
    let len_lit = xla::Literal::vec1(&lengths);
    let out = exec(params, &c.exe, vec![tok_lit, len_lit])?;
    let logits_flat = out[0].to_vec::<f32>()?;
    let kv_flat = out[1].to_vec::<f32>()?;
    let logits = logits_flat
        .chunks(dims.vocab)
        .take(n)
        .map(|c| c.to_vec())
        .collect();
    Ok(PrefillOut { logits, kv: KvBatch { data: kv_flat, batch: c.batch } })
}

fn run_decode(
    dims: &ModelDims,
    params: &[xla::Literal],
    compiled: &[Compiled],
    mut token: Vec<i32>,
    mut pos: Vec<i32>,
    kv: KvBatch,
) -> Result<DecodeOut> {
    let n = token.len();
    let c = pick(compiled, "decode", n)?;
    let mut kv = kv;
    if kv.batch != c.batch {
        // re-pack into the compiled batch size
        let mut bigger = KvBatch::zeros(dims, c.batch);
        for slot in 0..kv.batch.min(c.batch) {
            let seq = kv.gather(dims, slot, 0);
            bigger.scatter(dims, slot, &seq);
        }
        kv = bigger;
    }
    while token.len() < c.batch {
        token.push(dims.pad);
        pos.push(0);
    }
    let kv_dims = [
        dims.n_layers as i64,
        2,
        c.batch as i64,
        dims.n_heads as i64,
        dims.max_seq as i64,
        dims.head_dim as i64,
    ];
    let tok_lit = xla::Literal::vec1(&token);
    let pos_lit = xla::Literal::vec1(&pos);
    let kv_lit = xla::Literal::vec1(&kv.data).reshape(&kv_dims)?;
    let out = exec(params, &c.exe, vec![tok_lit, pos_lit, kv_lit])?;
    let logits_flat = out[0].to_vec::<f32>()?;
    let kv_flat = out[1].to_vec::<f32>()?;
    let logits = logits_flat
        .chunks(dims.vocab)
        .take(n)
        .map(|c| c.to_vec())
        .collect();
    Ok(DecodeOut { logits, kv: KvBatch { data: kv_flat, batch: c.batch } })
}

fn run_embed(
    dims: &ModelDims,
    params: &[xla::Literal],
    compiled: &[Compiled],
    mut tokens: Vec<Vec<i32>>,
    mut lengths: Vec<i32>,
) -> Result<Vec<Vec<f32>>> {
    let n = tokens.len();
    let c = pick(compiled, "embed", n)?;
    while tokens.len() < c.batch {
        let mut row = vec![dims.pad; dims.max_seq];
        row[0] = dims.bos;
        tokens.push(row);
        lengths.push(1);
    }
    let flat: Vec<i32> = tokens.concat();
    let tok_lit = xla::Literal::vec1(&flat).reshape(&[c.batch as i64, dims.max_seq as i64])?;
    let len_lit = xla::Literal::vec1(&lengths);
    let out = exec(params, &c.exe, vec![tok_lit, len_lit])?;
    let flat = out[0].to_vec::<f32>()?;
    Ok(flat.chunks(dims.d_model).take(n).map(|c| c.to_vec()).collect())
}
