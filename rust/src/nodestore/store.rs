//! The sharded KV + pub/sub store backing one emulated node.

use std::any::Any;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};

/// Type-erased stored value. Control-plane structs are stored as-is (no
/// serialization on the telemetry path).
pub type StoreValue = Arc<dyn Any + Send + Sync>;

const SHARDS: usize = 16;

#[derive(Clone)]
struct Entry {
    value: StoreValue,
    version: u64,
}

struct Shard {
    map: RwLock<HashMap<String, Entry>>,
}

/// A live prefix subscription; receives `(key, value)` for every put whose
/// key starts with the subscribed prefix.
pub struct Subscription {
    pub rx: mpsc::Receiver<(String, StoreValue)>,
}

impl Subscription {
    /// Drain everything currently delivered.
    pub fn drain(&self) -> Vec<(String, StoreValue)> {
        let mut out = Vec::new();
        while let Ok(item) = self.rx.try_recv() {
            out.push(item);
        }
        out
    }
}

struct Subscriber {
    prefix: String,
    tx: mpsc::Sender<(String, StoreValue)>,
}

/// See module docs ([`crate::nodestore`]).
pub struct NodeStore {
    shards: Vec<Shard>,
    subscribers: Mutex<Vec<Subscriber>>,
    version: std::sync::atomic::AtomicU64,
}

impl Default for NodeStore {
    fn default() -> Self {
        Self::new()
    }
}

impl NodeStore {
    pub fn new() -> Self {
        NodeStore {
            shards: (0..SHARDS)
                .map(|_| Shard { map: RwLock::new(HashMap::new()) })
                .collect(),
            subscribers: Mutex::new(Vec::new()),
            version: std::sync::atomic::AtomicU64::new(1),
        }
    }

    fn shard(&self, key: &str) -> &Shard {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % SHARDS]
    }

    /// Insert/replace `key`; bumps the key version and notifies prefix
    /// subscribers. Accepts any `'static` value.
    pub fn put<V: Any + Send + Sync>(&self, key: &str, value: V) {
        self.put_arc(key, Arc::new(value))
    }

    pub fn put_arc(&self, key: &str, value: StoreValue) {
        let version = self
            .version
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        {
            let mut map = self.shard(key).map.write().unwrap();
            map.insert(key.to_string(), Entry { value: value.clone(), version });
        }
        let mut subs = self.subscribers.lock().unwrap();
        subs.retain(|s| {
            if key.starts_with(&s.prefix) {
                s.tx.send((key.to_string(), value.clone())).is_ok()
            } else {
                true
            }
        });
    }

    /// Typed read; `None` if absent or a different type is stored.
    pub fn get<V: Any + Send + Sync>(&self, key: &str) -> Option<Arc<V>> {
        let map = self.shard(key).map.read().unwrap();
        map.get(key)?.value.clone().downcast::<V>().ok()
    }

    /// Read with the key's version (for optimistic re-checks).
    pub fn get_versioned<V: Any + Send + Sync>(&self, key: &str) -> Option<(Arc<V>, u64)> {
        let map = self.shard(key).map.read().unwrap();
        let e = map.get(key)?;
        Some((e.value.clone().downcast::<V>().ok()?, e.version))
    }

    pub fn remove(&self, key: &str) -> bool {
        self.shard(key).map.write().unwrap().remove(key).is_some()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.shard(key).map.read().unwrap().contains_key(key)
    }

    /// All `(key, value)` pairs under a prefix, typed; silently skips
    /// entries of other types. This is the global controller's aggregation
    /// primitive (e.g. `scan::<InstanceMetrics>("metrics/")`).
    pub fn scan<V: Any + Send + Sync>(&self, prefix: &str) -> Vec<(String, Arc<V>)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.map.read().unwrap();
            for (k, e) in map.iter() {
                if k.starts_with(prefix) {
                    if let Ok(v) = e.value.clone().downcast::<V>() {
                        out.push((k.clone(), v));
                    }
                }
            }
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.map.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Subscribe to every put under `prefix`. Component controllers use
    /// this to consume policy updates asynchronously (paper §4.1: "without
    /// placing the global controller on the critical path").
    pub fn subscribe(&self, prefix: &str) -> Subscription {
        let (tx, rx) = mpsc::channel();
        self.subscribers
            .lock()
            .unwrap()
            .push(Subscriber { prefix: prefix.to_string(), tx });
        Subscription { rx }
    }

    /// Atomic read-modify-write on one key (the store's "transactional
    /// support" in the prototype's Redis terms).
    pub fn update<V, F>(&self, key: &str, default: V, f: F)
    where
        V: Any + Send + Sync + Clone,
        F: FnOnce(&mut V),
    {
        let version = self
            .version
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut map = self.shard(key).map.write().unwrap();
        let entry = map.entry(key.to_string()).or_insert_with(|| Entry {
            value: Arc::new(default),
            version,
        });
        let mut current: V = entry
            .value
            .clone()
            .downcast::<V>()
            .map(|a| (*a).clone())
            .unwrap_or_else(|_| panic!("update: type mismatch at {key}"));
        f(&mut current);
        entry.value = Arc::new(current);
        entry.version = version;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_typed() {
        let s = NodeStore::new();
        s.put("a", 42u32);
        assert_eq!(s.get::<u32>("a"), Some(Arc::new(42)));
        assert!(s.get::<u64>("a").is_none(), "wrong type must not downcast");
        assert!(s.get::<u32>("b").is_none());
    }

    #[test]
    fn versions_increase() {
        let s = NodeStore::new();
        s.put("k", 1u8);
        let (_, v1) = s.get_versioned::<u8>("k").unwrap();
        s.put("k", 2u8);
        let (val, v2) = s.get_versioned::<u8>("k").unwrap();
        assert_eq!(*val, 2);
        assert!(v2 > v1);
    }

    #[test]
    fn scan_prefix_typed() {
        let s = NodeStore::new();
        s.put("metrics/a:0", 1u64);
        s.put("metrics/a:1", 2u64);
        s.put("policy/a:0", 9u64);
        s.put("metrics/other", "str");
        let mut got = s.scan::<u64>("metrics/");
        got.sort_by(|a, b| a.0.cmp(&b.0));
        assert_eq!(got.len(), 2);
        assert_eq!(*got[0].1, 1);
    }

    #[test]
    fn pubsub_prefix() {
        let s = NodeStore::new();
        let sub = s.subscribe("policy/");
        s.put("policy/dev:0", 7u64);
        s.put("metrics/dev:0", 8u64); // not delivered
        let (k, v) = sub.rx.recv().unwrap();
        assert_eq!(k, "policy/dev:0");
        assert_eq!(*v.downcast::<u64>().unwrap(), 7);
        assert!(sub.rx.try_recv().is_err());
    }

    #[test]
    fn subscription_drain() {
        let s = NodeStore::new();
        let sub = s.subscribe("p/");
        s.put("p/1", 1u64);
        s.put("p/2", 2u64);
        assert_eq!(sub.drain().len(), 2);
        assert_eq!(sub.drain().len(), 0);
    }

    #[test]
    fn update_rmw() {
        let s = NodeStore::new();
        s.update("cnt", 0u64, |v| *v += 1);
        s.update("cnt", 0u64, |v| *v += 1);
        assert_eq!(*s.get::<u64>("cnt").unwrap(), 2);
    }

    #[test]
    fn remove_contains() {
        let s = NodeStore::new();
        s.put("x", 1i32);
        assert!(s.contains("x"));
        assert!(s.remove("x"));
        assert!(!s.contains("x"));
        assert!(!s.remove("x"));
    }

    #[test]
    fn concurrent_puts() {
        let s = Arc::new(NodeStore::new());
        let mut handles = vec![];
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    s.put(&format!("k{}/{}", t, i), i as u64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 8000);
    }
}
