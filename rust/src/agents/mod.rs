//! Agent/tool execution backends + the stub layer (paper §3.1).
//!
//! In the paper, developers write agents as ordinary Python classes and a
//! stub-generation tool turns each declared callable into an importable
//! module whose methods return futures. Here the declaration lives in the
//! deployment config ([`crate::config::AgentConfig`]); [`stub::AgentStub`]
//! is the generated-stub analog (method call -> future), and this module
//! provides what executes *behind* the stub:
//!
//! * [`Backend`] — what a component controller drives: an LLM engine core
//!   (batched, continuous) or a serial tool executor.
//! * Tool executors: documentation lookup over the vector store, a web
//!   search with canned results, and a test harness with a configurable
//!   failure rate (the SWE workflow's retry driver).

pub mod stub;

pub use stub::{AgentStub, CallCtx};

use std::sync::Arc;
use std::time::Duration;

use crate::config::{AgentConfig, AgentKind, LatencyProfile};
use crate::engine::EngineCore;
use crate::error::{Error, Result};
use crate::futures::Value;
use crate::json;
use crate::util::rng::Rng;
use crate::vectorstore::{HashEmbedder, VectorStore};

/// What a component controller executes.
pub enum Backend {
    /// LLM agent: continuous-batching engine core.
    Engine(Box<dyn EngineCore>),
    /// Tool: serial request/response executor.
    Tool(Box<dyn ToolExec>),
}

/// A serial tool executor. `execute` blocks for the tool's (scaled)
/// service time and returns the result value.
pub trait ToolExec: Send {
    fn execute(&mut self, method: &str, args: &Value) -> Result<Value>;
}

fn scaled_sleep(profile: &LatencyProfile, time_scale: f64, extra_s: f64) {
    let d = Duration::from_secs_f64(((profile.base_s + extra_s) * time_scale).max(0.0));
    if !d.is_zero() {
        std::thread::sleep(d);
    }
}

// ------------------------------------------------------------------ tools

/// Documentation lookup over the vector store (ChromaDB substitute) —
/// paper Fig. 1 step 3.
pub struct VectorStoreTool {
    pub store: Arc<VectorStore>,
    pub embedder: HashEmbedder,
    pub profile: LatencyProfile,
    pub time_scale: f64,
}

impl ToolExec for VectorStoreTool {
    fn execute(&mut self, method: &str, args: &Value) -> Result<Value> {
        match method {
            "get" | "query" => {
                let query = args.get("query").as_str().unwrap_or_default();
                let k = args.get("k").as_usize().unwrap_or(3);
                scaled_sleep(&self.profile, self.time_scale, 0.0);
                let hits = self.store.query(&self.embedder.embed(query), k);
                Ok(Value::Arr(
                    hits.into_iter()
                        .map(|h| json!({"id": h.id, "score": h.score as f64, "text": h.text}))
                        .collect(),
                ))
            }
            "add" => {
                let text = args.get("text").as_str().unwrap_or_default().to_string();
                let id = self.store.add(text.clone(), self.embedder.embed(&text));
                Ok(json!({"id": id}))
            }
            other => Err(Error::UnknownAgent(format!("vector_store.{other}"))),
        }
    }
}

/// Web-search API simulation (paper Fig. 1 step 4): canned, deterministic
/// results with external-API latency.
pub struct WebSearchTool {
    pub profile: LatencyProfile,
    pub time_scale: f64,
    pub rng: Rng,
}

impl ToolExec for WebSearchTool {
    fn execute(&mut self, method: &str, args: &Value) -> Result<Value> {
        if method != "search" {
            return Err(Error::UnknownAgent(format!("web_search.{method}")));
        }
        let query = args.get("query").as_str().unwrap_or_default();
        // external APIs have heavy-tailed latency
        let extra = self.rng.lognormal_mean(self.profile.base_s.max(0.05), 0.8);
        scaled_sleep(&self.profile, self.time_scale, extra);
        let n = 2 + (query.len() % 3);
        Ok(Value::Arr(
            (0..n)
                .map(|i| {
                    json!({
                        "title": format!("result {i} for `{query}`"),
                        "snippet": format!("snippet {i}: {query} ...")
                    })
                })
                .collect(),
        ))
    }
}

/// Test-harness tool (paper Fig. 1 steps 5-8): runs "tests" with a
/// configured failure probability — the source of SWE-workflow retries.
pub struct TestHarnessTool {
    pub profile: LatencyProfile,
    pub time_scale: f64,
    pub failure_rate: f64,
    pub rng: Rng,
}

impl ToolExec for TestHarnessTool {
    fn execute(&mut self, method: &str, args: &Value) -> Result<Value> {
        if method != "unit_test" && method != "integration_test" {
            return Err(Error::UnknownAgent(format!("test_harness.{method}")));
        }
        let code = args.get("code").as_str().unwrap_or_default();
        scaled_sleep(&self.profile, self.time_scale, 0.001 * code.len() as f64);
        // retry_count lowers the failure odds: later attempts carry more
        // accumulated context (docs, traces) — mirrors the corrective loop.
        let attempt = args.get("attempt").as_u64().unwrap_or(0);
        let p = self.failure_rate / (1.0 + attempt as f64);
        let pass = !self.rng.bool_with(p);
        Ok(json!({
            "result": if pass { "Pass" } else { "Fail" },
            "tests_run": 1 + code.len() % 7,
        }))
    }
}

/// Instantiate the backend for an agent declaration.
pub struct BackendFactory {
    pub time_scale: f64,
    pub vector_store: Arc<VectorStore>,
    pub seed: u64,
}

impl BackendFactory {
    pub fn build(
        &self,
        cfg: &AgentConfig,
        instance_index: u32,
        engine: impl FnOnce() -> Box<dyn EngineCore>,
    ) -> Backend {
        let seed = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(instance_index as u64);
        match cfg.kind {
            AgentKind::Llm => Backend::Engine(engine()),
            AgentKind::VectorStore => Backend::Tool(Box::new(VectorStoreTool {
                store: self.vector_store.clone(),
                embedder: HashEmbedder::new(self.vector_store.dim()),
                profile: cfg.profile.clone(),
                time_scale: self.time_scale,
            })),
            AgentKind::WebSearch => Backend::Tool(Box::new(WebSearchTool {
                profile: cfg.profile.clone(),
                time_scale: self.time_scale,
                rng: Rng::new(seed),
            })),
            AgentKind::TestHarness => Backend::Tool(Box::new(TestHarnessTool {
                profile: cfg.profile.clone(),
                time_scale: self.time_scale,
                failure_rate: cfg.failure_rate,
                rng: Rng::new(seed),
            })),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_profile() -> LatencyProfile {
        LatencyProfile { base_s: 0.0, ..Default::default() }
    }

    #[test]
    fn vector_store_tool_query() {
        let store = Arc::new(VectorStore::new(64));
        let e = HashEmbedder::new(64);
        store.add("oauth docs", e.embed("oauth docs"));
        store.add("db docs", e.embed("db docs"));
        let mut tool = VectorStoreTool {
            store,
            embedder: e,
            profile: fast_profile(),
            time_scale: 0.0,
        };
        let out = tool
            .execute("get", &json!({"query": "oauth", "k": 1}))
            .unwrap();
        assert_eq!(out.as_arr().unwrap().len(), 1);
        assert!(out.idx(0).get("text").as_str().unwrap().contains("oauth"));
        assert!(tool.execute("nope", &json!({})).is_err());
    }

    #[test]
    fn web_search_returns_results() {
        let mut tool = WebSearchTool {
            profile: fast_profile(),
            time_scale: 0.0,
            rng: Rng::new(1),
        };
        let out = tool.execute("search", &json!({"query": "rates"})).unwrap();
        assert!(out.as_arr().unwrap().len() >= 2);
    }

    #[test]
    fn test_harness_fails_at_configured_rate() {
        let mut tool = TestHarnessTool {
            profile: fast_profile(),
            time_scale: 0.0,
            failure_rate: 0.5,
            rng: Rng::new(2),
        };
        let mut fails = 0;
        for _ in 0..200 {
            let out = tool
                .execute("unit_test", &json!({"code": "fn x() {}", "attempt": 0}))
                .unwrap();
            if out.get("result").as_str() == Some("Fail") {
                fails += 1;
            }
        }
        assert!((60..140).contains(&fails), "fail rate off: {fails}/200");
    }

    #[test]
    fn retries_fail_less() {
        let count_fails = |attempt: u64| {
            let mut tool = TestHarnessTool {
                profile: fast_profile(),
                time_scale: 0.0,
                failure_rate: 0.6,
                rng: Rng::new(3),
            };
            (0..300)
                .filter(|_| {
                    tool.execute("unit_test", &json!({"code": "x", "attempt": attempt}))
                        .unwrap()
                        .get("result")
                        .as_str()
                        == Some("Fail")
                })
                .count()
        };
        assert!(count_fails(3) < count_fails(0));
    }
}
