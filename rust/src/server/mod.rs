//! Deployment: assemble and run a NALAR cluster (paper Fig. 2 "At
//! deployment, NALAR launches and manages the runtime").
//!
//! `Deployment::launch` builds the emulated cluster from a
//! [`DeploymentConfig`]: node stores, bus, router, future table/graph,
//! agent instances with their component controllers (round-robin placed
//! across nodes), and the global controller with the configured policies.
//! Workflow drivers get a [`CallCtx`] per request and run on caller
//! threads; `kill`/`provision` lifecycle hooks route back here.

pub mod http;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::agents::{Backend, BackendFactory, CallCtx};
use crate::baselines::SystemUnderTest;
use crate::config::{AgentConfig, DeploymentConfig};
use crate::coordinator::{
    make_policy, ComponentController, GlobalController, InstanceHandle, LoadMap, Policy, Router,
};
use crate::engine::{EngineCore, PjrtCore, SimCore};
use crate::error::{Error, Result};
use crate::futures::{DepGraph, FutureTable};
use crate::ids::{IdGen, InstanceId, NodeId, RequestId, SessionId};
use crate::ingress::routing::SharedRoute;
use crate::metrics::LatencyRecorder;
use crate::nodestore::StoreDirectory;
use crate::runtime::PjrtModel;
use crate::state::kvcache::{KvCacheManager, KvPolicy};
use crate::trace::SharedSink;
use crate::transport::Bus;
use crate::vectorstore::VectorStore;

/// A running NALAR cluster. Handles are cheap clones over shared state;
/// `shutdown` consumes one handle but stops the cluster for all of them
/// (the ingress driver pool holds its own handle).
#[derive(Clone)]
pub struct Deployment {
    inner: Arc<Inner>,
}

struct Inner {
    cfg: Arc<DeploymentConfig>,
    bus: Bus,
    stores: StoreDirectory,
    loads: LoadMap,
    router: Arc<Router>,
    graph: Arc<DepGraph>,
    table: Arc<FutureTable>,
    ids: Arc<IdGen>,
    vector_store: Arc<VectorStore>,
    pjrt: Mutex<Option<PjrtModel>>,
    instances: Mutex<Vec<InstanceHandle>>,
    next_index: Mutex<HashMap<String, u32>>,
    next_node: AtomicU32,
    global: Mutex<Option<Arc<GlobalController>>>,
    global_stop: Arc<AtomicBool>,
    global_join: Mutex<Option<std::thread::JoinHandle<()>>>,
    pub latency: LatencyRecorder,
    /// Late-bound flight-recorder slot: component controllers hold a
    /// clone from spawn time, and the ingress scheduler installs the
    /// actual recorder when it starts — so engine dispatch/complete
    /// events land on the same per-request timelines the scheduler
    /// writes (a disabled no-op sink until then).
    trace: SharedSink,
    /// Late-bound JIT-routing slot (same pattern as `trace`): the ingress
    /// installs a [`crate::ingress::routing::RouteState`] here when the
    /// config declares model variants and a non-`fixed` route. Component
    /// controllers and the global controller hold clones from spawn time.
    route: SharedRoute,
}

impl Deployment {
    /// Launch in NALAR mode.
    pub fn launch(cfg: DeploymentConfig) -> Result<Deployment> {
        Self::launch_as(cfg, SystemUnderTest::Nalar)
    }

    /// Launch emulating a given system (NALAR or a baseline, §6.1).
    pub fn launch_as(mut cfg: DeploymentConfig, system: SystemUnderTest) -> Result<Deployment> {
        system.apply(&mut cfg);
        cfg.validate()?;
        let nodes: Vec<NodeId> = (0..cfg.nodes).map(NodeId).collect();
        let bus = Bus::new(Duration::from_micros(cfg.cross_node_latency_us));
        let stores = StoreDirectory::new(&nodes);
        let loads = LoadMap::new();
        let router = Arc::new(Router::new(bus.clone(), loads.clone(), cfg.seed ^ 0xB0B0));
        let (sticky, fallback) = system.router_mode();
        router.force_sticky.store(sticky, Ordering::Relaxed);
        router.set_fallback(fallback);

        let inner = Arc::new(Inner {
            cfg: Arc::new(cfg),
            bus,
            stores,
            loads,
            router,
            graph: Arc::new(DepGraph::new()),
            table: Arc::new(FutureTable::new()),
            ids: Arc::new(IdGen::new()),
            vector_store: Arc::new(VectorStore::new(64)),
            pjrt: Mutex::new(None),
            instances: Mutex::new(Vec::new()),
            next_index: Mutex::new(HashMap::new()),
            next_node: AtomicU32::new(0),
            global: Mutex::new(None),
            global_stop: Arc::new(AtomicBool::new(false)),
            global_join: Mutex::new(None),
            latency: LatencyRecorder::new(),
            trace: SharedSink::new(),
            route: SharedRoute::default(),
        });

        let d = Deployment { inner };
        // initial instances
        for a in d.inner.cfg.agents.clone() {
            for _ in 0..a.instances {
                d.spawn_instance(&a.name)?;
            }
        }
        d.start_global()?;
        Ok(d)
    }

    fn start_global(&self) -> Result<()> {
        let cfg = &self.inner.cfg;
        let mut policies: Vec<Box<dyn Policy>> = Vec::new();
        for name in &cfg.policies {
            policies.push(
                make_policy(name)
                    .ok_or_else(|| Error::Config(format!("unknown policy `{name}`")))?,
            );
        }
        let weak = Arc::downgrade(&self.inner);
        let provision = Arc::new(move |agent: &str| -> Option<InstanceId> {
            let inner = weak.upgrade()?;
            Deployment { inner }.spawn_instance(agent).ok()
        });
        let global = GlobalController::new(
            self.inner.bus.clone(),
            self.inner.stores.clone(),
            self.inner.router.clone(),
            self.inner.loads.clone(),
            self.inner.table.clone(),
            policies,
            provision,
        );
        global.set_route_slot(self.inner.route.clone());
        *self.inner.global.lock().unwrap() = Some(global.clone());
        let period = Duration::from_millis(cfg.control.global_period_ms);
        let stop = self.inner.global_stop.clone();
        let join = std::thread::Builder::new()
            .name("nalar-global".into())
            .spawn(move || global.run(period, stop))
            .map_err(|e| Error::Msg(e.to_string()))?;
        *self.inner.global_join.lock().unwrap() = Some(join);
        Ok(())
    }

    /// The `provision` primitive: launch one more instance of `agent`,
    /// honoring `max_instances`. Round-robin node placement.
    pub fn spawn_instance(&self, agent: &str) -> Result<InstanceId> {
        let acfg: AgentConfig = self
            .inner
            .cfg
            .agent(agent)
            .ok_or_else(|| Error::UnknownAgent(agent.into()))?
            .clone();
        let live = self.inner.bus.instances_of(agent).len() as u32;
        if live >= acfg.directives.max_instances {
            return Err(Error::Config(format!(
                "{agent}: max_instances {} reached",
                acfg.directives.max_instances
            )));
        }
        let index = {
            let mut m = self.inner.next_index.lock().unwrap();
            let e = m.entry(agent.to_string()).or_insert(0);
            let i = *e;
            *e += 1;
            i
        };
        let id = InstanceId::new(agent, index);
        let node =
            NodeId(self.inner.next_node.fetch_add(1, Ordering::Relaxed) % self.inner.cfg.nodes);

        let factory = BackendFactory {
            time_scale: self.inner.cfg.time_scale,
            vector_store: self.inner.vector_store.clone(),
            seed: self.inner.cfg.seed ^ ((index as u64) << 8),
        };
        let inner = &self.inner;
        let engine_builder = || -> Box<dyn EngineCore> {
            let ecfg = &inner.cfg.engine;
            let policy = if ecfg.kv_policy == "lru" { KvPolicy::Lru } else { KvPolicy::HintDriven };
            let kv = Arc::new(KvCacheManager::new(ecfg.kv_hbm_bytes, ecfg.kv_dram_bytes, policy));
            if ecfg.executor == "pjrt" {
                let mut guard = inner.pjrt.lock().unwrap();
                if guard.is_none() {
                    *guard = Some(
                        PjrtModel::load(&ecfg.artifacts_dir)
                            .expect("pjrt executor requested but artifacts failed to load"),
                    );
                }
                Box::new(PjrtCore::new(guard.clone().unwrap(), kv))
            } else {
                Box::new(SimCore::new(
                    acfg.profile.clone(),
                    inner.cfg.time_scale,
                    inner.cfg.engine.max_batch,
                    kv,
                    inner.cfg.seed ^ 0x5eed ^ index as u64,
                ))
            }
        };
        let backend: Backend = factory.build(&acfg, index, engine_builder);

        let handle = ComponentController::spawn(
            id.clone(),
            node,
            backend,
            acfg.directives.clone(),
            self.inner.bus.clone(),
            self.inner.stores.clone(),
            self.inner.router.clone(),
            &self.inner.loads,
            self.inner.graph.clone(),
            self.inner.trace.clone(),
            self.inner.route.clone(),
        );
        self.inner.instances.lock().unwrap().push(handle);
        Ok(id)
    }

    /// New user session.
    pub fn new_session(&self) -> SessionId {
        self.inner.ids.session()
    }

    /// Mint a request id without building a context yet. The ingress front
    /// door assigns ids at admission so a request is traceable from the
    /// moment it is accepted, before any driver picks it up.
    pub fn new_request_id(&self) -> RequestId {
        self.inner.ids.request()
    }

    /// Journal-replay hook: advance the id generators past the highest
    /// session/request/future ids observed in a recovered journal, so
    /// fresh ids minted after recovery never collide with replayed ones
    /// (see [`crate::journal::RecoveryPlan`]).
    pub fn advance_ids(&self, session: u64, request: u64, future: u64) {
        self.inner.ids.advance_past(session, request, future);
    }

    /// New request context for a workflow driver.
    pub fn ctx(&self, session: SessionId) -> CallCtx {
        let request: RequestId = self.inner.ids.request();
        self.ctx_with(session, request)
    }

    /// Context for an already-assigned request id (ingress-dispatched
    /// requests keep the id the front door stamped at admission).
    pub fn ctx_with(&self, session: SessionId, request: RequestId) -> CallCtx {
        CallCtx {
            session,
            request,
            stage: 0,
            bus: self.inner.bus.clone(),
            router: self.inner.router.clone(),
            graph: self.inner.graph.clone(),
            table: self.inner.table.clone(),
            ids: self.inner.ids.clone(),
            cfg: self.inner.cfg.clone(),
            route: None,
        }
    }

    // ------------------------------------------------------------ access
    pub fn cfg(&self) -> &DeploymentConfig {
        &self.inner.cfg
    }
    pub fn bus(&self) -> &Bus {
        &self.inner.bus
    }
    pub fn stores(&self) -> &StoreDirectory {
        &self.inner.stores
    }
    pub fn router(&self) -> &Arc<Router> {
        &self.inner.router
    }
    pub fn table(&self) -> &Arc<FutureTable> {
        &self.inner.table
    }
    pub fn graph(&self) -> &Arc<DepGraph> {
        &self.inner.graph
    }
    pub fn vector_store(&self) -> &Arc<VectorStore> {
        &self.inner.vector_store
    }
    pub fn latency(&self) -> &LatencyRecorder {
        &self.inner.latency
    }
    pub fn global(&self) -> Arc<GlobalController> {
        self.inner.global.lock().unwrap().clone().expect("global running")
    }
    pub fn loads(&self) -> &LoadMap {
        &self.inner.loads
    }
    /// The shared flight-recorder slot ([`SharedSink`]): the ingress
    /// scheduler installs its recorder here at start, component
    /// controllers read through it per event.
    pub fn trace_slot(&self) -> &SharedSink {
        &self.inner.trace
    }
    /// The shared JIT-routing slot ([`SharedRoute`]): the ingress installs
    /// the deployment's router here at start when the config asks for one;
    /// component controllers enforce through it per engine admit.
    pub fn route_slot(&self) -> &SharedRoute {
        &self.inner.route
    }

    /// Snapshot of the deployment-lifetime latency recorder in
    /// paper-equivalent seconds (`nalar bench` / operator dashboards).
    /// The open-loop harness records every request it drives in here.
    pub fn latency_paper_summary(&self) -> crate::metrics::LatencySummary {
        let paper_scale = 1.0 / self.inner.cfg.time_scale;
        self.inner.latency.summary_scaled(paper_scale)
    }

    /// Snapshot of the global controller's per-tick timing breakdown
    /// (collect/policy/apply — the Fig-10 metric) since launch.
    pub fn control_timings(&self) -> Vec<crate::coordinator::global::LoopTiming> {
        self.global().timings_snapshot()
    }

    /// Per-instance busy fractions (load-imbalance metric, §6.1).
    pub fn busy_fractions(&self, agent: &str) -> Vec<f64> {
        self.global()
            .collect()
            .instances_of(agent)
            .map(|i| i.m.busy_ewma)
            .collect()
    }

    /// Shut everything down (global first, then instances).
    pub fn shutdown(self) {
        self.inner.global_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.inner.global_join.lock().unwrap().take() {
            let _ = j.join();
        }
        let handles: Vec<InstanceHandle> =
            std::mem::take(&mut *self.inner.instances.lock().unwrap());
        for h in handles {
            h.stop();
        }
    }
}
