//! Table 4 reproduction: one-level vs two-level control.
//!
//! Measures the time to schedule one token/future when (a) a single
//! centralized global controller routes *every* future through its one
//! decision queue — a new arrival waits behind all pending work — versus
//! (b) NALAR's two-level design, where component-level controllers route
//! independently under installed policies and a new future's scheduling
//! latency is one local decision.
//!
//! Paper: one-level 1.2ms@1K -> 72.3ms@131K; two-level flat 0.1-0.4ms.

use std::sync::Arc;
use std::time::{Duration, Instant};

use nalar::coordinator::{LoadMap, Router};
use nalar::ids::*;
use nalar::transport::Bus;
use nalar::util::bench::Table;

const AGENTS: u32 = 128;
const LOCAL_CONTROLLERS: usize = 128;

fn mk_router() -> (Bus, Arc<Router>) {
    let bus = Bus::new(Duration::ZERO);
    let loads = LoadMap::new();
    for a in 0..AGENTS {
        let id = InstanceId::new("agent", a);
        let _rx = Box::leak(Box::new(bus.register(id.clone(), NodeId(a % 64))));
        loads.register(id);
    }
    (bus.clone(), Arc::new(Router::new(bus, loads, 9)))
}

/// One-level: all pending futures drain through one decision loop; a probe
/// future submitted at the back observes the queueing delay.
fn one_level(pending: usize, router: &Router) -> Duration {
    let t0 = Instant::now();
    for i in 0..pending {
        let _ = router.route(SessionId(i as u64), "agent", false);
    }
    // the probe token: scheduled only after everything ahead of it
    let _ = router.route(SessionId(pending as u64), "agent", false);
    t0.elapsed()
}

/// Two-level: the same pending work is split across component-level
/// controllers running concurrently; the probe only waits for its local
/// controller's share of one queue position.
fn two_level(pending: usize, router: &Arc<Router>) -> Duration {
    let per = pending / LOCAL_CONTROLLERS;
    std::thread::scope(|scope| {
        for c in 0..LOCAL_CONTROLLERS {
            let router = router.clone();
            scope.spawn(move || {
                for i in 0..per {
                    let _ = router.route(SessionId((c * per + i) as u64), "agent", false);
                }
            });
        }
        // probe routes locally, concurrent with the fleet
        let t0 = Instant::now();
        let _ = router.route(SessionId(u64::MAX), "agent", false);
        t0.elapsed()
    })
}

fn main() {
    println!("=== Table 4 — per-token scheduling: one-level vs two-level ===");
    let mut table = Table::new(&["futures", "one-level(ms)", "two-level(ms)", "ratio"]);
    for futures in [1024usize, 2048, 4096, 8192, 16384, 32768, 65536, 131072] {
        let (_b1, r1) = mk_router();
        let one = one_level(futures, &r1);
        let (_b2, r2) = mk_router();
        // median of 3 for the (tiny) two-level number
        let mut twos: Vec<Duration> = (0..3).map(|_| two_level(futures, &r2)).collect();
        twos.sort();
        let two = twos[1];
        table.row(&[
            futures.to_string(),
            format!("{:.2}", one.as_secs_f64() * 1e3),
            format!("{:.3}", two.as_secs_f64() * 1e3),
            format!("{:.0}x", one.as_secs_f64() / two.as_secs_f64().max(1e-9)),
        ]);
    }
    table.print();
    println!("\npaper reference: one-level 1.2 -> 72.3 ms; two-level 0.1 -> 0.4 ms");
}
