//! KV-cache tensor packing.
//!
//! The HLO decode entries take/return the cache as one `[L, 2, B, H, S, Dh]`
//! f32 tensor. The engine keeps each *sequence's* cache separately (so
//! sessions can be retained, offloaded or migrated independently — that is
//! the whole point of NALAR's KV layer) and gathers/scatters them around
//! each batched step.

use crate::runtime::manifest::ModelDims;

/// One sequence's KV cache: `[L, 2, H, S, Dh]` flattened.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqKv {
    pub data: Vec<f32>,
    pub pos: usize,
}

impl SeqKv {
    pub fn zeros(dims: &ModelDims) -> Self {
        SeqKv { data: vec![0.0; dims.kv_floats_per_seq()], pos: 0 }
    }
}

/// A batched KV tensor in HLO layout `[L, 2, B, H, S, Dh]`.
pub struct KvBatch {
    pub data: Vec<f32>,
    pub batch: usize,
}

impl KvBatch {
    pub fn zeros(dims: &ModelDims, batch: usize) -> Self {
        KvBatch { data: vec![0.0; dims.kv_floats_per_seq() * batch], batch }
    }

    /// Floats per (layer, k/v, batch-element) block: `H * S * Dh`.
    fn block(dims: &ModelDims) -> usize {
        dims.n_heads * dims.max_seq * dims.head_dim
    }

    /// Copy sequence `seq`'s cache into batch slot `slot`.
    pub fn scatter(&mut self, dims: &ModelDims, slot: usize, seq: &SeqKv) {
        assert!(slot < self.batch);
        let block = Self::block(dims);
        let planes = dims.n_layers * 2;
        for p in 0..planes {
            let src = &seq.data[p * block..(p + 1) * block];
            let dst_off = (p * self.batch + slot) * block;
            self.data[dst_off..dst_off + block].copy_from_slice(src);
        }
    }

    /// Extract batch slot `slot` into a per-sequence cache.
    pub fn gather(&self, dims: &ModelDims, slot: usize, pos: usize) -> SeqKv {
        assert!(slot < self.batch);
        let block = Self::block(dims);
        let planes = dims.n_layers * 2;
        let mut data = vec![0.0; planes * block];
        for p in 0..planes {
            let src_off = (p * self.batch + slot) * block;
            data[p * block..(p + 1) * block]
                .copy_from_slice(&self.data[src_off..src_off + block]);
        }
        SeqKv { data, pos }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            vocab: 259,
            d_model: 64,
            n_heads: 2,
            head_dim: 4,
            n_layers: 2,
            max_seq: 8,
            bos: 256,
            eos: 257,
            pad: 258,
        }
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let d = dims();
        let mut seq = SeqKv::zeros(&d);
        for (i, x) in seq.data.iter_mut().enumerate() {
            *x = i as f32;
        }
        seq.pos = 5;
        let mut batch = KvBatch::zeros(&d, 4);
        batch.scatter(&d, 2, &seq);
        let back = batch.gather(&d, 2, 5);
        assert_eq!(back, seq);
        // other slots untouched
        let empty = batch.gather(&d, 0, 0);
        assert!(empty.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn distinct_slots_dont_alias() {
        let d = dims();
        let mut a = SeqKv::zeros(&d);
        a.data.fill(1.0);
        let mut b = SeqKv::zeros(&d);
        b.data.fill(2.0);
        let mut batch = KvBatch::zeros(&d, 2);
        batch.scatter(&d, 0, &a);
        batch.scatter(&d, 1, &b);
        assert!(batch.gather(&d, 0, 0).data.iter().all(|&x| x == 1.0));
        assert!(batch.gather(&d, 1, 0).data.iter().all(|&x| x == 2.0));
    }

    #[test]
    fn hlo_layout_interleaving() {
        // For [L,2,B,...] layout, plane p of slot s sits at (p*B + s)*block.
        let d = dims();
        let mut seq = SeqKv::zeros(&d);
        seq.data.fill(7.0);
        let mut batch = KvBatch::zeros(&d, 2);
        batch.scatter(&d, 1, &seq);
        let block = d.n_heads * d.max_seq * d.head_dim;
        // plane 0 slot 0 is zeros, plane 0 slot 1 is sevens
        assert_eq!(batch.data[0], 0.0);
        assert_eq!(batch.data[block], 7.0);
        // plane 1 slot 0 zeros again
        assert_eq!(batch.data[2 * block], 0.0);
        assert_eq!(batch.data[3 * block], 7.0);
    }

    #[test]
    #[should_panic]
    fn out_of_range_slot_panics() {
        let d = dims();
        let mut batch = KvBatch::zeros(&d, 2);
        batch.scatter(&d, 2, &SeqKv::zeros(&d));
    }
}
