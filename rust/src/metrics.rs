//! Serving metrics: latency quantiles, throughput, load imbalance.
//!
//! The evaluation (paper §6.1, Figure 9) reports average / P50 / P95 / P99
//! end-to-end latency per request rate, plus a load-imbalance factor for
//! the router and SWE workflows. `LatencyRecorder` backs those tables;
//! `summary_scaled` converts the testbed's scaled milliseconds back into
//! "paper-equivalent" seconds (see DESIGN.md §3 substitution table).

use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Value;

/// Collects latency samples and computes the Fig-9 summary row.
#[derive(Default, Debug)]
pub struct LatencyRecorder {
    samples: Mutex<Vec<f64>>, // seconds
}

/// One Fig-9 row: the summary statistics for a (workflow, rate, system) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub avg: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Stable JSON form used by the `nalar bench` reports (DESIGN.md §4):
    /// every report point carries exactly these quantile fields.
    pub fn to_json(&self) -> Value {
        crate::json!({
            "count": self.count,
            "avg": self.avg,
            "p50": self.p50,
            "p95": self.p95,
            "p99": self.p99,
            "max": self.max
        })
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, latency: Duration) {
        self.samples.lock().unwrap().push(latency.as_secs_f64());
    }

    pub fn record_secs(&self, secs: f64) {
        self.samples.lock().unwrap().push(secs);
    }

    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Summary with all values multiplied by `scale` (use `1.0 /
    /// time_scale` to report paper-equivalent seconds).
    pub fn summary_scaled(&self, scale: f64) -> LatencySummary {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return LatencySummary { count: 0, avg: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
            s[idx] * scale
        };
        LatencySummary {
            count: s.len(),
            avg: s.iter().sum::<f64>() / s.len() as f64 * scale,
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            max: s[s.len() - 1] * scale,
        }
    }

    pub fn summary(&self) -> LatencySummary {
        self.summary_scaled(1.0)
    }
}

/// Load imbalance across instances: `max(busy) / mean(busy)` (>= 1.0).
///
/// The paper reports baselines showing ">2.1x higher load-imbalance" on the
/// SWE workflow and >90% branch imbalance in the Azure traces (§6.1).
pub fn load_imbalance(busy_fractions: &[f64]) -> f64 {
    if busy_fractions.is_empty() {
        return 1.0;
    }
    let mean = busy_fractions.iter().sum::<f64>() / busy_fractions.len() as f64;
    if mean <= f64::EPSILON {
        return 1.0;
    }
    let max = busy_fractions.iter().cloned().fold(f64::MIN, f64::max);
    max / mean
}

/// Goodput: requests completed *within their deadline* per wall-clock
/// second of the measurement window (the saturation-sweep y-axis — under
/// overload, completions past the deadline no longer count).
pub fn goodput(completed_in_deadline: u64, window: Duration) -> f64 {
    if window.is_zero() {
        return 0.0;
    }
    completed_in_deadline as f64 / window.as_secs_f64()
}

/// Fraction of offered requests rejected by admission control.
pub fn shed_rate(shed: u64, offered: u64) -> f64 {
    if offered == 0 {
        0.0
    } else {
        shed as f64 / offered as f64
    }
}

/// Per-instance serving counters pushed into the node store as telemetry.
#[derive(Debug, Default, Clone, Copy)]
pub struct Counters {
    pub enqueued: u64,
    pub started: u64,
    pub completed: u64,
    pub failed: u64,
    pub migrated_in: u64,
    pub migrated_out: u64,
    pub busy_time_us: u64,
}

impl Counters {
    pub fn busy_fraction(&self, window: Duration) -> f64 {
        if window.is_zero() {
            return 0.0;
        }
        (self.busy_time_us as f64 / window.as_micros() as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_secs(i as f64);
        }
        let s = r.summary();
        assert_eq!(s.count, 100);
        assert!((s.avg - 50.5).abs() < 1e-9);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn scaled_summary() {
        let r = LatencyRecorder::new();
        r.record_secs(2.0);
        let s = r.summary_scaled(100.0);
        assert_eq!(s.avg, 200.0);
    }

    #[test]
    fn empty_summary_zeroes() {
        let r = LatencyRecorder::new();
        let s = r.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn imbalance() {
        assert_eq!(load_imbalance(&[]), 1.0);
        assert_eq!(load_imbalance(&[0.5, 0.5]), 1.0);
        assert!((load_imbalance(&[0.9, 0.1]) - 1.8).abs() < 1e-9);
        assert_eq!(load_imbalance(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn goodput_and_shed_rate() {
        assert_eq!(goodput(80, Duration::from_secs(4)), 20.0);
        assert_eq!(goodput(5, Duration::ZERO), 0.0);
        assert_eq!(shed_rate(25, 100), 0.25);
        assert_eq!(shed_rate(0, 0), 0.0);
    }

    #[test]
    fn busy_fraction_capped() {
        let c = Counters { busy_time_us: 2_000_000, ..Default::default() };
        assert_eq!(c.busy_fraction(Duration::from_secs(1)), 1.0);
    }

    #[test]
    fn summary_to_json_has_quantile_fields() {
        let r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record_secs(i as f64);
        }
        let v = r.summary().to_json();
        for key in ["count", "avg", "p50", "p95", "p99", "max"] {
            assert!(!v.get(key).is_null(), "missing `{key}`");
        }
        assert_eq!(v.get("count").as_usize(), Some(10));
        assert_eq!(v.get("max").as_f64(), Some(10.0));
    }
}
