//! End-to-end request tracing: span timelines + a bounded flight recorder.
//!
//! The counters the front door has published since PR 2 answer *how many*
//! requests were admitted / shed / completed; this module answers *where a
//! single request spent its time*. Every lifecycle transition (admitted →
//! queued → scheduled → polling → parked-on-future → resumed → terminal)
//! is recorded as a [`TraceEvent`] into a [`FlightRecorder`]: a bounded,
//! lock-sharded ring of recent events, sharded by `RequestId` exactly like
//! `futures::table::FutureTable` so two requests on different shards never
//! contend. The recorder is *behind the wire*: `GET /v1/requests/{id}/trace`
//! serves a request's timeline and `nalar trace` prints a waterfall of the
//! slowest requests (DESIGN.md §10).
//!
//! Hot-path discipline: recording one event is one shard-mutex acquisition
//! and one `VecDeque` write into pre-allocated storage — no allocation, no
//! global lock, no unbounded growth. When a shard's ring is full the oldest
//! event is overwritten and a dropped-events counter increments, so the
//! recorder degrades by forgetting history, never by growing.
//!
//! [`Ring`] is the generic bounded buffer underneath; the global
//! controller's loop-timing log reuses it (`coordinator::global`).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::ids::RequestId;
use crate::util::clock::Clock;

/// Shard count for the flight recorder (same constant and keying rule as
/// `FutureTable`: shard = `request.0 % SHARDS`). All events of one request
/// land in one shard, so a timeline read locks exactly one mutex.
pub const SHARDS: usize = 32;

/// One request-lifecycle transition. `detail` is kind-dependent: the
/// tenant index for `Queued`, the first awaited `FutureId` for `Parked`,
/// the engine-call tag for `EngineDispatch`/`EngineComplete` (with the
/// busy-time in microseconds on complete), and 0 elsewhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub request: RequestId,
    /// Shard-monotonic sequence number: strictly increasing for the
    /// events of one request (they share a shard), *not* contiguous —
    /// other requests on the same shard interleave the counter.
    pub seq: u64,
    /// Nanoseconds since the recorder's epoch, read from the injected
    /// [`Clock`] — a virtual clock makes whole timelines deterministic.
    pub clock_ns: u64,
    pub kind: TraceKind,
    pub detail: u64,
}

/// The event taxonomy (DESIGN.md §10). One request's timeline is
/// `Admitted, Queued, Scheduled, (Polling, Parked, Resumed)*, Polling,
/// terminal`, with `EngineDispatch`/`EngineComplete` overlaying the
/// parked spans from the component-controller side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Passed admission control; a `RequestId` exists from here on.
    Admitted,
    /// Entered its tenant's sub-queue (`detail` = tenant index).
    Queued,
    /// Popped from the queue by a scheduler worker (queue-wait ends).
    Scheduled,
    /// A driver poll began (`detail` = the driver's current stage).
    Polling,
    /// The poll returned `Pending`; the continuation parked
    /// (`detail` = the first awaited future id).
    Parked,
    /// A waker (or sweep nudge) moved the continuation back to ready.
    Resumed,
    /// The JIT router changed this request's model-variant decision
    /// (`detail` = the new variant index; DESIGN.md §13). An annotation,
    /// not a scheduler state: `stage_durations` skips it so gap
    /// attribution is unchanged whether routing is on or off.
    Routed,
    /// An engine/tool call for this request started service
    /// (`detail` = the component-controller call tag).
    EngineDispatch,
    /// The call finished (`detail` = busy time in microseconds).
    EngineComplete,
    /// Terminal: completed successfully (`detail` = latency in ns).
    Done,
    /// Terminal: the driver returned an error (`detail` = latency ns).
    Failed,
    /// Terminal: shed after admission (ingress shutdown drain).
    Shed,
    /// Terminal: deadline passed (queued or parked).
    Expired,
    /// Terminal: withdrawn via `Ticket::cancel`.
    Cancelled,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Admitted => "admitted",
            TraceKind::Queued => "queued",
            TraceKind::Scheduled => "scheduled",
            TraceKind::Polling => "polling",
            TraceKind::Parked => "parked",
            TraceKind::Resumed => "resumed",
            TraceKind::Routed => "routed",
            TraceKind::EngineDispatch => "engine_dispatch",
            TraceKind::EngineComplete => "engine_complete",
            TraceKind::Done => "done",
            TraceKind::Failed => "failed",
            TraceKind::Shed => "shed",
            TraceKind::Expired => "expired",
            TraceKind::Cancelled => "cancelled",
        }
    }

    /// Terminal kinds end a timeline; at most one per request
    /// (exactly-one-terminal-outcome, `ingress::TicketCell`).
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            TraceKind::Done
                | TraceKind::Failed
                | TraceKind::Shed
                | TraceKind::Expired
                | TraceKind::Cancelled
        )
    }
}

/// A fixed-capacity overwrite-oldest buffer. `push` beyond capacity
/// evicts the oldest entry and counts it as dropped; storage is
/// pre-allocated at construction so a push never allocates.
#[derive(Debug)]
pub struct Ring<T> {
    cap: usize,
    buf: VecDeque<T>,
    written: u64,
    dropped: u64,
}

impl<T> Ring<T> {
    pub fn new(cap: usize) -> Ring<T> {
        let cap = cap.max(1);
        Ring { cap, buf: VecDeque::with_capacity(cap), written: 0, dropped: 0 }
    }

    /// Append, evicting the oldest entry if full. Returns the value's
    /// all-time write index (0-based, monotonic).
    pub fn push(&mut self, v: T) -> u64 {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(v);
        let seq = self.written;
        self.written += 1;
        seq
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Entries evicted by overflow (selective `retain` removals are a
    /// deliberate forget, not data loss, and are not counted here).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// All-time number of pushes.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// Oldest-to-newest iteration over what is still buffered.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf.iter()
    }

    /// Keep only entries matching the predicate (used to evict a
    /// consumed request's events without touching its shard-mates).
    pub fn retain(&mut self, f: impl FnMut(&T) -> bool) {
        self.buf.retain(f);
    }
}

/// The bounded per-node event store. `capacity` is split evenly across
/// [`SHARDS`] rings (per-shard capacity = `ceil(capacity / SHARDS)`, min
/// 1), so total retention is at least the configured capacity and a hot
/// shard cannot starve the others' history.
pub struct FlightRecorder {
    clock: Clock,
    epoch: Instant,
    shards: Vec<Mutex<Ring<TraceEvent>>>,
}

impl FlightRecorder {
    pub fn new(capacity: usize, clock: Clock) -> FlightRecorder {
        let per_shard = (capacity.max(1) + SHARDS - 1) / SHARDS;
        let epoch = clock.now();
        FlightRecorder {
            clock,
            epoch,
            shards: (0..SHARDS).map(|_| Mutex::new(Ring::new(per_shard))).collect(),
        }
    }

    fn shard(&self, request: RequestId) -> &Mutex<Ring<TraceEvent>> {
        &self.shards[(request.0 as usize) % SHARDS]
    }

    /// Record one transition. One shard lock + one ring write; the
    /// timestamp is read from the injected clock before locking.
    pub fn record(&self, request: RequestId, kind: TraceKind, detail: u64) {
        let clock_ns = self.clock.now().saturating_duration_since(self.epoch).as_nanos() as u64;
        let mut ring = self.shard(request).lock().unwrap();
        let seq = ring.written();
        ring.push(TraceEvent { request, seq, clock_ns, kind, detail });
    }

    /// The still-buffered events of one request, oldest first.
    pub fn timeline(&self, request: RequestId) -> Vec<TraceEvent> {
        let ring = self.shard(request).lock().unwrap();
        ring.iter().filter(|e| e.request == request).copied().collect()
    }

    /// Evict one request's events (trace consumed over the wire — same
    /// lifecycle as the PR-6 ticket registry's consume-on-read).
    pub fn forget(&self, request: RequestId) {
        let mut ring = self.shard(request).lock().unwrap();
        ring.retain(|e| e.request != request);
    }

    /// Total events overwritten by ring overflow across all shards.
    pub fn dropped(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().dropped()).sum()
    }

    /// Total events ever recorded across all shards.
    pub fn written(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().written()).sum()
    }

    /// Total retained capacity (per-shard capacity × shard count).
    pub fn capacity(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().capacity()).sum()
    }

    /// Request ids with at least one buffered event (the `nalar trace`
    /// waterfall scans this; not a hot-path operation).
    pub fn requests(&self) -> Vec<RequestId> {
        let mut ids: Vec<RequestId> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().iter().map(|e| e.request).collect::<Vec<_>>())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }
}

/// The handle threaded through `SchedulerOpts` into every transition
/// site. `disabled()` makes every call a no-op (a `None` check, no lock),
/// so tracing can be configured off with zero hot-path cost.
#[derive(Clone, Default)]
pub struct TraceSink(Option<Arc<FlightRecorder>>);

impl TraceSink {
    /// A sink that records nothing (the `trace.capacity = 0` setting).
    pub fn disabled() -> TraceSink {
        TraceSink(None)
    }

    /// A sink backed by a fresh recorder of `capacity` events total.
    /// `capacity == 0` means disabled.
    pub fn recording(capacity: usize, clock: Clock) -> TraceSink {
        if capacity == 0 {
            TraceSink(None)
        } else {
            TraceSink(Some(Arc::new(FlightRecorder::new(capacity, clock))))
        }
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    pub fn record(&self, request: RequestId, kind: TraceKind, detail: u64) {
        if let Some(r) = &self.0 {
            r.record(request, kind, detail);
        }
    }

    pub fn timeline(&self, request: RequestId) -> Vec<TraceEvent> {
        match &self.0 {
            Some(r) => r.timeline(request),
            None => Vec::new(),
        }
    }

    pub fn forget(&self, request: RequestId) {
        if let Some(r) = &self.0 {
            r.forget(request);
        }
    }

    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map_or(0, |r| r.dropped())
    }

    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.0.as_ref()
    }
}

/// A late-installable sink slot. Component controllers are spawned when
/// the deployment launches — before any `Ingress` (which owns the
/// recorder) exists — so they hold a `SharedSink` whose inner sink the
/// ingress installs at start. Reads take the `RwLock` read path only.
#[derive(Clone, Default)]
pub struct SharedSink(Arc<RwLock<TraceSink>>);

impl SharedSink {
    pub fn new() -> SharedSink {
        SharedSink::default()
    }

    /// Point every holder of this slot at `sink` (idempotent; a second
    /// ingress on the same deployment takes over the slot).
    pub fn install(&self, sink: TraceSink) {
        *self.0.write().unwrap() = sink;
    }

    pub fn record(&self, request: RequestId, kind: TraceKind, detail: u64) {
        self.0.read().unwrap().record(request, kind, detail);
    }

    pub fn get(&self) -> TraceSink {
        self.0.read().unwrap().clone()
    }
}

/// Per-component wall-time decomposition of one timeline, in
/// nanoseconds. `queue_wait + sched_delay + poll + future_wait` covers
/// admission → terminal up to clock granularity; `engine_service`
/// overlaps `future_wait` (the request is parked while an engine serves
/// its call) and is reported alongside, not summed.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StageDurations {
    pub queue_wait_ns: u64,
    pub sched_delay_ns: u64,
    pub poll_ns: u64,
    pub future_wait_ns: u64,
    pub engine_service_ns: u64,
    /// First event → terminal event (0 if the timeline is still open).
    pub total_ns: u64,
}

impl StageDurations {
    /// The four additive components (excludes the overlapping
    /// `engine_service`).
    pub fn sum_ns(&self) -> u64 {
        self.queue_wait_ns + self.sched_delay_ns + self.poll_ns + self.future_wait_ns
    }
}

/// Fold a timeline into its per-stage decomposition. Walks the
/// state-entering events in order, attributing each gap to the state it
/// was spent in; `EngineDispatch`/`EngineComplete` pairs (matched by
/// `detail` tag) accumulate `engine_service` as an overlay.
pub fn stage_durations(events: &[TraceEvent]) -> StageDurations {
    let mut out = StageDurations::default();
    let mut prev: Option<(TraceKind, u64)> = None;
    let mut dispatched: Vec<(u64, u64)> = Vec::new(); // (tag, dispatch ns)
    let mut first_ns: Option<u64> = None;
    for e in events {
        match e.kind {
            // annotation, not a state: must not reset `prev` or the gap
            // following a routing decision would be unattributed
            TraceKind::Routed => continue,
            TraceKind::EngineDispatch => {
                dispatched.push((e.detail, e.clock_ns));
                continue; // overlay: not a scheduler state transition
            }
            TraceKind::EngineComplete => {
                if let Some(pos) = dispatched.iter().position(|(tag, _)| *tag == e.detail) {
                    let (_, at) = dispatched.swap_remove(pos);
                    out.engine_service_ns += e.clock_ns.saturating_sub(at);
                }
                continue;
            }
            _ => {}
        }
        first_ns.get_or_insert(e.clock_ns);
        if let Some((kind, at)) = prev {
            let gap = e.clock_ns.saturating_sub(at);
            match kind {
                // Admitted → Queued is the same lock acquisition; the
                // gap (if any) counts as queue wait.
                TraceKind::Admitted | TraceKind::Queued => out.queue_wait_ns += gap,
                // Scheduled → first poll, and Resumed → re-poll: time
                // spent runnable but waiting for a worker.
                TraceKind::Scheduled | TraceKind::Resumed => out.sched_delay_ns += gap,
                TraceKind::Polling => out.poll_ns += gap,
                TraceKind::Parked => out.future_wait_ns += gap,
                _ => {}
            }
        }
        if e.kind.is_terminal() {
            if let Some(first) = first_ns {
                out.total_ns = e.clock_ns.saturating_sub(first);
            }
            prev = None;
        } else {
            prev = Some((e.kind, e.clock_ns));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r: Ring<u32> = Ring::new(4);
        for i in 0..6u32 {
            let seq = r.push(i);
            assert_eq!(seq, i as u64, "push returns the all-time write index");
        }
        assert_eq!(r.len(), 4, "bounded at capacity");
        assert_eq!(r.capacity(), 4);
        assert_eq!(r.dropped(), 2, "two evictions counted");
        assert_eq!(r.written(), 6);
        let kept: Vec<u32> = r.iter().copied().collect();
        assert_eq!(kept, vec![2, 3, 4, 5], "oldest entries were the ones evicted");
    }

    #[test]
    fn ring_retain_is_not_a_drop() {
        let mut r: Ring<u32> = Ring::new(8);
        for i in 0..5u32 {
            r.push(i);
        }
        r.retain(|v| v % 2 == 0);
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 0, "selective forget is not overflow loss");
    }

    #[test]
    fn recorder_bounds_per_shard_and_counts_drops() {
        let (clock, _v) = Clock::manual();
        // capacity 32 over 32 shards = exactly 1 event retained per shard
        let rec = FlightRecorder::new(32, clock);
        let rid = RequestId(7);
        rec.record(rid, TraceKind::Admitted, 0);
        rec.record(rid, TraceKind::Queued, 0);
        rec.record(rid, TraceKind::Done, 0);
        let tl = rec.timeline(rid);
        assert_eq!(tl.len(), 1, "ring kept only the newest event");
        assert_eq!(tl[0].kind, TraceKind::Done);
        assert_eq!(rec.dropped(), 2, "both evictions counted");
        assert_eq!(rec.written(), 3);
    }

    #[test]
    fn recorder_timelines_are_per_request_and_virtual_clock_stamped() {
        let (clock, v) = Clock::manual();
        let rec = FlightRecorder::new(1024, clock);
        let a = RequestId(1);
        let b = RequestId(1 + SHARDS as u64); // same shard as `a` on purpose
        rec.record(a, TraceKind::Admitted, 0);
        v.advance(Duration::from_millis(5));
        rec.record(b, TraceKind::Admitted, 0);
        v.advance(Duration::from_millis(5));
        rec.record(a, TraceKind::Done, 0);
        let tl = rec.timeline(a);
        assert_eq!(tl.len(), 2, "shard-mate `b` is filtered out");
        assert_eq!(tl[0].kind, TraceKind::Admitted);
        assert_eq!(tl[0].clock_ns, 0);
        assert_eq!(tl[1].kind, TraceKind::Done);
        assert_eq!(tl[1].clock_ns, 10_000_000, "virtual clock stamps exactly");
        assert!(tl[0].seq < tl[1].seq, "seq orders a request's events");
        rec.forget(a);
        assert!(rec.timeline(a).is_empty());
        assert_eq!(rec.timeline(b).len(), 1, "forget is per-request, not per-shard");
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn disabled_sink_is_a_no_op() {
        let sink = TraceSink::disabled();
        assert!(!sink.enabled());
        sink.record(RequestId(1), TraceKind::Admitted, 0);
        assert!(sink.timeline(RequestId(1)).is_empty());
        assert_eq!(sink.dropped(), 0);
        let zero = TraceSink::recording(0, Clock::wall());
        assert!(!zero.enabled(), "capacity 0 means disabled");
    }

    #[test]
    fn shared_sink_installs_late() {
        let shared = SharedSink::new();
        shared.record(RequestId(3), TraceKind::EngineDispatch, 9); // pre-install: dropped
        let (clock, _v) = Clock::manual();
        let sink = TraceSink::recording(256, clock);
        shared.install(sink.clone());
        shared.record(RequestId(3), TraceKind::EngineDispatch, 9);
        assert_eq!(sink.timeline(RequestId(3)).len(), 1, "post-install events land");
    }

    #[test]
    fn stage_durations_decompose_a_timeline() {
        let r = RequestId(0);
        let ev = |seq: u64, ms: u64, kind: TraceKind, detail: u64| TraceEvent {
            request: r,
            seq,
            clock_ns: ms * 1_000_000,
            kind,
            detail,
        };
        let tl = vec![
            ev(0, 0, TraceKind::Admitted, 0),
            ev(1, 0, TraceKind::Queued, 0),
            ev(2, 4, TraceKind::Scheduled, 0),  // queue_wait 4ms
            ev(3, 4, TraceKind::Polling, 0),    // sched_delay 0
            ev(4, 6, TraceKind::Parked, 11),    // poll 2ms
            ev(5, 6, TraceKind::EngineDispatch, 1),
            ev(6, 14, TraceKind::EngineComplete, 1), // engine 8ms (overlay)
            ev(7, 16, TraceKind::Resumed, 0),   // future_wait 10ms
            ev(8, 17, TraceKind::Polling, 1),   // sched_delay 1ms
            ev(9, 18, TraceKind::Done, 0),      // poll 1ms
        ];
        let s = stage_durations(&tl);
        assert_eq!(s.queue_wait_ns, 4_000_000);
        assert_eq!(s.sched_delay_ns, 1_000_000);
        assert_eq!(s.poll_ns, 3_000_000);
        assert_eq!(s.future_wait_ns, 10_000_000);
        assert_eq!(s.engine_service_ns, 8_000_000);
        assert_eq!(s.total_ns, 18_000_000);
        assert_eq!(s.sum_ns(), s.total_ns, "additive components cover the timeline");
    }

    #[test]
    fn terminal_kinds_are_exactly_the_five_outcomes() {
        for k in [
            TraceKind::Done,
            TraceKind::Failed,
            TraceKind::Shed,
            TraceKind::Expired,
            TraceKind::Cancelled,
        ] {
            assert!(k.is_terminal(), "{}", k.name());
        }
        for k in [
            TraceKind::Admitted,
            TraceKind::Queued,
            TraceKind::Scheduled,
            TraceKind::Polling,
            TraceKind::Parked,
            TraceKind::Resumed,
            TraceKind::Routed,
            TraceKind::EngineDispatch,
            TraceKind::EngineComplete,
        ] {
            assert!(!k.is_terminal(), "{}", k.name());
        }
    }

    #[test]
    fn routed_is_an_annotation_not_a_state() {
        let r = RequestId(0);
        let ev = |seq: u64, ms: u64, kind: TraceKind, detail: u64| TraceEvent {
            request: r,
            seq,
            clock_ns: ms * 1_000_000,
            kind,
            detail,
        };
        // Same shape as the decomposition test, with a Routed event
        // landing mid-poll: the decomposition must be identical.
        let tl = vec![
            ev(0, 0, TraceKind::Admitted, 0),
            ev(1, 0, TraceKind::Queued, 0),
            ev(2, 4, TraceKind::Scheduled, 0),
            ev(3, 4, TraceKind::Routed, 2), // skipped by the fold
            ev(4, 4, TraceKind::Polling, 0),
            ev(5, 6, TraceKind::Parked, 11),
            ev(6, 16, TraceKind::Resumed, 0),
            ev(7, 17, TraceKind::Polling, 1),
            ev(8, 18, TraceKind::Done, 0),
        ];
        let s = stage_durations(&tl);
        assert_eq!(s.queue_wait_ns, 4_000_000);
        assert_eq!(s.future_wait_ns, 10_000_000);
        assert_eq!(s.sum_ns(), s.total_ns, "Routed must not break gap attribution");
    }
}
