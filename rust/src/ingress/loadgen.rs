//! `nalar loadgen` — the open-loop saturation sweep (paper §6).
//!
//! For each (offered RPS, system) point this drives the ingress front door
//! with a Poisson arrival process ([`Arrivals::schedule`]): submits never
//! block on completion — exactly the open-loop discipline under which the
//! paper's capacity claim is stated. Each point reports goodput (requests
//! completed *within deadline* per second), shed rate, and latency
//! quantiles; the sweep across RPS produces the §6 saturation curve where
//! NALAR sustains 80 RPS and the baselines' goodput collapses (their
//! unbounded queues turn overload into divergent p99 instead of sheds).
//!
//! Output: `BENCH_rps_sweep.json` in the `nalar-bench/v1` schema
//! (validated by [`crate::bench::validate`]; `latency` is censored at the
//! deadline so baseline p99 divergence is visible, `latency_ok` is
//! completions only).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::baselines::SystemUnderTest;
use crate::bench;
use crate::config::DeploymentConfig;
use crate::error::{Error, Result};
use crate::ids::SessionId;
use crate::ingress::Ingress;
use crate::json;
use crate::metrics::{goodput, shed_rate, LatencyRecorder};
use crate::server::Deployment;
use crate::util::bench::Table;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workflow::harness::input_for;
use crate::workflow::WorkflowKind;
use crate::workload::Arrivals;

/// One `nalar loadgen` invocation.
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    pub workflow: WorkflowKind,
    pub systems: Vec<SystemUnderTest>,
    /// Offered load points (wall-clock requests/second).
    pub rates: Vec<f64>,
    /// Measurement window per point (wall-clock seconds).
    pub secs: u64,
    /// CI-smoke profile flag (stamped into the report).
    pub quick: bool,
    pub out_dir: PathBuf,
    /// Sessions drawn Zipf-skewed, as in the Fig-9 harness.
    pub session_pool: usize,
    /// Per-request deadline in paper seconds (scaled by `time_scale`).
    pub timeout_paper_s: f64,
    /// Override the config's `time_scale` (None = keep the config's).
    pub time_scale: Option<f64>,
    pub seed: u64,
    /// Deployment config file (None = the workflow's builtin config).
    pub config: Option<PathBuf>,
    /// Override the config's `ingress.workers` scheduler thread count
    /// (None = keep the config's). The event-driven scheduler multiplexes
    /// in-flight requests over these threads, so a small value with a
    /// large offered load is the thread-decoupling stress test.
    pub workers: Option<usize>,
    /// Override the deployment's policy list (None = keep the config's /
    /// the system's defaults). The hc gate pins this to `load_balance`
    /// only: `resource_realloc` may kill an instance mid-run, failing its
    /// queued futures retryably — legitimate in the saturation sweep,
    /// noise in a must-complete-everything functional gate.
    pub policies: Option<Vec<String>>,
    /// Fail the run if any point completes fewer requests than it
    /// admitted (offered − shed) — the CI gate for the scheduler: with
    /// in-flight ≫ threads, every admitted request must still finish.
    pub expect_admitted_complete: bool,
}

impl LoadgenOpts {
    /// CI-smoke profile: two points, two systems, seconds of wall time.
    pub fn quick(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            workflow,
            systems: vec![SystemUnderTest::Nalar, SystemUnderTest::AutoGenLike],
            rates: vec![40.0, 80.0],
            secs: 1,
            quick: true,
            out_dir: PathBuf::from("."),
            session_pool: 16,
            timeout_paper_s: 30.0,
            time_scale: Some(0.002),
            seed: 0x10AD,
            config: None,
            workers: None,
            policies: None,
            expect_admitted_complete: false,
        }
    }

    /// The full §6 sweep: all four systems across the saturation range.
    /// `time_scale` 0.1 (only a 10x speedup) puts the workload's capacity
    /// cliff inside the swept range, so 80 RPS is a genuine saturation
    /// point rather than a trivial one.
    pub fn full(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            workflow,
            systems: SystemUnderTest::all().to_vec(),
            rates: vec![20.0, 40.0, 60.0, 80.0, 100.0, 120.0, 160.0],
            secs: 8,
            quick: false,
            out_dir: PathBuf::from("."),
            session_pool: 48,
            timeout_paper_s: 30.0,
            time_scale: Some(0.1),
            seed: 0x10AD,
            config: None,
            workers: None,
            policies: None,
            expect_admitted_complete: false,
        }
    }

    /// High-concurrency CI gate: one point offering ~640 requests in 2s
    /// onto a 4-thread scheduler (in-flight ≫ threads), failing the run
    /// if any admitted request does not complete. The generous deadline
    /// makes this a functional gate on the event-driven scheduler, not a
    /// latency benchmark.
    pub fn hc_smoke(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![320.0],
            secs: 2,
            session_pool: 32,
            timeout_paper_s: 600.0,
            time_scale: Some(0.0005),
            workers: Some(4),
            // `resource_realloc` may kill an instance mid-run, failing its
            // queued futures retryably — legitimate in the saturation
            // sweep, noise in a must-complete-everything gate.
            policies: Some(vec!["load_balance".into()]),
            expect_admitted_complete: true,
            ..Self::quick(workflow)
        }
    }
}

/// Run the sweep and write `BENCH_rps_sweep.json`. Returns the path.
pub fn run(opts: &LoadgenOpts) -> Result<PathBuf> {
    if opts.rates.is_empty() || opts.systems.is_empty() {
        return Err(Error::Config("loadgen needs at least one rate and one system".into()));
    }
    let mut table = Table::new(&[
        "system", "rps", "offered", "ok", "shed", "expired", "fail", "goodput", "p50(s)", "p99(s)",
    ]);
    let mut points = Vec::new();
    for &rps in &opts.rates {
        for &system in &opts.systems {
            let t0 = Instant::now();
            let p = run_point(opts, rps, system)?;
            println!(
                "[loadgen] {} {} @ {:.0} rps done in {:.1?}",
                opts.workflow.name(),
                system.name(),
                rps,
                t0.elapsed()
            );
            table.row(&[
                p.get("system").as_str().unwrap_or("?").to_string(),
                format!("{:.0}", p.get("rps_wall").as_f64().unwrap_or(0.0)),
                p.get("offered").as_u64().unwrap_or(0).to_string(),
                p.get("completed").as_u64().unwrap_or(0).to_string(),
                p.get("shed").as_u64().unwrap_or(0).to_string(),
                p.get("expired_in_queue").as_u64().unwrap_or(0).to_string(),
                p.get("failed").as_u64().unwrap_or(0).to_string(),
                format!("{:.1}", p.get("goodput_rps").as_f64().unwrap_or(0.0)),
                format!("{:.1}", p.get("latency").get("p50").as_f64().unwrap_or(0.0)),
                format!("{:.1}", p.get("latency").get("p99").as_f64().unwrap_or(0.0)),
            ]);
            if opts.expect_admitted_complete {
                let offered = p.get("offered").as_u64().unwrap_or(0);
                let shed = p.get("shed").as_u64().unwrap_or(0);
                let completed = p.get("completed").as_u64().unwrap_or(0);
                if completed < offered.saturating_sub(shed) {
                    return Err(Error::Msg(format!(
                        "high-concurrency gate: {} {} @ {:.0} rps completed only {completed} of \
                         {} admitted requests",
                        opts.workflow.name(),
                        system.name(),
                        rps,
                        offered.saturating_sub(shed),
                    )));
                }
            }
            points.push(p);
        }
    }
    println!("\n=== RPS sweep — {} workflow, open loop ===", opts.workflow.name());
    table.print();
    let report = bench::report(bench::RPS_SWEEP, opts.quick, "paper_s", points);
    bench::validate(&report)?;
    std::fs::create_dir_all(&opts.out_dir)?;
    bench::write_report(&opts.out_dir, bench::RPS_SWEEP, &report)
}

/// One (rate, system) cell of the sweep.
fn run_point(opts: &LoadgenOpts, rps: f64, system: SystemUnderTest) -> Result<Value> {
    let mut cfg = match &opts.config {
        Some(path) => DeploymentConfig::from_json_file(path)?,
        None => opts.workflow.config(),
    };
    if let Some(ts) = opts.time_scale {
        cfg.time_scale = ts;
    }
    if let Some(w) = opts.workers {
        cfg.ingress.workers = w.max(1);
    }
    // Apply the system's serving mode FIRST (for NALAR this fills the
    // default policy trio when the config declares none — pushing ours
    // earlier would suppress that fill), then add the ingress-aware
    // provisioning loop on top. Baselines get stripped of all policies
    // (and admission control) by the same `apply`, which `launch_as`
    // re-runs idempotently. An explicit `opts.policies` override is
    // authoritative: nothing is appended to it.
    system.apply(&mut cfg);
    if let Some(policies) = &opts.policies {
        cfg.policies = policies.clone();
    } else if system == SystemUnderTest::Nalar
        && !cfg.policies.iter().any(|p| p == "overload_provision")
    {
        cfg.policies.push("overload_provision".into());
    }
    let d = Deployment::launch_as(cfg, system)?;
    let time_scale = d.cfg().time_scale;
    let timeout = Duration::from_secs_f64((opts.timeout_paper_s * time_scale).max(0.001));
    let window = Duration::from_secs(opts.secs.max(1));
    let ingress = Ingress::start(&d, &[opts.workflow]);
    let ingress_policy = ingress.metrics(opts.workflow).map(|m| m.policy).unwrap_or_default();

    let schedule = Arrivals::new(rps, opts.seed ^ rps.to_bits()).schedule(window);
    let offered = schedule.len() as u64;
    let sessions: Vec<SessionId> = (0..opts.session_pool.max(1)).map(|_| d.new_session()).collect();
    let mut turns = vec![0u64; sessions.len()];
    let mut rng = Rng::new(opts.seed ^ 0xFEED);

    // Open loop: pace submissions on the arrival schedule; never wait for
    // completions in this loop.
    let mut tickets = Vec::with_capacity(schedule.len());
    let mut shed = 0u64;
    let start = Instant::now();
    for at in &schedule {
        let wait = at.saturating_sub(start.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let progress = (start.elapsed().as_secs_f64() / window.as_secs_f64()).min(1.0);
        let sidx = rng.zipf(sessions.len(), 1.1);
        let turn = turns[sidx];
        turns[sidx] += 1;
        let input = input_for(opts.workflow, progress, turn, &mut rng);
        match ingress.submit(opts.workflow, Some(sessions[sidx]), input, timeout) {
            Ok(t) => tickets.push(t),
            Err(_) => shed += 1, // fast retryable rejection, already counted
        }
    }

    // Drain: every admitted request either completes or hits its deadline
    // (the scheduler's sweep fails expired work fast, so this terminates).
    let ok_rec = LatencyRecorder::new(); // completions within deadline
    let tail_rec = LatencyRecorder::new(); // + timeouts censored at the deadline
    let mut completed = 0u64;
    let mut failed = 0u64;
    for t in &tickets {
        let outcome = t.wait(timeout + Duration::from_millis(50));
        let lat = t.latency().unwrap_or(timeout);
        match outcome {
            Ok(_) if lat <= timeout => {
                completed += 1;
                ok_rec.record(lat);
                tail_rec.record(lat);
            }
            _ => {
                failed += 1;
                tail_rec.record(lat.min(timeout));
            }
        }
    }
    // Everything is drained, so the final snapshot splits the failures:
    // `expired_in_queue` never started a driver (queueing shed the work),
    // the remainder failed in execution (slow driver / agent error).
    let m_end = ingress.metrics(opts.workflow).unwrap_or_default();
    let expired_in_queue = m_end.expired_in_queue;
    ingress.stop();
    d.shutdown();

    let paper = 1.0 / time_scale;
    let gput = goodput(completed, window);
    let mut p = json!({
        "workflow": opts.workflow.name(),
        "system": system.name(),
        "rps_wall": rps,
        "rps_paper": rps * time_scale,
        "duration_s": opts.secs,
        "offered": offered,
        "completed": completed,
        "failed": failed.saturating_sub(expired_in_queue),
        "expired_in_queue": expired_in_queue,
        "shed": shed,
        "goodput_rps": gput,
        "goodput_frac": gput / rps,
        "shed_rate": shed_rate(shed, offered),
        "timeout_paper_s": opts.timeout_paper_s,
        "ingress_policy": ingress_policy,
        "ingress_workers": m_end.workers
    });
    p.insert("latency", tail_rec.summary_scaled(paper).to_json());
    p.insert("latency_ok", ok_rec.summary_scaled(paper).to_json());
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_writes_schema_valid_report() {
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![30.0],
            session_pool: 8,
            timeout_paper_s: 60.0,
            time_scale: Some(0.0005),
            out_dir: dir.clone(),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let path = run(&opts).unwrap();
        assert!(path.ends_with("BENCH_rps_sweep.json"));
        bench::check_files(&dir, &[bench::RPS_SWEEP]).unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let pts = report.get("points").as_arr().unwrap();
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert!(p.get("completed").as_u64().unwrap() > 0, "nothing completed");
        assert_eq!(p.get("ingress_policy").as_str(), Some("bounded"));
        assert!(p.get("expired_in_queue").as_u64().is_some(), "new-schema field missing");
        assert!(p.get("ingress_workers").as_u64().unwrap() >= 1);
        assert!(p.get("latency").get("p99").as_f64().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hc_gate_fails_when_admitted_work_cannot_complete() {
        // A zero-second deadline guarantees nothing completes; the
        // completion gate must turn that into an error instead of a
        // quietly-degraded report.
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-hc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            rates: vec![50.0],
            secs: 1,
            session_pool: 4,
            // 1ms effective deadline against ~12ms of service time:
            // nothing admitted can finish in time.
            timeout_paper_s: 0.0,
            time_scale: Some(0.01),
            out_dir: dir.clone(),
            workers: Some(2),
            expect_admitted_complete: true,
            ..LoadgenOpts::hc_smoke(WorkflowKind::Router)
        };
        let err = run(&opts).unwrap_err();
        assert!(err.to_string().contains("high-concurrency gate"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
