# Local entrypoints — identical to what CI runs (.github/workflows/ci.yml).

.PHONY: build test test-scheduler test-fairness fmt clippy lint bench bench-quick bench-contention bench-contention-quick bench-recovery bench-recovery-quick bench-routing bench-routing-quick loadgen loadgen-quick loadgen-hc serve-smoke artifacts clean

build:
	cargo build --release --all-targets

test:
	cargo test -q

# Deterministic scheduler suites: the Ticket::cancel race matrix + the
# FIFO-vs-deadline_slack A/B trace (virtual clock, scripted engine) and
# the admission-controller property tests. --test-threads pinned: the
# lifecycle tests hold scheduler workers hostage on purpose, so they must
# not share a runner with a dozen sibling tests fighting for cores.
test-scheduler:
	cargo test -q --release --test integration_scheduler -- --test-threads=2
	cargo test -q --release --test props -- --test-threads=2

# Deterministic multi-tenant fairness suite: the noisy-neighbor FIFO-vs-
# DRR A/B trace, the weighted 3:1 service-order replay and the
# cancel-debits-the-right-sub-queue lifecycle test (virtual clock,
# scripted engine). Same pinned --test-threads rationale as above: these
# tests hold the single scheduler worker hostage on purpose.
test-fairness:
	cargo test -q --release --test integration_fairness -- --test-threads=2

fmt:
	cargo fmt --all -- --check

clippy:
	cargo clippy --all-targets -- -D warnings

lint: fmt clippy

# Full paper reproduction: writes BENCH_fig9.json, BENCH_fig10.json,
# BENCH_table4.json, BENCH_sec62.json at the repo root (minutes).
bench:
	cargo run --release -- bench

# CI-smoke profile (seconds) + schema validation — what bench-smoke runs.
bench-quick:
	cargo run --release -- bench --quick
	cargo run --release -- bench --check-only

# Scheduler lock-scaling microbenchmark (ISSUE 8): sweeps worker threads
# × workflow shards × tenants, reporting submit/wake/poll/complete
# throughput and p99 shard-lock hold time -> BENCH_contention.json at the
# repo root. The full profile records the lock-scaling curve later PRs
# regress against (minutes); the quick profile is the CI smoke.
bench-contention:
	cargo run --release -- bench contention
	cargo run --release -- bench contention --check-only

bench-contention-quick:
	cargo run --release -- bench contention --quick
	cargo run --release -- bench contention --check-only

# Kill-and-recover benchmark (ISSUE 9): journals a run, halts with
# requests in flight, replays the journal into a fresh deployment and
# drives the recovered requests to completion -> BENCH_recovery.json
# (schema arm recovery/v1; the validator enforces count conservation).
# The full profile sweeps the always/batch/never fsync policies; the
# quick profile is the CI recovery-smoke.
bench-recovery:
	cargo run --release -- bench recovery
	cargo run --release -- bench recovery --check-only

bench-recovery-quick:
	cargo run --release -- bench recovery --quick
	cargo run --release -- bench recovery --check-only

# JIT model-routing gate (ISSUE 10): jit vs the fixed-large pin on the
# router workflow across an rps sweep, identical three-variant
# latency/quality curve on both arms -> BENCH_routing.json (schema arm
# routing/v1). The run errors unless jit beats the pin on goodput at the
# shared quality floor for at least one swept rate; the quick profile is
# the CI routing-smoke.
bench-routing:
	cargo run --release -- bench routing
	cargo run --release -- bench routing --check-only

bench-routing-quick:
	cargo run --release -- bench routing --quick
	cargo run --release -- bench routing --check-only

# Full §6 saturation sweep through the ingress front door: writes
# BENCH_rps_sweep.json at the repo root (minutes).
loadgen:
	cargo run --release -- loadgen

# CI-smoke sweep (seconds) + schema validation — what loadgen-smoke runs.
loadgen-quick:
	cargo run --release -- loadgen --quick
	cargo run --release -- loadgen --check-only

# High-concurrency scheduler gate (what the loadgen-smoke CI job also
# runs): ~640 offered requests on a 4-thread scheduler; fails unless every
# admitted request completes.
loadgen-hc:
	cargo run --release -- loadgen --hc-smoke --out hc-point
	cargo run --release -- loadgen --check-only --out hc-point

# End-to-end gate for the HTTP serving plane (DESIGN.md §9): boots
# `nalar serve --listen 127.0.0.1:0`, drives it with `loadgen --remote`
# (async-park POSTs, GET polls, DELETE cancels over a real socket),
# validates the rps_sweep report (transport=http), then stops the server
# and asserts it exits 0 — which it only does with zero leaked
# connections.
serve-smoke:
	cargo build --release --bin nalar
	bash scripts/serve_smoke.sh

# OPTIONAL / offline-skippable: lowers the L2 JAX transformer (with the L1
# Pallas attention kernels) to HLO text + a weights blob for the PJRT
# executor. Requires python3 + jax; nothing in the build, tests or benches
# depends on it — the `sim` executor serves every benchmark, and
# `tests/runtime_numerics.rs` skips cleanly when artifacts are missing.
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../rust/artifacts

clean:
	cargo clean
