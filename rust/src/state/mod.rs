//! Managed state layer (paper §3.3, §4.3.2).
//!
//! Agentic workflows accumulate session state (lists/dicts in the paper's
//! GitHub survey) and KV caches. NALAR decouples that *logical* state from
//! physical placement: state lives in the node store under
//! `state/{session}/{key}`, tagged with the session the local controller
//! already knows for every request, so the runtime can relocate sessions —
//! requests *and* their state — without developer involvement.
//!
//! * [`ManagedList`]/[`ManagedDict`]: the developer-facing abstractions.
//!   Handles are constructed per request execution by the component
//!   controller, so after a migration the next request transparently binds
//!   to the state's new home.
//! * [`kvcache`]: the LMCache substitute — a tiered K,V cache with the
//!   policy hooks NALAR's global controller drives (retain / offload /
//!   migrate), versus the generic LRU the paper criticizes.

pub mod kvcache;
mod managed;

pub use managed::{migrate_session_state, ManagedDict, ManagedList};
