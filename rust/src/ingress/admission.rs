//! Admission control at the serving front door.
//!
//! Open-loop traffic does not slow down when the system does — arrivals
//! keep coming, and something must give: either the queue (bounded
//! shedding), the arrival rate (token bucket), or latency (unbounded, the
//! baseline failure mode the §6 saturation sweep exposes). One
//! [`AdmissionController`] guards each workflow queue; its accept/shed
//! counters flow into [`crate::coordinator::IngressMetrics`] telemetry.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::{IngressSettings, TenantSettings};

/// How the front door decides accept-vs-shed at submit time.
#[derive(Debug, Clone)]
pub enum AdmissionPolicy {
    /// Accept everything. The queue absorbs overload and latency diverges
    /// instead — how every compared baseline behaves (§2.3).
    Unbounded,
    /// Shed when the target queue already holds `cap` requests: bounds
    /// both queue memory and worst-case queueing delay, and turns
    /// overload into fast, retryable rejections.
    Bounded { cap: usize },
    /// Token bucket: admit at most `rate` requests/second (wall clock),
    /// with bursts up to `burst` tokens.
    TokenBucket { rate: f64, burst: f64 },
}

impl AdmissionPolicy {
    /// Parse a config/CLI policy name ("unbounded" | "bounded" |
    /// "token_bucket"). The name picks the *variant*; parameters come
    /// from [`Self::from_settings`]. This is the name-validity authority
    /// config validation uses (mirroring
    /// [`crate::ingress::SchedulePolicy::parse`]), so a typo fails at
    /// load time instead of silently running `bounded`.
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "unbounded" => Some(AdmissionPolicy::Unbounded),
            "bounded" => Some(AdmissionPolicy::Bounded {
                cap: IngressSettings::default().queue_cap,
            }),
            "token_bucket" => Some(AdmissionPolicy::TokenBucket {
                rate: f64::INFINITY,
                burst: IngressSettings::default().token_burst,
            }),
            _ => None,
        }
    }

    /// Resolve the configured policy (`DeploymentConfig.ingress`);
    /// unknown names fall back to `Bounded` (config validation rejects
    /// them via [`Self::parse`] before a deployment ever launches).
    pub fn from_settings(s: &IngressSettings) -> AdmissionPolicy {
        match Self::parse(&s.policy) {
            Some(AdmissionPolicy::Unbounded) => AdmissionPolicy::Unbounded,
            Some(AdmissionPolicy::TokenBucket { .. }) => AdmissionPolicy::TokenBucket {
                rate: if s.token_rate > 0.0 { s.token_rate } else { f64::INFINITY },
                burst: s.token_burst.max(1.0),
            },
            Some(AdmissionPolicy::Bounded { .. }) | None => {
                AdmissionPolicy::Bounded { cap: s.queue_cap.max(1) }
            }
        }
    }

    /// The admission layer one tenant adds *under* the shared policy:
    /// its own token bucket when the tenant configures a rate, otherwise
    /// nothing (`Unbounded` — the shared policy still applies on top).
    pub fn for_tenant(t: &TenantSettings) -> AdmissionPolicy {
        if t.token_rate > 0.0 {
            AdmissionPolicy::TokenBucket { rate: t.token_rate, burst: t.token_burst.max(1.0) }
        } else {
            AdmissionPolicy::Unbounded
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Unbounded => "unbounded",
            AdmissionPolicy::Bounded { .. } => "bounded",
            AdmissionPolicy::TokenBucket { .. } => "token_bucket",
        }
    }

    /// Queue cap this policy enforces (0 = unbounded).
    pub fn cap(&self) -> usize {
        match self {
            AdmissionPolicy::Bounded { cap } => *cap,
            _ => 0,
        }
    }
}

/// A structured shed verdict: the human-readable reason (display only)
/// plus, when the shedding layer was a token bucket, its refill rate in
/// requests/second. [`crate::error::Error::retry_after`] inverts the rate
/// into the `Retry-After` wire header — carrying it as data (rather than
/// re-parsing the reason string, the old bug) means rewording the reason
/// can never silently drop the header.
#[derive(Debug, Clone, PartialEq)]
pub struct Shed {
    pub reason: String,
    /// Token-bucket refill rate (rps) when rate-limited; `None` otherwise.
    pub retry_rate: Option<f64>,
}

impl Shed {
    fn full(reason: String) -> Shed {
        Shed { reason, retry_rate: None }
    }
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Accept/shed decision state for one workflow queue.
pub struct AdmissionController {
    policy: AdmissionPolicy,
    bucket: Mutex<Bucket>,
    pub accepted: AtomicU64,
    pub shed: AtomicU64,
}

impl AdmissionController {
    pub fn new(policy: AdmissionPolicy) -> AdmissionController {
        let burst = match &policy {
            AdmissionPolicy::TokenBucket { burst, .. } => *burst,
            _ => 0.0,
        };
        AdmissionController {
            policy,
            bucket: Mutex::new(Bucket { tokens: burst, last: Instant::now() }),
            accepted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> &AdmissionPolicy {
        &self.policy
    }

    /// Decide for one request given the current queue `depth`. Updates the
    /// accept/shed counters; `Err` carries the structured shed verdict.
    pub fn admit(&self, depth: usize) -> Result<(), Shed> {
        self.admit_at(depth, Instant::now())
    }

    /// [`Self::admit`] against an explicit `now` — the deterministic
    /// entry point for property tests driving the token bucket with a
    /// virtual clock ([`crate::testkit::Clock`]): refill becomes a pure
    /// function of the timestamps the test chooses. Time never runs
    /// backwards (an older `now` refills nothing).
    pub fn admit_at(&self, depth: usize, now: Instant) -> Result<(), Shed> {
        let verdict = self.decide_at(depth, now);
        self.record(verdict.is_ok());
        verdict
    }

    /// The decision alone, without touching the accept/shed counters.
    /// The ingress layers per-tenant token buckets under the shared
    /// per-workflow policy and must count each submit exactly once, on
    /// the *composed* verdict — so it decides through this and folds the
    /// final verdict in via [`Self::record`]. Token-bucket state still
    /// advances on `Ok` (an admitted request consumed its token even if a
    /// later layer sheds it: conservative under overload).
    pub fn decide_at(&self, depth: usize, now: Instant) -> Result<(), Shed> {
        match &self.policy {
            AdmissionPolicy::Unbounded => Ok(()),
            AdmissionPolicy::Bounded { cap } => {
                if depth >= *cap {
                    Err(Shed::full(format!("queue full ({depth}/{cap})")))
                } else {
                    Ok(())
                }
            }
            AdmissionPolicy::TokenBucket { rate, burst } => {
                let mut b = self.bucket.lock().unwrap();
                let refill = now.saturating_duration_since(b.last).as_secs_f64() * rate;
                b.tokens = (b.tokens + refill).min(*burst);
                b.last = b.last.max(now);
                if b.tokens >= 1.0 {
                    b.tokens -= 1.0;
                    Ok(())
                } else {
                    Err(Shed {
                        reason: format!("rate limit ({rate:.1} rps)"),
                        retry_rate: Some(*rate),
                    })
                }
            }
        }
    }

    /// The JIT router's tenant-budget probe (DESIGN.md §13): is this
    /// tenant's token bucket dry right now? A refill-adjusted peek that
    /// consumes nothing; policies without a bucket are never over budget.
    pub fn over_budget(&self, now: Instant) -> bool {
        match &self.policy {
            AdmissionPolicy::TokenBucket { rate, burst } => {
                let b = self.bucket.lock().unwrap();
                let refill = now.saturating_duration_since(b.last).as_secs_f64() * rate;
                (b.tokens + refill).min(*burst) < 1.0
            }
            _ => false,
        }
    }

    /// Fold a composed verdict into the accept/shed counters (exactly
    /// once per submit; see [`Self::decide_at`]).
    pub fn record(&self, admitted: bool) {
        if admitted {
            self.accepted.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shed.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_settings_resolves_names() {
        let mut s = IngressSettings::default();
        assert!(matches!(AdmissionPolicy::from_settings(&s), AdmissionPolicy::Bounded { .. }));
        s.policy = "unbounded".into();
        assert!(matches!(AdmissionPolicy::from_settings(&s), AdmissionPolicy::Unbounded));
        s.policy = "token_bucket".into();
        s.token_rate = 10.0;
        assert!(matches!(
            AdmissionPolicy::from_settings(&s),
            AdmissionPolicy::TokenBucket { .. }
        ));
    }

    #[test]
    fn parse_is_the_name_authority() {
        // every known policy round-trips through its own name...
        for name in ["unbounded", "bounded", "token_bucket"] {
            let p = AdmissionPolicy::parse(name).expect(name);
            assert_eq!(p.name(), name);
        }
        // ...and typos are rejected instead of silently becoming Bounded
        // (the bug: `from_settings` used to eat them via its fallback arm)
        for typo in ["bouned", "token-bucket", "Unbounded", "fifo", ""] {
            assert!(AdmissionPolicy::parse(typo).is_none(), "{typo} must not parse");
        }
    }

    /// Wire contract with `Error::retry_after`: the shed verdict carries
    /// the bucket's refill rate as structured data (`Shed::retry_rate`),
    /// so the HTTP layer's `Retry-After` derivation is immune to any
    /// rewording of the human-readable reason.
    #[test]
    fn shed_verdicts_feed_retry_after_derivation() {
        use crate::error::Error;
        let ctl = AdmissionController::new(AdmissionPolicy::TokenBucket { rate: 4.0, burst: 1.0 });
        let now = Instant::now();
        ctl.admit_at(0, now).unwrap();
        let shed = ctl.admit_at(0, now).unwrap_err();
        assert_eq!(shed.retry_rate, Some(4.0));
        let err = Error::Shed("router".into(), shed.reason, shed.retry_rate);
        assert_eq!(err.retry_after(), std::time::Duration::from_secs_f64(0.25));
        let bounded = AdmissionController::new(AdmissionPolicy::Bounded { cap: 1 });
        let shed = bounded.admit_at(1, now).unwrap_err();
        assert_eq!(shed.retry_rate, None);
        let err = Error::Shed("router".into(), shed.reason, shed.retry_rate);
        assert_eq!(err.retry_after(), std::time::Duration::from_secs(1), "no rate: flat 1 s");
    }

    #[test]
    fn over_budget_peeks_without_consuming() {
        let ctl = AdmissionController::new(AdmissionPolicy::TokenBucket { rate: 1e-9, burst: 1.0 });
        let now = Instant::now();
        assert!(!ctl.over_budget(now), "initial burst token present");
        ctl.admit_at(0, now).unwrap();
        assert!(ctl.over_budget(now), "bucket dry after the burst");
        // the peek consumed nothing and changed nothing
        assert!(ctl.over_budget(now));
        // policies without a bucket are never over budget
        let b = AdmissionController::new(AdmissionPolicy::Bounded { cap: 1 });
        assert!(!b.over_budget(now));
        let u = AdmissionController::new(AdmissionPolicy::Unbounded);
        assert!(!u.over_budget(now));
    }

    #[test]
    fn for_tenant_builds_a_bucket_only_when_a_rate_is_set() {
        let mut t = TenantSettings::default();
        assert!(matches!(AdmissionPolicy::for_tenant(&t), AdmissionPolicy::Unbounded));
        t.token_rate = 25.0;
        t.token_burst = 4.0;
        match AdmissionPolicy::for_tenant(&t) {
            AdmissionPolicy::TokenBucket { rate, burst } => {
                assert_eq!(rate, 25.0);
                assert_eq!(burst, 4.0);
            }
            other => panic!("expected a token bucket, got {}", other.name()),
        }
    }

    #[test]
    fn decide_then_record_matches_admit_at() {
        // The split path (used by the ingress to compose tenant buckets
        // with the shared policy) must count exactly once per verdict.
        let c = AdmissionController::new(AdmissionPolicy::Bounded { cap: 2 });
        let now = Instant::now();
        let ok = c.decide_at(0, now);
        c.record(ok.is_ok());
        let shed = c.decide_at(2, now);
        c.record(shed.is_ok());
        assert!(ok.is_ok() && shed.is_err());
        assert_eq!(c.accepted.load(Ordering::Relaxed), 1);
        assert_eq!(c.shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn unbounded_accepts_any_depth() {
        let c = AdmissionController::new(AdmissionPolicy::Unbounded);
        for depth in [0, 10, 100_000] {
            assert!(c.admit(depth).is_ok());
        }
        assert_eq!(c.accepted.load(Ordering::Relaxed), 3);
        assert_eq!(c.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn bounded_sheds_at_cap() {
        let c = AdmissionController::new(AdmissionPolicy::Bounded { cap: 4 });
        assert!(c.admit(3).is_ok());
        let err = c.admit(4).unwrap_err();
        assert!(err.reason.contains("queue full"), "{}", err.reason);
        assert!(c.admit(5).is_err());
        assert_eq!(c.accepted.load(Ordering::Relaxed), 1);
        assert_eq!(c.shed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn token_bucket_enforces_burst_then_rate() {
        // negligible refill rate: only the initial burst admits
        let c = AdmissionController::new(AdmissionPolicy::TokenBucket { rate: 1e-9, burst: 2.0 });
        assert!(c.admit(0).is_ok());
        assert!(c.admit(0).is_ok());
        let err = c.admit(0).unwrap_err();
        assert!(err.reason.contains("rate limit"), "{}", err.reason);
        assert_eq!(err.retry_rate, Some(1e-9));
    }
}
