//! Offline stand-in for the `xla` (PJRT) bindings.
//!
//! The build environment cannot vendor a real XLA binding, so this module
//! mirrors exactly the API surface [`crate::runtime::pjrt`] consumes and
//! reports the backend as unavailable at client construction. Everything
//! downstream (deployment launch with `executor: "pjrt"`, the quickstart
//! example, `tests/runtime_numerics.rs`) degrades into a clean "backend
//! unavailable" error instead of a link failure, and the `sim` executor
//! serves all benchmarks. Dropping a real binding in means replacing this
//! module body; no call site changes.

use std::fmt;
use std::path::Path;

/// Error raised by every entry point of the stub.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Self {
        Error(
            "XLA/PJRT backend not vendored in this build; use the `sim` executor \
             (see DESIGN.md §PJRT)"
                .into(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Host-side literal (tensor) handle.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Device client. `cpu()` is the stub's single failure point: it errors
/// before any weights are uploaded or HLO parsed, so callers fail fast.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_context() {
        let err = PjRtClient::cpu().err().expect("stub must not hand out clients");
        assert!(err.to_string().contains("not vendored"));
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}
