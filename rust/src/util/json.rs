//! Minimal-but-complete JSON: value model, parser, writer, `json!` macro.
//!
//! Future payloads, managed state, configs and the AOT manifest all move
//! through [`Value`]. The parser is a recursive-descent implementation of
//! RFC 8259 (escapes, `\uXXXX` incl. surrogate pairs, exponents); the
//! writer emits compact or pretty text. Object keys keep insertion order
//! irrelevant by using a BTreeMap (deterministic output for tests/goldens).

use std::collections::BTreeMap;
use std::fmt;

pub type Map = BTreeMap<String, Value>;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Map),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

static NULL: Value = Value::Null;

impl Value {
    // ------------------------------------------------------------ accessors
    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as u64) } else { None })
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|u| u as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&Vec<Value>> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&Map> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access (`Value::Null` if absent / not an object).
    pub fn get(&self, key: &str) -> &Value {
        match self {
            Value::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element access.
    pub fn idx(&self, i: usize) -> &Value {
        match self {
            Value::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Typed convenience getters with defaults (config parsing).
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).as_str().unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).as_f64().unwrap_or(default)
    }
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).as_u64().unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).as_bool().unwrap_or(default)
    }

    pub fn insert(&mut self, key: &str, v: impl Into<Value>) {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        }
    }

    pub fn push(&mut self, v: impl Into<Value>) {
        if let Value::Arr(a) = self {
            a.push(v.into());
        }
    }

    // ------------------------------------------------------------- writing
    // Compact text comes from the `Display` impl (`value.to_string()`).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

// ------------------------------------------------------------------ parser
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = Map::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let v = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(v).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("bad \\u escape"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.b[self.pos..];
                    let text = std::str::from_utf8(rest).map_err(|_| self.err("bad utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let hx = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// -------------------------------------------------------------- conversions
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
macro_rules! from_num {
    ($($t:ty),*) => {$(
        impl From<$t> for Value {
            fn from(n: $t) -> Self { Value::Num(n as f64) }
        }
    )*};
}
from_num!(f64, f32, i64, i32, u64, u32, usize, u16, i16, u8);
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl<T: Into<Value> + Clone> From<&[T]> for Value {
    fn from(v: &[T]) -> Self {
        Value::Arr(v.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Value>> From<Option<T>> for Value {
    fn from(v: Option<T>) -> Self {
        v.map(Into::into).unwrap_or(Value::Null)
    }
}

/// `json!` literal macro (serde_json-style):
/// `json!(null)`, `json!(3)`, `json!("s")`, `json!([a, b.c()])`,
/// `json!({"k": some.expr(), "nested": {"x": 1}, "list": [1, 2]})`.
/// Values interpolate via `Into<Value>`; nested `{}`/`[]` literals recurse.
#[macro_export]
macro_rules! json {
    (null) => { $crate::util::json::Value::Null };
    ([]) => { $crate::util::json::Value::Arr(Vec::new()) };
    ({}) => { $crate::util::json::Value::Obj($crate::util::json::Map::new()) };
    ([ $($tt:tt)+ ]) => {{
        let mut a: Vec<$crate::util::json::Value> = Vec::new();
        $crate::json_arr_internal!(a; $($tt)+);
        $crate::util::json::Value::Arr(a)
    }};
    ({ $($tt:tt)+ }) => {{
        let mut m = $crate::util::json::Map::new();
        $crate::json_obj_internal!(m; $($tt)+);
        $crate::util::json::Value::Obj(m)
    }};
    ($other:expr) => { $crate::util::json::Value::from($other) };
}

/// Internal muncher for `json!` object bodies.
#[doc(hidden)]
#[macro_export]
macro_rules! json_obj_internal {
    ($m:ident;) => {};
    ($m:ident; $k:literal : null $(, $($rest:tt)*)?) => {
        $m.insert($k.to_string(), $crate::util::json::Value::Null);
        $crate::json_obj_internal!($m; $($($rest)*)?);
    };
    ($m:ident; $k:literal : { $($inner:tt)* } $(, $($rest:tt)*)?) => {
        $m.insert($k.to_string(), $crate::json!({ $($inner)* }));
        $crate::json_obj_internal!($m; $($($rest)*)?);
    };
    ($m:ident; $k:literal : [ $($inner:tt)* ] $(, $($rest:tt)*)?) => {
        $m.insert($k.to_string(), $crate::json!([ $($inner)* ]));
        $crate::json_obj_internal!($m; $($($rest)*)?);
    };
    ($m:ident; $k:literal : $v:expr , $($rest:tt)*) => {
        $m.insert($k.to_string(), $crate::util::json::Value::from($v));
        $crate::json_obj_internal!($m; $($rest)*);
    };
    ($m:ident; $k:literal : $v:expr) => {
        $m.insert($k.to_string(), $crate::util::json::Value::from($v));
    };
}

/// Internal muncher for `json!` array bodies.
#[doc(hidden)]
#[macro_export]
macro_rules! json_arr_internal {
    ($a:ident;) => {};
    ($a:ident; null $(, $($rest:tt)*)?) => {
        $a.push($crate::util::json::Value::Null);
        $crate::json_arr_internal!($a; $($($rest)*)?);
    };
    ($a:ident; { $($inner:tt)* } $(, $($rest:tt)*)?) => {
        $a.push($crate::json!({ $($inner)* }));
        $crate::json_arr_internal!($a; $($($rest)*)?);
    };
    ($a:ident; [ $($inner:tt)* ] $(, $($rest:tt)*)?) => {
        $a.push($crate::json!([ $($inner)* ]));
        $crate::json_arr_internal!($a; $($($rest)*)?);
    };
    ($a:ident; $v:expr , $($rest:tt)*) => {
        $a.push($crate::util::json::Value::from($v));
        $crate::json_arr_internal!($a; $($rest)*);
    };
    ($a:ident; $v:expr) => {
        $a.push($crate::util::json::Value::from($v));
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_everything() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"nested": true}, "c": null, "d": "hi\n\"q\""}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(v.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(v.get("b").get("nested").as_bool(), Some(true));
        assert!(v.get("c").is_null());
        assert_eq!(v.get("d").as_str(), Some("hi\n\"q\""));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // non-ascii passthrough
        let v2 = parse("\"héllo\"").unwrap();
        assert_eq!(v2.as_str(), Some("héllo"));
    }

    #[test]
    fn errors_have_positions() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("\"\\u12\"").is_err());
    }

    #[test]
    fn json_macro() {
        let v = json!({
            "name": "dev",
            "n": 3,
            "list": [1, 2, "x"],
            "inner": {"ok": true},
            "nil": null
        });
        assert_eq!(v.get("n").as_i64(), Some(3));
        assert_eq!(v.get("list").idx(2).as_str(), Some("x"));
        assert_eq!(v.get("inner").get("ok").as_bool(), Some(true));
        assert!(v.get("nil").is_null());
        let expr = 41 + 1;
        assert_eq!(json!(expr).as_i64(), Some(42));
    }

    #[test]
    fn missing_paths_are_null() {
        let v = json!({"a": 1});
        assert!(v.get("zz").is_null());
        assert!(v.get("zz").get("deeper").is_null());
        assert!(v.idx(0).is_null());
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(json!(5).to_string(), "5");
        assert_eq!(json!(5.5).to_string(), "5.5");
        assert_eq!(json!(-1).to_string(), "-1");
    }

    #[test]
    fn defaults_helpers() {
        let v = json!({"x": 2, "s": "y", "b": true});
        assert_eq!(v.f64_or("x", 0.0), 2.0);
        assert_eq!(v.f64_or("missing", 7.0), 7.0);
        assert_eq!(v.str_or("s", "d"), "y");
        assert!(v.bool_or("b", false));
        assert_eq!(v.u64_or("missing", 9), 9);
    }

    #[test]
    fn deep_nesting() {
        let mut text = String::new();
        for _ in 0..100 {
            text.push('[');
        }
        text.push('1');
        for _ in 0..100 {
            text.push(']');
        }
        let mut v = &parse(&text).unwrap();
        for _ in 0..100 {
            v = v.idx(0);
        }
        assert_eq!(v.as_i64(), Some(1));
    }
}
