//! Figure 10 reproduction: global control-loop latency vs live futures.
//!
//! Emulates the paper's setup — 64 nodes / 128 agents and 32 nodes / 64
//! agents — then grows the future count 1K -> 131K and measures global
//! controller iterations (collect + SRTF-style policy + apply), reporting
//! the breakdown plus p50/p95/p99 per point. Paper: 464 ms at 131K futures
//! on 64 nodes, >65% in policy logic, and node-count-independence.
//!
//! Thin wrapper over [`nalar::bench::fig10`] — the same code path as
//! `nalar bench --only fig10`; writes `BENCH_fig10.json`.

use std::path::Path;

fn main() {
    let quick = std::env::var("NALAR_BENCH_QUICK").is_ok();
    let report = nalar::bench::fig10(quick).expect("fig10 reproduction failed");
    nalar::bench::validate(&report).expect("fig10 report schema");
    let path = nalar::bench::write_report(Path::new("."), "fig10", &report).expect("write report");
    println!("wrote {}", path.display());
}
