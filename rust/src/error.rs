//! Error type for the NALAR runtime.
//!
//! Per the paper's fault-tolerance stance (§5): NALAR does not mask faults;
//! failed requests are reported back to the driver with the workflow path,
//! the failing agent and the underlying cause, and the driver decides
//! whether to retry.
//!
//! The offline build has no `thiserror`/`anyhow`; `Display`, `Error` and
//! the `From` conversions are written out by hand (DESIGN.md §3).

use std::fmt;

use crate::ids::{FutureId, InstanceId};

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    /// `(future, failing instance, cause)`.
    FutureFailed(FutureId, InstanceId, String),
    FutureTimeout(FutureId, std::time::Duration),
    NoInstance(String),
    UnknownAgent(String),
    /// Admission control rejected the request at the ingress front door
    /// (`(workflow, reason, retry_rate)`). Always retryable: the request
    /// never entered the system, so the caller may back off and resubmit.
    /// `retry_rate` is the shedding token bucket's refill rate in
    /// requests/second when the shed was a rate limit (`None` for
    /// queue-full / stopped-ingress sheds) — structured data, so the
    /// `Retry-After` wire header survives any rewording of the
    /// human-readable reason.
    Shed(String, String, Option<f64>),
    /// The request's end-to-end deadline expired before (or while) a
    /// driver ran it.
    Deadline(std::time::Duration),
    /// The caller cancelled the request (`Ticket::cancel`). Terminal and
    /// NOT retryable: the caller explicitly withdrew the work, so backing
    /// off and resubmitting would resurrect what was just killed.
    Cancelled,
    InstanceKilled(InstanceId),
    Engine(String),
    Runtime(String),
    Artifact(String),
    Config(String),
    State(String),
    Io(std::io::Error),
    Json(crate::util::json::ParseError),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::FutureFailed(id, agent, cause) => {
                write!(f, "future {id} failed at {agent}: {cause}")
            }
            Error::FutureTimeout(id, after) => write!(f, "future {id} timed out after {after:?}"),
            Error::NoInstance(agent) => write!(f, "no instance available for agent type `{agent}`"),
            Error::Shed(workflow, reason, _) => {
                write!(f, "request shed at ingress for `{workflow}`: {reason}")
            }
            Error::Deadline(after) => write!(f, "request deadline expired after {after:?}"),
            Error::Cancelled => write!(f, "request cancelled by the caller"),
            Error::UnknownAgent(agent) => write!(f, "unknown agent type `{agent}`"),
            Error::InstanceKilled(i) => write!(f, "instance {i} was killed"),
            Error::Engine(e) => write!(f, "engine error: {e}"),
            Error::Runtime(e) => write!(f, "runtime (PJRT) error: {e}"),
            Error::Artifact(e) => write!(f, "artifact error: {e}"),
            Error::Config(e) => write!(f, "config error: {e}"),
            Error::State(e) => write!(f, "state error: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Self {
        Error::Json(e)
    }
}

impl From<crate::runtime::xla::Error> for Error {
    fn from(e: crate::runtime::xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }

    /// True when the driver may meaningfully retry (per-§5 semantics).
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            Error::FutureFailed(..)
                | Error::FutureTimeout(..)
                | Error::InstanceKilled(..)
                | Error::NoInstance(..)
                | Error::Shed(..)
                | Error::Deadline(..)
        )
    }

    /// The single wire-mapping authority: the HTTP status code the
    /// serving plane ([`crate::server::http`]) reports for this error.
    /// The match is exhaustive on purpose — adding a variant forces a
    /// deliberate decision here instead of a silent 500 (DESIGN.md §9).
    pub fn http_status(&self) -> u16 {
        match self {
            // The front door refused or withdrew the request.
            Error::Shed(..) => 429,
            Error::Deadline(..) => 408,
            Error::Cancelled => 409,
            // The caller's request was malformed or named unknown things.
            Error::Config(..) | Error::Json(..) | Error::UnknownAgent(..) => 400,
            // Capacity / placement faults: the service is temporarily
            // unable, the caller may back off and retry.
            Error::NoInstance(..) | Error::InstanceKilled(..) => 503,
            Error::FutureTimeout(..) => 504,
            // An upstream agent computed and failed.
            Error::FutureFailed(..) => 502,
            // Everything else is an internal fault.
            Error::Engine(..)
            | Error::Runtime(..)
            | Error::Artifact(..)
            | Error::State(..)
            | Error::Io(..)
            | Error::Msg(..) => 500,
        }
    }

    /// Suggested `Retry-After` for a [`Error::Shed`] response. Token-bucket
    /// sheds carry their refill rate as structured data on the variant
    /// (see `ingress::admission::Shed`), which inverts to one token's
    /// refill time, clamped to [1 ms, 60 s]. Queue-full and
    /// stopped-ingress sheds carry no rate; they (and every non-`Shed`
    /// error) fall back to a flat 1 s. The human-readable reason string is
    /// display-only — rewording it cannot change (or drop) this header.
    pub fn retry_after(&self) -> std::time::Duration {
        const FALLBACK: std::time::Duration = std::time::Duration::from_secs(1);
        match self {
            Error::Shed(_, _, Some(rate)) if *rate > 0.0 => {
                std::time::Duration::from_secs_f64((1.0 / rate).clamp(0.001, 60.0))
            }
            _ => FALLBACK,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_classification() {
        assert!(Error::FutureTimeout(FutureId(1), std::time::Duration::from_secs(1)).retryable());
        assert!(Error::NoInstance("x".into()).retryable());
        assert!(Error::Shed("router".into(), "queue full".into(), None).retryable());
        assert!(Error::Deadline(std::time::Duration::from_secs(3)).retryable());
        assert!(!Error::Cancelled.retryable(), "a cancel must not invite a resubmit");
        assert!(!Error::Config("bad".into()).retryable());
        assert!(!Error::Engine("x".into()).retryable());
    }

    #[test]
    fn display_includes_context() {
        let e = Error::FutureFailed(FutureId(7), InstanceId::new("dev", 1), "oom".into());
        let s = e.to_string();
        assert!(s.contains("f7") && s.contains("dev:1") && s.contains("oom"));
    }

    /// Every variant is pinned to its wire status: a new variant must
    /// extend this table (and the `http_status` match) deliberately
    /// rather than silently inheriting 500.
    #[test]
    fn http_status_covers_every_variant() {
        use std::time::Duration;
        let io = || std::io::Error::new(std::io::ErrorKind::NotFound, "x");
        let json_err = || crate::util::json::parse("{").unwrap_err();
        let table: Vec<(Error, u16)> = vec![
            (Error::FutureFailed(FutureId(1), InstanceId::new("dev", 1), "oom".into()), 502),
            (Error::FutureTimeout(FutureId(1), Duration::from_secs(1)), 504),
            (Error::NoInstance("router".into()), 503),
            (Error::UnknownAgent("router".into()), 400),
            (Error::Shed("router".into(), "queue full (8/8)".into(), None), 429),
            (Error::Deadline(Duration::from_secs(1)), 408),
            (Error::Cancelled, 409),
            (Error::InstanceKilled(InstanceId::new("dev", 1)), 503),
            (Error::Engine("x".into()), 500),
            (Error::Runtime("x".into()), 500),
            (Error::Artifact("x".into()), 500),
            (Error::Config("x".into()), 400),
            (Error::State("x".into()), 500),
            (Error::Io(io()), 500),
            (Error::Json(json_err()), 400),
            (Error::Msg("x".into()), 500),
        ];
        for (err, want) in table {
            assert_eq!(err.http_status(), want, "{err}");
        }
    }

    #[test]
    fn retry_after_inverts_the_structured_token_bucket_rate() {
        use std::time::Duration;
        let shed = |r: &str, rate: Option<f64>| Error::Shed("router".into(), r.into(), rate);
        assert_eq!(
            shed("rate limit (2.0 rps)", Some(2.0)).retry_after(),
            Duration::from_secs_f64(0.5)
        );
        assert_eq!(
            shed("tenant `hog`: rate limit (4.0 rps)", Some(4.0)).retry_after(),
            Duration::from_secs_f64(0.25)
        );
        // clamped: an absurdly slow refill caps at 60 s, a fast one
        // floors at 1 ms
        assert_eq!(shed("rate limit", Some(1e-9)).retry_after(), Duration::from_secs(60));
        assert_eq!(shed("rate limit", Some(10000.0)).retry_after(), Duration::from_millis(1));
        // no rate: flat 1 s back-off
        assert_eq!(shed("queue full (8/8)", None).retry_after(), Duration::from_secs(1));
        assert_eq!(shed("ingress stopped", None).retry_after(), Duration::from_secs(1));
        assert_eq!(Error::Cancelled.retry_after(), Duration::from_secs(1));
    }

    /// Regression (ISSUE 10): the header used to be derived by parsing
    /// `rate limit ({rate} rps)` out of the display string, so any
    /// rewording of the reason silently dropped `Retry-After`. The rate is
    /// structured data now — a reason that mentions no rate at all still
    /// yields the right header, and a reason that *looks* like the old
    /// format but carries no structured rate gets the flat fallback.
    #[test]
    fn retry_after_survives_reworded_shed_reasons() {
        use std::time::Duration;
        let reworded = Error::Shed(
            "router".into(),
            "throttled — please slow down and try again".into(),
            Some(4.0),
        );
        assert_eq!(reworded.retry_after(), Duration::from_secs_f64(0.25));
        let unstructured =
            Error::Shed("router".into(), "rate limit (4.0 rps)".into(), None);
        assert_eq!(unstructured.retry_after(), Duration::from_secs(1), "strings are display-only");
    }

    #[test]
    fn io_and_json_sources_chain() {
        let io = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(std::error::Error::source(&io).is_some());
        assert!(io.to_string().contains("gone"));
        let js = Error::from(crate::util::json::parse("{").unwrap_err());
        assert!(js.to_string().contains("json"));
    }
}
