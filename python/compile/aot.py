"""AOT compile path: lower the L2 model to HLO text + weight blob.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs (consumed by the Rust runtime, ``rust/src/runtime/``):

* ``artifacts/<entry>.hlo.txt`` — one HLO module per (phase, batch) variant.
  HLO **text** is the interchange format, not a serialized ``HloModuleProto``:
  jax >= 0.5 emits protos with 64-bit instruction ids that the ``xla``
  crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
  parser reassigns ids and round-trips cleanly.
* ``artifacts/params.bin`` — all weights, f32 little-endian, concatenated in
  :func:`compile.model.param_spec` order.
* ``artifacts/manifest.json`` — model config, weight layout, and the
  input/output signature of every entry point.

Every entry takes the weights as *leading* runtime inputs (same order for
every variant), then the data inputs. Entries are lowered with
``return_tuple=True`` so the Rust side unwraps one tuple.
"""

import argparse
import functools
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelConfig, decode, embed, flat_params, init_params, param_spec, prefill

PREFILL_BATCHES = (1, 2, 4)
DECODE_BATCHES = (1, 2, 4, 8)
EMBED_BATCHES = (1, 4)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(cfg):
    return [_spec(shape) for _, shape in param_spec(cfg)]


def _rebuild(cfg, flat):
    names = [name for name, _ in param_spec(cfg)]
    return dict(zip(names, flat))


def build_entries(cfg: ModelConfig):
    """Yield ``(name, fn(*flat_params, *data), data_specs, data_names)``."""
    n_params = len(list(param_spec(cfg)))
    t, s = cfg.max_seq, cfg.max_seq
    kv_shape = lambda b: (cfg.n_layers, 2, b, cfg.n_heads, s, cfg.head_dim)

    def prefill_fn(*args):
        params = _rebuild(cfg, args[:n_params])
        tokens, length = args[n_params:]
        return prefill(params, tokens, length, cfg)

    def decode_fn(*args):
        params = _rebuild(cfg, args[:n_params])
        token, pos, kv = args[n_params:]
        return decode(params, token, pos, kv, cfg)

    def embed_fn(*args):
        params = _rebuild(cfg, args[:n_params])
        tokens, length = args[n_params:]
        return (embed(params, tokens, length, cfg),)

    for b in PREFILL_BATCHES:
        yield (
            f"prefill_b{b}",
            prefill_fn,
            [_spec((b, t), jnp.int32), _spec((b,), jnp.int32)],
            ["tokens", "length"],
            [("logits", (b, cfg.vocab), "f32"), ("kv", kv_shape(b), "f32")],
        )
    for b in DECODE_BATCHES:
        yield (
            f"decode_b{b}",
            decode_fn,
            [_spec((b,), jnp.int32), _spec((b,), jnp.int32), _spec(kv_shape(b))],
            ["token", "pos", "kv"],
            [("logits", (b, cfg.vocab), "f32"), ("kv", kv_shape(b), "f32")],
        )
    for b in EMBED_BATCHES:
        yield (
            f"embed_b{b}",
            embed_fn,
            [_spec((b, t), jnp.int32), _spec((b,), jnp.int32)],
            ["tokens", "length"],
            [("embedding", (b, cfg.d_model), "f32")],
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    cfg = ModelConfig()
    params = init_params(cfg, seed=args.seed)
    flat = flat_params(params, cfg)

    # --- weights blob -----------------------------------------------------
    layout, offset = [], 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        layout.append({"name": name, "shape": list(shape), "offset": offset, "len": n})
        offset += n
    blob = np.concatenate([np.asarray(a, np.float32).ravel() for a in flat])
    assert blob.size == offset
    blob.tofile(out / "params.bin")

    # --- HLO variants ------------------------------------------------------
    pspecs = _param_specs(cfg)
    entries = []
    for name, fn, data_specs, data_names, outputs in build_entries(cfg):
        lowered = jax.jit(fn, keep_unused=True).lower(*pspecs, *data_specs)
        text = to_hlo_text(lowered)
        (out / f"{name}.hlo.txt").write_text(text)
        entries.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "data_inputs": [
                    {
                        "name": dn,
                        "shape": list(ds.shape),
                        "dtype": "i32" if ds.dtype == jnp.int32 else "f32",
                    }
                    for dn, ds in zip(data_names, data_specs)
                ],
                "outputs": [
                    {"name": on, "shape": list(os_), "dtype": od} for on, os_, od in outputs
                ],
            }
        )
        print(f"  lowered {name}: {len(text)} chars")

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "bos": cfg.BOS,
            "eos": cfg.EOS,
            "pad": cfg.PAD,
            "seed": args.seed,
        },
        "params_file": "params.bin",
        "param_count": offset,
        "params": layout,
        "entries": entries,
    }
    # Manifest written last: it is the Makefile's up-to-dateness witness.
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(entries)} entries + {offset} weights to {out}")


if __name__ == "__main__":
    main()
