//! The three evaluation workflows (paper §6) + the serving harness.
//!
//! Workflow drivers are ordinary Rust code over the stub API — the analog
//! of the paper's "drivers are ordinary Python" (§3.1): they call agents
//! through [`CallCtx::agent`], get futures back, branch on values, and
//! implement their own retry logic (Fig. 4 #3). NALAR never sees a static
//! graph; structure is extracted from the futures at runtime.
//!
//! Each workflow is written as a resumable state machine ([`Driver`]) so
//! an in-flight request is a stored continuation rather than a parked OS
//! thread; the blocking entry points below (`run_request`, each module's
//! `run`) are thin compat shims over [`drive_blocking`].

pub mod driver;
pub mod financial;
pub mod harness;
pub mod router;
pub mod swe;

pub use driver::{drive_blocking, driver_for, restore_driver, Driver, Step};
pub use harness::{run_open_loop, RunConfig, RunStats};

use std::time::Duration;

use crate::agents::CallCtx;
use crate::config::DeploymentConfig;
use crate::error::Result;
use crate::futures::Value;
use crate::ids::{RequestId, SessionId};
use crate::server::Deployment;
use crate::state::{ManagedDict, ManagedList};

/// Which paper workflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkflowKind {
    /// §6 Financial Analyst: stateful, human-in-the-loop, fan-out + join.
    Financial,
    /// §6 Router-based: classify then branch (chat vs coding).
    Router,
    /// §6 Software Engineering: recursive plan/implement/test with retries.
    Swe,
}

impl WorkflowKind {
    /// Parse a CLI/config name ("financial" | "router" | "swe").
    pub fn parse(s: &str) -> Option<WorkflowKind> {
        match s {
            "financial" => Some(WorkflowKind::Financial),
            "router" => Some(WorkflowKind::Router),
            "swe" => Some(WorkflowKind::Swe),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkflowKind::Financial => "financial",
            WorkflowKind::Router => "router",
            WorkflowKind::Swe => "swe",
        }
    }

    /// Reference deployment config for this workflow (sim executor; the
    /// quickstart example swaps in `pjrt`). Mirrors `configs/*.json`.
    pub fn config(&self) -> DeploymentConfig {
        let text = match self {
            WorkflowKind::Financial => configs::FINANCIAL,
            WorkflowKind::Router => configs::ROUTER,
            WorkflowKind::Swe => configs::SWE,
        };
        DeploymentConfig::from_json(text).expect("builtin config is valid")
    }
}

/// Per-request environment handed to a driver: the call context plus
/// managed-state bindings for the session.
pub struct Env {
    pub ctx: CallCtx,
    session_store: std::sync::Arc<crate::nodestore::NodeStore>,
}

impl Env {
    pub fn new(d: &Deployment, session: SessionId) -> Env {
        Self::with_ctx(d, session, d.ctx(session))
    }

    /// Environment for a request whose id was already assigned (the
    /// ingress front door stamps ids at admission).
    pub fn with_request(d: &Deployment, session: SessionId, request: RequestId) -> Env {
        Self::with_ctx(d, session, d.ctx_with(session, request))
    }

    fn with_ctx(d: &Deployment, session: SessionId, ctx: CallCtx) -> Env {
        // Migrations move `state/{session}/*` between stores (Fig. 8 step
        // 5), so the bind goes through the StoreDirectory lookup: a request
        // landing on any node observes the state wherever it currently
        // lives (home node by default, `moved` registry otherwise).
        Env { ctx, session_store: d.stores().locate_session(session) }
    }

    pub fn session(&self) -> SessionId {
        self.ctx.session
    }

    /// `managedList` bound to this session (paper §3.3).
    pub fn state_list(&self, name: &str) -> ManagedList {
        ManagedList::bind(self.session_store.clone(), self.ctx.session, name)
    }

    /// `managedDict` bound to this session.
    pub fn state_dict(&self, name: &str) -> ManagedDict {
        ManagedDict::bind(self.session_store.clone(), self.ctx.session, name)
    }
}

/// Dispatch one request through the chosen workflow driver.
pub fn run_request(
    d: &Deployment,
    kind: WorkflowKind,
    session: SessionId,
    input: &Value,
    timeout: Duration,
) -> Result<Value> {
    run_env(Env::new(d, session), kind, input, timeout)
}

/// Like [`run_request`], but keeps the request id the ingress front door
/// assigned at admission.
pub fn run_request_as(
    d: &Deployment,
    kind: WorkflowKind,
    session: SessionId,
    request: RequestId,
    input: &Value,
    timeout: Duration,
) -> Result<Value> {
    run_env(Env::with_request(d, session, request), kind, input, timeout)
}

fn run_env(env: Env, kind: WorkflowKind, input: &Value, timeout: Duration) -> Result<Value> {
    drive_blocking(driver_for(kind, input).as_mut(), &env, timeout)
}

/// Built-in deployment configs (also shipped as `configs/*.json`).
pub mod configs {
    pub const FINANCIAL: &str = r#"{
  "nodes": 2,
  "time_scale": 0.01,
  "seed": 11,
  "control": {"global_period_ms": 40, "hol_threshold_ms": 120},
  "engine": {"max_batch": 8, "executor": "sim", "kv_policy": "hint",
             "variants": [{"name": "fast", "latency_mult": 0.35, "quality": 0.82},
                          {"name": "base", "latency_mult": 1.0, "quality": 0.92},
                          {"name": "large", "latency_mult": 2.2, "quality": 0.99}]},
  "ingress": {"policy": "bounded", "schedule": "fifo", "route": "fixed",
              "queue_cap": 256, "workers": 8,
              "max_in_flight": 1024,
              "tenants": [{"name": "interactive", "weight": 2},
                          {"name": "batch", "weight": 1}]},
  "agents": [
    {"name": "stock_analysis", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.3, "mean_output_tokens": 90, "per_output_token_s": 0.01, "output_sigma": 0.5},
     "methods": ["analyze"]},
    {"name": "bond_market", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.3, "mean_output_tokens": 90, "per_output_token_s": 0.01, "output_sigma": 0.5},
     "methods": ["analyze"]},
    {"name": "market_research", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.3, "mean_output_tokens": 110, "per_output_token_s": 0.01, "output_sigma": 0.6},
     "methods": ["analyze"]},
    {"name": "web_search", "kind": "web_search", "instances": 2,
     "directives": {"max_instances": 4},
     "profile": {"base_s": 0.5},
     "methods": ["search"]},
    {"name": "analyst", "kind": "llm", "instances": 4,
     "directives": {"managed_state": true, "max_instances": 6, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.4, "mean_output_tokens": 220, "per_output_token_s": 0.012, "output_sigma": 0.8},
     "methods": ["summarize"]}
  ],
  "policies": ["load_balance", "hol_migration"]
}"#;

    pub const ROUTER: &str = r#"{
  "nodes": 2,
  "time_scale": 0.01,
  "seed": 22,
  "control": {"global_period_ms": 40, "hol_threshold_ms": 120},
  "engine": {"max_batch": 8, "executor": "sim", "kv_policy": "hint",
             "variants": [{"name": "fast", "latency_mult": 0.35, "quality": 0.82},
                          {"name": "base", "latency_mult": 1.0, "quality": 0.92},
                          {"name": "large", "latency_mult": 2.2, "quality": 0.99}]},
  "ingress": {"policy": "bounded", "schedule": "fifo", "route": "fixed",
              "queue_cap": 256, "workers": 8,
              "max_in_flight": 1024,
              "tenants": [{"name": "interactive", "weight": 2},
                          {"name": "batch", "weight": 1}]},
  "agents": [
    {"name": "router", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2, "resources": {"GPU": 0.25}},
     "profile": {"base_s": 0.05, "mean_output_tokens": 6, "per_output_token_s": 0.01, "output_sigma": 0.3},
     "methods": ["classify"]},
    {"name": "chat", "kind": "llm", "instances": 4,
     "directives": {"batchable": true, "min_instances": 1, "max_instances": 7, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.2, "mean_output_tokens": 110, "per_output_token_s": 0.009, "output_sigma": 0.6},
     "methods": ["reply"]},
    {"name": "coder", "kind": "llm", "instances": 3,
     "directives": {"batchable": true, "min_instances": 1, "max_instances": 7, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.3, "mean_output_tokens": 260, "per_output_token_s": 0.011, "output_sigma": 0.7},
     "methods": ["implement"]},
    {"name": "test_harness", "kind": "test_harness", "instances": 2,
     "directives": {"max_instances": 4},
     "profile": {"base_s": 0.3},
     "failure_rate": 0.15,
     "methods": ["unit_test"]}
  ],
  "policies": ["load_balance", "hol_migration", "resource_realloc"]
}"#;

    pub const SWE: &str = r#"{
  "nodes": 2,
  "time_scale": 0.01,
  "seed": 33,
  "control": {"global_period_ms": 40, "hol_threshold_ms": 120},
  "engine": {"max_batch": 8, "executor": "sim", "kv_policy": "hint",
             "variants": [{"name": "fast", "latency_mult": 0.35, "quality": 0.82},
                          {"name": "base", "latency_mult": 1.0, "quality": 0.92},
                          {"name": "large", "latency_mult": 2.2, "quality": 0.99}]},
  "ingress": {"policy": "bounded", "schedule": "fifo", "route": "fixed",
              "queue_cap": 256, "workers": 8,
              "max_in_flight": 1024,
              "tenants": [{"name": "interactive", "weight": 2},
                          {"name": "batch", "weight": 1}]},
  "agents": [
    {"name": "planner", "kind": "llm", "instances": 1,
     "directives": {"batchable": true, "max_instances": 2, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.3, "mean_output_tokens": 60, "per_output_token_s": 0.008, "output_sigma": 0.4},
     "methods": ["plan"]},
    {"name": "developer", "kind": "llm", "instances": 3,
     "directives": {"batchable": true, "min_instances": 1, "max_instances": 6, "resources": {"GPU": 1}},
     "profile": {"base_s": 0.4, "mean_output_tokens": 240, "per_output_token_s": 0.011, "output_sigma": 0.7},
     "methods": ["implement"]},
    {"name": "documentation", "kind": "vector_store", "instances": 2,
     "directives": {"max_instances": 4},
     "profile": {"base_s": 0.15},
     "methods": ["get", "add", "query"]},
    {"name": "web_search", "kind": "web_search", "instances": 1,
     "directives": {"max_instances": 2},
     "profile": {"base_s": 0.5},
     "methods": ["search"]},
    {"name": "test_harness", "kind": "test_harness", "instances": 2,
     "directives": {"min_instances": 1, "max_instances": 4},
     "profile": {"base_s": 0.6},
     "failure_rate": 0.35,
     "methods": ["unit_test", "integration_test"]}
  ],
  "policies": ["load_balance", "hol_migration", "resource_realloc"]
}"#;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_configs_parse_and_validate() {
        for k in [WorkflowKind::Financial, WorkflowKind::Router, WorkflowKind::Swe] {
            let cfg = k.config();
            assert!(!cfg.agents.is_empty(), "{}", k.name());
            assert!(cfg.policies.len() >= 2, "{} needs its default policies", k.name());
            // every reference deployment declares the two-tenant split
            // (interactive 2 : batch 1) the fairness quickstart uses
            assert_eq!(cfg.ingress.tenants.len(), 2, "{}", k.name());
            assert_eq!(cfg.ingress.tenants[0].name, "interactive", "{}", k.name());
            assert_eq!(cfg.ingress.tenants[0].weight, 2.0, "{}", k.name());
            assert_eq!(cfg.ingress.tenants[1].name, "batch", "{}", k.name());
        }
    }

    #[test]
    fn financial_analyst_uses_managed_state_not_batchable() {
        let cfg = WorkflowKind::Financial.config();
        let analyst = cfg.agent("analyst").unwrap();
        assert!(analyst.directives.managed_state);
        assert!(!analyst.directives.batchable, "§5: incompatible with managed state");
    }

    #[test]
    fn swe_test_harness_fails_often_enough_to_recurse() {
        let cfg = WorkflowKind::Swe.config();
        assert!(cfg.agent("test_harness").unwrap().failure_rate > 0.2);
    }
}
