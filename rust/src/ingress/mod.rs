//! Ingress: the open-loop serving front door.
//!
//! Everything before this subsystem ran workflows *closed-loop*: the
//! harness spawned one caller thread per request and each driver blocked
//! its caller — no queueing, no admission, no way to reproduce the paper's
//! capacity claim ("sustains 80 RPS where baselines fail", §6). Ingress is
//! the missing front of the pipeline:
//!
//! * [`Ingress::submit`] accepts a workflow request asynchronously,
//!   stamps its [`RequestId`]/[`SessionId`] at admission, and enqueues it
//!   into a per-workflow bounded queue instead of blocking the caller —
//!   the returned [`Ticket`] is the caller's completion handle.
//! * an [`AdmissionController`] per queue decides accept-vs-shed
//!   ([`AdmissionPolicy`]: unbounded / bounded / token bucket); shed
//!   requests fail fast with a retryable [`Error::Shed`].
//! * a **driver pool** of worker threads drains the queues onto the
//!   existing [`crate::workflow`] drivers against the [`Deployment`] —
//!   drivers still block, but on pool threads the operator sizes.
//! * queue depth and accept/shed/complete counters are pushed into the
//!   node store (`ingress/{workflow}`), where
//!   [`crate::coordinator::GlobalController::collect`] aggregates them so
//!   overload-aware policies (e.g.
//!   [`crate::coordinator::policies::OverloadProvision`]) can react.
//!
//! [`loadgen`] drives this front door with a Poisson arrival process to
//! produce the `BENCH_rps_sweep.json` saturation curve.

pub mod admission;
pub mod loadgen;

pub use admission::{AdmissionController, AdmissionPolicy};

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::IngressMetrics;
use crate::error::{Error, Result};
use crate::futures::Value;
use crate::ids::{NodeId, RequestId, SessionId};
use crate::nodestore::keys;
use crate::server::Deployment;
use crate::workflow::{run_request_as, WorkflowKind};

/// Completion slot shared between a [`Ticket`] and the worker that runs
/// the request.
struct TicketCell {
    slot: Mutex<TicketState>,
    cv: Condvar,
}

struct TicketState {
    done: bool,
    result: Option<Result<Value>>,
    /// Submit-to-completion latency, set exactly once at fulfilment.
    latency: Option<Duration>,
}

impl TicketCell {
    fn new() -> Arc<TicketCell> {
        Arc::new(TicketCell {
            slot: Mutex::new(TicketState { done: false, result: None, latency: None }),
            cv: Condvar::new(),
        })
    }

    fn fulfil(&self, result: Result<Value>, latency: Duration) {
        let mut g = self.slot.lock().unwrap();
        if !g.done {
            g.done = true;
            g.result = Some(result);
            g.latency = Some(latency);
        }
        self.cv.notify_all();
    }
}

/// The caller's handle for an admitted request. `submit` returns it
/// immediately; the request runs whenever a pool worker picks it up.
pub struct Ticket {
    pub request: RequestId,
    pub session: SessionId,
    cell: Arc<TicketCell>,
}

impl Ticket {
    /// Block until the request finishes or `timeout` passes. Consumes the
    /// result: a second `wait` after a successful one errors.
    pub fn wait(&self, timeout: Duration) -> Result<Value> {
        let deadline = Instant::now() + timeout;
        let mut g = self.cell.slot.lock().unwrap();
        loop {
            if g.done {
                return g
                    .result
                    .take()
                    .unwrap_or_else(|| Err(Error::Msg("ticket result already taken".into())));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Deadline(timeout));
            }
            let (g2, _) = self.cell.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }

    /// Submit-to-completion latency, once the request finished.
    pub fn latency(&self) -> Option<Duration> {
        self.cell.slot.lock().unwrap().latency
    }
}

/// One queued request.
struct Queued {
    session: SessionId,
    request: RequestId,
    input: Value,
    submitted: Instant,
    deadline: Instant,
    timeout: Duration,
    cell: Arc<TicketCell>,
}

/// Telemetry publish throttle — same cadence as the component
/// controllers' `maybe_push_telemetry`, so the hot path pays at most one
/// store write per queue per period instead of one per event.
const PUBLISH_PERIOD: Duration = Duration::from_millis(20);

struct IngressInner {
    d: Deployment,
    kinds: Vec<WorkflowKind>,
    /// One deque per entry of `kinds`, all under one lock (signalled by
    /// `cv`); contention is negligible at front-door rates and a single
    /// lock keeps pop-fairness across workflows trivial.
    queues: Mutex<Vec<VecDeque<Queued>>>,
    cv: Condvar,
    admission: Vec<AdmissionController>,
    completed: Vec<AtomicU64>,
    failed: Vec<AtomicU64>,
    last_publish: Vec<Mutex<Instant>>,
    stop: AtomicBool,
}

impl IngressInner {
    fn kind_index(&self, kind: WorkflowKind) -> Option<usize> {
        self.kinds.iter().position(|k| *k == kind)
    }

    /// One queue's telemetry snapshot (shared by [`Ingress::metrics`] and
    /// the node-store publish path — one construction site).
    fn snapshot(&self, idx: usize) -> IngressMetrics {
        let adm = &self.admission[idx];
        IngressMetrics {
            workflow: self.kinds[idx].name().to_string(),
            depth: self.queues.lock().unwrap()[idx].len(),
            cap: adm.policy().cap(),
            policy: adm.policy().name().to_string(),
            accepted: adm.accepted.load(Ordering::Relaxed),
            shed: adm.shed.load(Ordering::Relaxed),
            completed: self.completed[idx].load(Ordering::Relaxed),
            failed: self.failed[idx].load(Ordering::Relaxed),
        }
    }

    /// Push this queue's telemetry into the node store (node 0 hosts the
    /// front door — it is "the" ingress node of the emulated cluster).
    fn publish(&self, idx: usize) {
        let m = self.snapshot(idx);
        let key = keys::ingress(&m.workflow);
        self.d.stores().node(NodeId(0)).put(&key, m);
    }

    /// Throttled [`Self::publish`]: at most one store write per queue per
    /// [`PUBLISH_PERIOD`]. Lifecycle edges (start/stop) publish directly.
    fn maybe_publish(&self, idx: usize) {
        {
            let mut last = self.last_publish[idx].lock().unwrap();
            if last.elapsed() < PUBLISH_PERIOD {
                return;
            }
            *last = Instant::now();
        }
        self.publish(idx);
    }

    fn worker_loop(self: Arc<Self>, worker: usize) {
        let nkinds = self.kinds.len();
        let mut rot = worker; // stagger the scan start per worker
        loop {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            let popped = {
                let mut q = self.queues.lock().unwrap();
                let mut found = None;
                for i in 0..nkinds {
                    let idx = (rot + i) % nkinds;
                    if let Some(job) = q[idx].pop_front() {
                        found = Some((idx, job));
                        break;
                    }
                }
                if found.is_none() {
                    // idle: block briefly so stop/submit wake us
                    let _ = self.cv.wait_timeout(q, Duration::from_millis(2)).unwrap();
                }
                found
            };
            let Some((idx, job)) = popped else { continue };
            rot = rot.wrapping_add(1);
            let now = Instant::now();
            let result = if now >= job.deadline {
                // expired while queued: fail fast, never start the driver
                Err(Error::Deadline(job.timeout))
            } else {
                run_request_as(
                    &self.d,
                    self.kinds[idx],
                    job.session,
                    job.request,
                    &job.input,
                    job.deadline - now,
                )
            };
            match &result {
                Ok(_) => self.completed[idx].fetch_add(1, Ordering::Relaxed),
                Err(_) => self.failed[idx].fetch_add(1, Ordering::Relaxed),
            };
            job.cell.fulfil(result, job.submitted.elapsed());
            self.maybe_publish(idx);
        }
    }
}

/// See module docs.
pub struct Ingress {
    inner: Arc<IngressInner>,
    joins: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Ingress {
    /// Start a front door for `kinds` using the deployment's configured
    /// admission settings (`DeploymentConfig.ingress`).
    pub fn start(d: &Deployment, kinds: &[WorkflowKind]) -> Ingress {
        let s = &d.cfg().ingress;
        Self::start_with(d, kinds, AdmissionPolicy::from_settings(s), s.workers)
    }

    /// Start with an explicit admission policy and driver-pool size.
    pub fn start_with(
        d: &Deployment,
        kinds: &[WorkflowKind],
        policy: AdmissionPolicy,
        workers: usize,
    ) -> Ingress {
        assert!(!kinds.is_empty(), "ingress needs at least one workflow");
        let inner = Arc::new(IngressInner {
            d: d.clone(),
            kinds: kinds.to_vec(),
            queues: Mutex::new(kinds.iter().map(|_| VecDeque::new()).collect()),
            cv: Condvar::new(),
            admission: kinds.iter().map(|_| AdmissionController::new(policy.clone())).collect(),
            completed: kinds.iter().map(|_| AtomicU64::new(0)).collect(),
            failed: kinds.iter().map(|_| AtomicU64::new(0)).collect(),
            last_publish: kinds.iter().map(|_| Mutex::new(Instant::now())).collect(),
            stop: AtomicBool::new(false),
        });
        let joins = (0..workers.max(1))
            .map(|w| {
                let inner = inner.clone();
                std::thread::Builder::new()
                    .name(format!("nalar-ingress-{w}"))
                    .spawn(move || inner.worker_loop(w))
                    .expect("spawn ingress worker")
            })
            .collect();
        for idx in 0..kinds.len() {
            inner.publish(idx); // make the queue visible to policies at once
        }
        Ingress { inner, joins: Mutex::new(joins) }
    }

    /// Accept or shed one request. Non-blocking: on acceptance the request
    /// is queued and the caller gets a [`Ticket`]; on shed the caller gets
    /// a retryable [`Error::Shed`] immediately. `session: None` opens a
    /// fresh session. `timeout` is the request's end-to-end deadline,
    /// counted from admission.
    pub fn submit(
        &self,
        kind: WorkflowKind,
        session: Option<SessionId>,
        input: Value,
        timeout: Duration,
    ) -> Result<Ticket> {
        let inner = &self.inner;
        let idx = inner
            .kind_index(kind)
            .ok_or_else(|| Error::Config(format!("ingress does not serve `{}`", kind.name())))?;
        let verdict = {
            let mut q = inner.queues.lock().unwrap();
            // Checked under the queue lock: `stop` drains the queues under
            // this same lock after setting the flag, so a submit either
            // lands before the drain (and is failed by it) or observes the
            // flag here — no ticket is ever left unfulfilled.
            if inner.stop.load(Ordering::Relaxed) {
                return Err(Error::Shed(kind.name().into(), "ingress stopped".into()));
            }
            match inner.admission[idx].admit(q[idx].len()) {
                Ok(()) => {
                    let session = session.unwrap_or_else(|| inner.d.new_session());
                    let request = inner.d.new_request_id();
                    let cell = TicketCell::new();
                    let now = Instant::now();
                    q[idx].push_back(Queued {
                        session,
                        request,
                        input,
                        submitted: now,
                        deadline: now + timeout,
                        timeout,
                        cell: cell.clone(),
                    });
                    Ok(Ticket { request, session, cell })
                }
                Err(reason) => Err(Error::Shed(kind.name().into(), reason)),
            }
        };
        if verdict.is_ok() {
            inner.cv.notify_one();
        }
        inner.maybe_publish(idx);
        verdict
    }

    /// Current depth of a workflow's queue.
    pub fn depth(&self, kind: WorkflowKind) -> usize {
        match self.inner.kind_index(kind) {
            Some(idx) => self.inner.queues.lock().unwrap()[idx].len(),
            None => 0,
        }
    }

    /// Telemetry snapshot for one workflow queue (same struct the global
    /// controller aggregates).
    pub fn metrics(&self, kind: WorkflowKind) -> Option<IngressMetrics> {
        Some(self.inner.snapshot(self.inner.kind_index(kind)?))
    }

    /// Stop the pool: workers finish their in-flight request, everything
    /// still queued fails fast (reported, not masked — §5). Idempotent;
    /// also runs on drop.
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        self.inner.cv.notify_all();
        for j in self.joins.lock().unwrap().drain(..) {
            let _ = j.join();
        }
        let drained: Vec<(usize, Vec<Queued>)> = {
            let mut q = self.inner.queues.lock().unwrap();
            q.iter_mut().enumerate().map(|(i, dq)| (i, dq.drain(..).collect())).collect()
        };
        for (idx, jobs) in drained {
            for job in jobs {
                self.inner.failed[idx].fetch_add(1, Ordering::Relaxed);
                let kind = self.inner.kinds[idx].name().to_string();
                let waited = job.submitted.elapsed();
                job.cell.fulfil(Err(Error::Shed(kind, "ingress stopped".into())), waited);
            }
            self.inner.publish(idx);
        }
    }
}

impl Drop for Ingress {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn fast_router() -> Deployment {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        cfg.control.global_period_ms = 10;
        Deployment::launch(cfg).unwrap()
    }

    fn router_input() -> Value {
        json!({"prompt": "hello", "class": "chat"})
    }

    #[test]
    fn submits_complete_through_the_driver_pool() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 4);
        let timeout = Duration::from_secs(20);
        let tickets: Vec<Ticket> = (0..8)
            .map(|_| ing.submit(WorkflowKind::Router, None, router_input(), timeout).unwrap())
            .collect();
        for t in &tickets {
            let out = t.wait(timeout).unwrap();
            assert!(!out.is_null());
            assert!(t.latency().unwrap() > Duration::ZERO);
        }
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.accepted, 8);
        assert_eq!(m.completed, 8);
        assert_eq!(m.shed, 0);
        // distinct request ids were stamped at admission
        let mut ids: Vec<u64> = tickets.iter().map(|t| t.request.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn bounded_queue_sheds_fast_and_never_exceeds_cap() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.002; // slow enough that 1 worker falls behind
        let d = Deployment::launch(cfg).unwrap();
        let cap = 4;
        let ing =
            Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Bounded { cap }, 1);
        let timeout = Duration::from_secs(30);
        let mut tickets = Vec::new();
        let mut sheds = 0;
        for _ in 0..40 {
            match ing.submit(WorkflowKind::Router, None, router_input(), timeout) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    // fails fast with a retryable shed error
                    assert!(matches!(e, Error::Shed(..)), "{e}");
                    assert!(e.retryable());
                    sheds += 1;
                }
            }
            assert!(ing.depth(WorkflowKind::Router) <= cap, "bounded queue exceeded its cap");
        }
        assert!(sheds > 0, "a 1-worker pool must fall behind a 40-request burst");
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        assert_eq!(m.shed, sheds);
        assert_eq!(m.cap, cap);
        for t in &tickets {
            let _ = t.wait(timeout); // accepted work still drains
        }
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn expired_deadline_fails_fast_without_running() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let t = ing
            .submit(WorkflowKind::Router, None, router_input(), Duration::ZERO)
            .unwrap();
        let err = t.wait(Duration::from_secs(5)).unwrap_err();
        assert!(matches!(err, Error::Deadline(..)), "{err}");
        assert!(err.retryable());
        ing.stop();
        d.shutdown();
    }

    #[test]
    fn telemetry_lands_in_global_controller_view() {
        let d = fast_router();
        let ing = Ingress::start_with(
            &d,
            &[WorkflowKind::Router],
            AdmissionPolicy::Bounded { cap: 64 },
            2,
        );
        let timeout = Duration::from_secs(20);
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| ing.submit(WorkflowKind::Router, None, router_input(), timeout).unwrap())
            .collect();
        for t in &tickets {
            t.wait(timeout).unwrap();
        }
        // publishes are throttled on the hot path; stop() flushes the
        // final state, which the global controller then aggregates.
        ing.stop();
        let view = d.global().collect();
        let ingress = view
            .ingress
            .iter()
            .find(|i| i.workflow == "router")
            .expect("ingress telemetry missing from cluster view");
        assert_eq!(ingress.accepted, 4);
        assert_eq!(ingress.completed, 4);
        assert_eq!(ingress.policy, "bounded");
        assert_eq!(ingress.cap, 64);
        d.shutdown();
    }

    #[test]
    fn stop_fails_queued_work_and_rejects_new_submits() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.002;
        let d = Deployment::launch(cfg).unwrap();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let timeout = Duration::from_secs(30);
        let tickets: Vec<Ticket> = (0..10)
            .map(|_| ing.submit(WorkflowKind::Router, None, router_input(), timeout).unwrap())
            .collect();
        ing.stop();
        let failures = tickets
            .iter()
            .filter(|t| t.wait(Duration::from_secs(1)).is_err())
            .count();
        assert!(failures >= 1, "queued work must fail fast at shutdown");
        assert!(ing
            .submit(WorkflowKind::Router, None, router_input(), timeout)
            .is_err());
        d.shutdown();
    }

    #[test]
    fn unserved_workflow_is_a_config_error() {
        let d = fast_router();
        let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 1);
        let err = ing
            .submit(WorkflowKind::Swe, None, json!({"task": "t"}), Duration::from_secs(1))
            .unwrap_err();
        assert!(matches!(err, Error::Config(..)), "{err}");
        ing.stop();
        d.shutdown();
    }
}
