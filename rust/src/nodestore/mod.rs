//! Node store: the low-latency metadata + telemetry substrate (paper §4.1).
//!
//! The paper's prototype uses one Redis per node as a "telemetry-and-
//! decision broker": component-level controllers push metrics and local
//! observations *up*, the global controller writes policy updates *down*,
//! and neither side synchronizes with the other directly. This module is
//! that substrate built from scratch (substitution table, DESIGN.md §3):
//!
//! * sharded in-memory keyspace with per-key versions (optimistic reads),
//! * prefix scans (the global controller's aggregation primitive),
//! * prefix pub/sub so component controllers consume policy changes
//!   asynchronously — the global controller is never on the critical path.
//!
//! Values are `Arc<dyn Any + Send + Sync>`: control-plane structs move
//! through the store without serialization (the §Perf pass measured JSON
//! serialization dominating the Fig-10 loop; typed values removed it).

mod store;

pub use store::{NodeStore, StoreValue, Subscription};

use std::collections::HashMap;
use std::sync::Arc;

use crate::ids::{NodeId, SessionId};

/// One store per emulated node, plus a directory for cross-node access.
///
/// In the paper each node's controllers talk only to the local store while
/// the global controller reads all of them; `StoreDirectory` gives it that
/// reach. The directory also tracks where each migrated session's managed
/// state lives (`moved`), so per-request binds stay O(1) instead of
/// scanning stores on the serving hot path.
#[derive(Clone)]
pub struct StoreDirectory {
    stores: Arc<HashMap<NodeId, Arc<NodeStore>>>,
    /// Sessions whose `state/{session}/*` entries were migrated away from
    /// their home node, and where they live now.
    moved: Arc<std::sync::RwLock<HashMap<SessionId, NodeId>>>,
}

impl StoreDirectory {
    pub fn new(nodes: &[NodeId]) -> Self {
        let stores = nodes
            .iter()
            .map(|&n| (n, Arc::new(NodeStore::new())))
            .collect();
        StoreDirectory {
            stores: Arc::new(stores),
            moved: Arc::new(std::sync::RwLock::new(HashMap::new())),
        }
    }

    pub fn node(&self, node: NodeId) -> Arc<NodeStore> {
        self.stores
            .get(&node)
            .cloned()
            .unwrap_or_else(|| panic!("no store for node {node}"))
    }

    pub fn nodes(&self) -> impl Iterator<Item = (NodeId, &Arc<NodeStore>)> {
        self.stores.iter().map(|(k, v)| (*k, v))
    }

    /// A session's home node — where its managed state lives unless a
    /// migration moved it. The single source of truth for this derivation;
    /// binds, migrations and the registry all go through it.
    pub fn home_of(&self, session: SessionId) -> NodeId {
        NodeId((session.0 % self.stores.len().max(1) as u64) as u32)
    }

    /// Resolve the store that actually holds `session`'s managed state.
    ///
    /// Sessions have a home node ([`Self::home_of`]), but migrations move
    /// `state/{session}/*` entries between stores (Fig. 8 step 5), so a
    /// request landing on *any* node — in particular one dispatched by the
    /// ingress scheduler — must look the state up rather than assume the
    /// home store. O(1): one read of the moved-session registry, falling
    /// back to the home store for never-migrated sessions.
    pub fn locate_session(&self, session: SessionId) -> Arc<NodeStore> {
        match self.moved.read().unwrap().get(&session) {
            Some(node) => self.node(*node),
            None => self.node(self.home_of(session)),
        }
    }

    /// Move `session`'s managed state to `to`'s node store (resolving the
    /// current source through the registry) and record the new location so
    /// [`Self::locate_session`] keeps resolving it. This is the
    /// directory-aware form of [`crate::state::migrate_session_state`]
    /// (Fig. 8 step 5); binds racing an in-flight migration may still read
    /// the source store, as before. Returns `(entries_moved, approx_bytes)`.
    pub fn migrate_session(&self, session: SessionId, to: NodeId) -> (usize, u64) {
        let from = self.moved_to(session).unwrap_or_else(|| self.home_of(session));
        let result = if from == to {
            (0, 0)
        } else {
            crate::state::migrate_session_state(&self.node(from), &self.node(to), session)
        };
        let mut moved = self.moved.write().unwrap();
        if to == self.home_of(session) {
            moved.remove(&session); // back where locate_session defaults to
        } else {
            moved.insert(session, to);
        }
        result
    }

    /// Where `session`'s state currently lives, if it was migrated away
    /// from its home node.
    pub fn moved_to(&self, session: SessionId) -> Option<NodeId> {
        self.moved.read().unwrap().get(&session).copied()
    }

    pub fn len(&self) -> usize {
        self.stores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stores.is_empty()
    }
}

/// Canonical key layout used by the controllers.
pub mod keys {
    use crate::ids::{FutureId, InstanceId, SessionId};

    pub fn instance_metrics(i: &InstanceId) -> String {
        format!("metrics/{i}")
    }
    pub const METRICS_PREFIX: &str = "metrics/";

    pub fn policy(i: &InstanceId) -> String {
        format!("policy/{i}")
    }
    pub const POLICY_PREFIX: &str = "policy/";

    pub fn future_meta(f: FutureId) -> String {
        format!("future/{f}")
    }
    pub const FUTURE_PREFIX: &str = "future/";

    pub fn session_state(s: SessionId, key: &str) -> String {
        format!("state/{s}/{key}")
    }
    pub fn session_prefix(s: SessionId) -> String {
        format!("state/{s}/")
    }

    /// Ingress front-door telemetry, one entry per workflow queue.
    pub fn ingress(workflow: &str) -> String {
        format!("ingress/{workflow}")
    }
    pub const INGRESS_PREFIX: &str = "ingress/";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directory_per_node_isolated() {
        let dir = StoreDirectory::new(&[NodeId(0), NodeId(1)]);
        dir.node(NodeId(0)).put("k", 1u64);
        assert_eq!(dir.node(NodeId(0)).get::<u64>("k"), Some(Arc::new(1u64)));
        assert!(dir.node(NodeId(1)).get::<u64>("k").is_none());
    }

    #[test]
    #[should_panic]
    fn missing_node_panics() {
        let dir = StoreDirectory::new(&[NodeId(0)]);
        dir.node(NodeId(9));
    }

    #[test]
    fn locate_session_follows_migrated_state() {
        let dir = StoreDirectory::new(&[NodeId(0), NodeId(1)]);
        let session = SessionId(4);
        assert_eq!(dir.home_of(session), NodeId(0), "4 % 2 nodes");
        let key = keys::session_state(session, "history");
        // no migration recorded: resolve to the home store (O(1) default)
        assert!(Arc::ptr_eq(&dir.locate_session(session), &dir.node(NodeId(0))));
        dir.node(NodeId(0)).put(&key, vec![crate::json!(1)]);
        // migrate to node 1: keys move and the lookup follows
        let (moved, _bytes) = dir.migrate_session(session, NodeId(1));
        assert_eq!(moved, 1);
        assert!(!dir.node(NodeId(0)).contains(&key));
        assert!(dir.node(NodeId(1)).contains(&key));
        assert_eq!(dir.moved_to(session), Some(NodeId(1)));
        assert!(Arc::ptr_eq(&dir.locate_session(session), &dir.node(NodeId(1))));
        // migrate back home: registry entry cleared, default applies again
        dir.migrate_session(session, NodeId(0));
        assert_eq!(dir.moved_to(session), None);
        assert!(Arc::ptr_eq(&dir.locate_session(session), &dir.node(NodeId(0))));
    }
}
