//! Message types exchanged between component-level controllers.

use std::sync::Arc;

use crate::futures::{FutureCell, Value};
use crate::ids::SessionId;

/// An agent/tool invocation in flight: the shared future cell plus the
/// call arguments. The cell carries all Table-3 metadata; passing the Arc
/// is the in-process analog of sending the future's metadata over gRPC.
pub struct CallMsg {
    pub cell: Arc<FutureCell>,
    pub args: Value,
}

/// Session state + queued work transferred during migration (Fig. 8 step 5).
pub struct MigratePayload {
    pub session: SessionId,
    /// Queued (not yet running) calls being moved.
    pub calls: Vec<CallMsg>,
    /// Serialized managed state snapshot (`state/` entries).
    pub state: Vec<(String, Value)>,
    /// Approximate KV-cache bytes that move with the session (cost model).
    pub kv_bytes: u64,
}

/// Inbox protocol of a component-level controller.
pub enum Message {
    /// New invocation from a stub (Op 1 reached the executor).
    Call(CallMsg),
    /// Global-controller command (Fig. 8 step 1): hand this session's
    /// queued work + state to `to`. The component controllers coordinate
    /// the rest among themselves.
    MigrateOut { session: SessionId, to: crate::ids::InstanceId },
    /// Migration (Fig. 8 step 5): receive a session's queued work + state.
    MigrateIn(MigratePayload),
    /// Graceful stop (the `kill` primitive drains via this).
    Shutdown,
}
