//! Front-door scheduling: policy-selectable ordering for the ingress
//! ready/admission queues.
//!
//! PR 3 turned every in-flight request into a stored continuation, which
//! made the ready queue *a queue of requests the scheduler owns* — and a
//! FIFO pop is then just one policy among several. This module is the
//! ROADMAP's "order wakeups by deadline slack or graph stage" item:
//!
//! * [`SchedulePolicy::Fifo`] — arrival order (the baseline discipline).
//! * [`SchedulePolicy::DeadlineSlack`] — pop the minimum
//!   `deadline − now − estimated_remaining`: SRTF at the ingress layer.
//!   The remaining-work estimate comes from [`StageStats`], per-stage
//!   time-to-completion EWMAs learned from finished requests; until a
//!   stage has samples the estimate is zero and the policy degrades to
//!   EDF (earliest deadline first), which is already deadline-aware.
//! * [`SchedulePolicy::Stage`] — drain later-stage work first (a pure
//!   least-remaining-stages heuristic, no clock needed).
//!
//! [`pick`] is a pure function of (policy, now, keys) so ordering is unit
//! tested without threads, clocks or a deployment. The linear scan is
//! deliberate: the ready queue holds *woken* requests (typically a few),
//! not all parked ones, and a scan re-evaluates slack against a fresh
//! `now` every pop — a heap keyed at push time would act on stale slack.

use std::time::{Duration, Instant};

use crate::config::IngressSettings;

/// Which ordering the front door pops queues in. See module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    Fifo,
    DeadlineSlack,
    Stage,
}

impl SchedulePolicy {
    /// Parse a config/CLI name ("fifo" | "deadline_slack" | "stage").
    pub fn parse(s: &str) -> Option<SchedulePolicy> {
        match s {
            "fifo" => Some(SchedulePolicy::Fifo),
            "deadline_slack" => Some(SchedulePolicy::DeadlineSlack),
            "stage" => Some(SchedulePolicy::Stage),
            _ => None,
        }
    }

    /// Resolve the configured policy (`DeploymentConfig.ingress`);
    /// unknown names fall back to FIFO (config validation rejects them
    /// before a deployment ever launches).
    pub fn from_settings(s: &IngressSettings) -> SchedulePolicy {
        Self::parse(&s.schedule).unwrap_or(SchedulePolicy::Fifo)
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulePolicy::Fifo => "fifo",
            SchedulePolicy::DeadlineSlack => "deadline_slack",
            SchedulePolicy::Stage => "stage",
        }
    }
}

/// One candidate's scheduling key (position in the queue = iteration
/// order, which FIFO and all tie-breaks preserve).
#[derive(Debug, Clone, Copy)]
pub struct Key {
    pub deadline: Instant,
    pub stage: u32,
    /// Estimated time to completion from the request's current stage
    /// (`None` = no samples yet: treated as zero, i.e. EDF).
    pub est_remaining: Option<Duration>,
}

/// Signed seconds of slack: negative once the deadline passed or the
/// estimate no longer fits — the most urgent work has the least slack.
fn slack_secs(now: Instant, k: &Key) -> f64 {
    let to_deadline = if k.deadline >= now {
        k.deadline.duration_since(now).as_secs_f64()
    } else {
        -now.duration_since(k.deadline).as_secs_f64()
    };
    to_deadline - k.est_remaining.unwrap_or(Duration::ZERO).as_secs_f64()
}

/// Index of the entry `policy` pops next, or `None` on an empty queue.
/// Ties keep arrival order (the iteration order), so every policy is
/// FIFO among equals and starvation needs an actual priority inversion.
pub fn pick(
    policy: SchedulePolicy,
    now: Instant,
    mut keys: impl Iterator<Item = Key>,
) -> Option<usize> {
    match policy {
        SchedulePolicy::Fifo => keys.next().map(|_| 0),
        SchedulePolicy::DeadlineSlack => {
            let mut best: Option<(usize, f64)> = None;
            for (i, k) in keys.enumerate() {
                let s = slack_secs(now, &k);
                if best.map(|(_, b)| s < b).unwrap_or(true) {
                    best = Some((i, s));
                }
            }
            best.map(|(i, _)| i)
        }
        SchedulePolicy::Stage => {
            let mut best: Option<(usize, u32)> = None;
            for (i, k) in keys.enumerate() {
                if best.map(|(_, b)| k.stage > b).unwrap_or(true) {
                    best = Some((i, k.stage));
                }
            }
            best.map(|(i, _)| i)
        }
    }
}

/// Stages beyond this share the last bucket (no workflow here is close).
const MAX_STAGE: usize = 16;

/// EWMA weight of a new sample (recent behaviour dominates, but one
/// outlier request cannot swing the estimate).
const ALPHA: f64 = 0.2;

/// Per-workflow, per-stage time-to-completion statistics. The scheduler
/// records, for each stage a finishing request passed through, how long
/// that request still took from entering the stage; `estimate(stage)` is
/// then the learned remaining-work term of the deadline-slack key.
#[derive(Debug)]
pub struct StageStats {
    rem: Vec<Option<f64>>,
}

impl Default for StageStats {
    fn default() -> Self {
        Self::new()
    }
}

impl StageStats {
    pub fn new() -> StageStats {
        StageStats { rem: vec![None; MAX_STAGE] }
    }

    fn bucket(stage: u32) -> usize {
        (stage as usize).min(MAX_STAGE - 1)
    }

    /// A request that entered `stage` took `remaining` longer to finish.
    pub fn observe(&mut self, stage: u32, remaining: Duration) {
        let b = Self::bucket(stage);
        let x = remaining.as_secs_f64();
        self.rem[b] = Some(match self.rem[b] {
            None => x,
            Some(prev) => (1.0 - ALPHA) * prev + ALPHA * x,
        });
    }

    /// Estimated remaining time for a request currently at `stage`. Falls
    /// back to the nearest *earlier* stage with samples (an overestimate,
    /// i.e. conservative: the request looks more urgent, not less);
    /// `None` until any applicable stage has data.
    pub fn estimate(&self, stage: u32) -> Option<Duration> {
        let b = Self::bucket(stage);
        self.rem
            .iter()
            .take(b + 1)
            .rev()
            .flatten()
            .next()
            .map(|s| Duration::from_secs_f64(s.max(0.0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(deadline_in_ms: i64, stage: u32, est_ms: Option<u64>) -> (Instant, Key) {
        let now = Instant::now();
        let deadline = if deadline_in_ms >= 0 {
            now + Duration::from_millis(deadline_in_ms as u64)
        } else {
            now - Duration::from_millis((-deadline_in_ms) as u64)
        };
        (now, Key { deadline, stage, est_remaining: est_ms.map(Duration::from_millis) })
    }

    fn keys(now_anchor: Instant, specs: &[(i64, u32, Option<u64>)]) -> Vec<Key> {
        specs
            .iter()
            .map(|(d, stage, est)| Key {
                deadline: if *d >= 0 {
                    now_anchor + Duration::from_millis(*d as u64)
                } else {
                    now_anchor - Duration::from_millis((-*d) as u64)
                },
                stage: *stage,
                est_remaining: est.map(Duration::from_millis),
            })
            .collect()
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for p in [SchedulePolicy::Fifo, SchedulePolicy::DeadlineSlack, SchedulePolicy::Stage] {
            assert_eq!(SchedulePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(SchedulePolicy::parse("lifo"), None);
        let mut s = IngressSettings::default();
        assert_eq!(SchedulePolicy::from_settings(&s), SchedulePolicy::Fifo);
        s.schedule = "deadline_slack".into();
        assert_eq!(SchedulePolicy::from_settings(&s), SchedulePolicy::DeadlineSlack);
    }

    #[test]
    fn fifo_always_pops_the_front() {
        let now = Instant::now();
        let ks = keys(now, &[(500, 0, None), (1, 9, None)]);
        assert_eq!(pick(SchedulePolicy::Fifo, now, ks.into_iter()), Some(0));
        assert_eq!(pick(SchedulePolicy::Fifo, now, std::iter::empty()), None);
    }

    #[test]
    fn deadline_slack_is_edf_without_estimates() {
        let now = Instant::now();
        let ks = keys(now, &[(500, 0, None), (20, 0, None), (300, 0, None)]);
        assert_eq!(pick(SchedulePolicy::DeadlineSlack, now, ks.into_iter()), Some(1));
    }

    #[test]
    fn deadline_slack_estimates_flip_pure_edf_order() {
        let now = Instant::now();
        // The 200ms-deadline request still needs ~190ms of work (slack
        // ~10ms); the 100ms one is nearly done (slack ~95ms). Plain EDF
        // would pick index 1; slack must pick index 0.
        let ks = keys(now, &[(200, 1, Some(190)), (100, 3, Some(5))]);
        assert_eq!(pick(SchedulePolicy::DeadlineSlack, now, ks.into_iter()), Some(0));
    }

    #[test]
    fn expired_deadlines_are_most_urgent() {
        let now = Instant::now();
        let ks = keys(now, &[(50, 0, None), (-10, 0, None)]);
        assert_eq!(pick(SchedulePolicy::DeadlineSlack, now, ks.into_iter()), Some(1));
    }

    #[test]
    fn slack_ties_keep_arrival_order() {
        let (now, k) = key(100, 0, None);
        assert_eq!(pick(SchedulePolicy::DeadlineSlack, now, vec![k, k].into_iter()), Some(0));
    }

    #[test]
    fn stage_drains_later_stage_first_with_fifo_ties() {
        let now = Instant::now();
        let ks = keys(now, &[(10, 1, None), (900, 3, None), (5, 3, None), (1, 0, None)]);
        assert_eq!(pick(SchedulePolicy::Stage, now, ks.into_iter()), Some(1));
    }

    #[test]
    fn stage_stats_learn_and_fall_back_conservatively() {
        let mut st = StageStats::new();
        assert_eq!(st.estimate(0), None, "cold stats must not invent estimates");
        st.observe(1, Duration::from_millis(800));
        // Exact stage hit.
        assert_eq!(st.estimate(1), Some(Duration::from_millis(800)));
        // Stage 3 has no samples: fall back to the nearest earlier stage
        // (an overestimate — the request looks more urgent, never less).
        assert_eq!(st.estimate(3), Some(Duration::from_millis(800)));
        // Stage 0 precedes every sample: still cold.
        assert_eq!(st.estimate(0), None);
        // EWMA moves toward new samples without jumping to them.
        st.observe(1, Duration::from_millis(300));
        let e = st.estimate(1).unwrap().as_secs_f64();
        assert!(e < 0.8 && e > 0.3, "EWMA must land between old and new, got {e}");
        // Stages beyond the cap share the last bucket.
        st.observe(99, Duration::from_millis(100));
        assert_eq!(st.estimate(50), st.estimate(99));
    }
}
