//! Financial-analyst workflow under load (Fig. 9a scenario, sim engine).
//!
//! Serves the stateful analyst workflow at a configurable rate and prints
//! the Fig-9a row (avg/P50/P95/P99 in paper-equivalent seconds) plus
//! migration and KV-policy counters — NALAR vs a chosen baseline.
//!
//! Run: `cargo run --release --example financial_analyst -- --rps 4 --system nalar`

use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::server::Deployment;
use nalar::util::cli::Args;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};

fn main() -> nalar::Result<()> {
    let args = Args::from_env();
    let rps = args.f64_or("rps", 4.0);
    let secs = args.u64_or("secs", 5);
    let system = match args.str_or("system", "nalar").as_str() {
        "ayo" => SystemUnderTest::AyoLike,
        "crew" => SystemUnderTest::CrewLike,
        "autogen" => SystemUnderTest::AutoGenLike,
        _ => SystemUnderTest::Nalar,
    };

    let cfg = WorkflowKind::Financial.config();
    let scale = cfg.time_scale;
    println!(
        "== financial analyst | {} | {} wall-RPS ({:.0} paper-RPS) | {}s ==",
        system.name(),
        rps,
        rps * scale,
        secs
    );
    let d = Deployment::launch_as(cfg, system)?;

    let rc = RunConfig {
        workflow: WorkflowKind::Financial,
        rps,
        duration: Duration::from_secs(secs),
        session_pool: 32,
        request_timeout: Duration::from_secs(60),
        seed: 11,
    };
    let (stats, rec) = run_open_loop(&d, &rc);
    let paper = rec.summary_scaled(1.0 / stats.time_scale);

    println!("completed {} / failed {}", stats.completed, stats.failed);
    println!(
        "latency (paper-s): avg {:.0}  p50 {:.0}  p95 {:.0}  p99 {:.0}",
        paper.avg, paper.p50, paper.p95, paper.p99
    );
    println!("analyst load imbalance: {:.2}x", stats.imbalance);

    let view = d.global().collect();
    let (mut mig_in, mut mig_out) = (0, 0);
    for i in &view.instances {
        mig_in += i.m.migrated_in;
        mig_out += i.m.migrated_out;
    }
    println!("migrations: {mig_out} out / {mig_in} in");
    d.shutdown();
    Ok(())
}
