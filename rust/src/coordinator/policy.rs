//! The policy interface (paper §4.2, Table 2).
//!
//! Policies are programs that inspect the cluster view and invoke a small
//! set of primitives. The global controller runs them single-threaded in a
//! push-based loop: one decision-maker, one authoritative update stream;
//! enforcement happens at the component controllers.

use crate::coordinator::component::LocalOrder;
use crate::coordinator::global::ClusterView;
use crate::ids::{InstanceId, SessionId};

/// Buffered control decisions — paper Table 2.
#[derive(Debug, Clone)]
pub enum PolicyCmd {
    /// `route(session-id, agent-type, agent-instance)`.
    RouteSession { session: SessionId, agent: String, instance: InstanceId },
    /// `route(agent-type, instances, weights)`.
    RouteWeights { agent: String, weights: Vec<(InstanceId, f64)> },
    /// `set_priority(session-id, value[, agent])`.
    SetPriority { session: SessionId, priority: i32, agent: Option<String> },
    /// `migrate(session-id, current-location, destination)`.
    Migrate { session: SessionId, from: InstanceId, to: InstanceId },
    /// `kill(agent-instance)`.
    Kill(InstanceId),
    /// `provision(agent-type)`.
    Provision { agent: String },
    /// Install a local queue order at a component controller.
    InstallOrder { instance: InstanceId, order: LocalOrder },
    /// Tune the JIT model router (DESIGN.md §13): below `slack_fast_s`
    /// seconds of deadline slack a request goes urgent (fastest variant
    /// meeting the floor); above `headroom_large × estimate` it may take
    /// the largest; `quality_floor` is the minimum variant quality
    /// non-negative-slack dispatches may use.
    RouteControl { slack_fast_s: f64, headroom_large: f64, quality_floor: f64 },
}

/// The API handed to `Policy::tick` — method-per-primitive, buffering
/// commands that the global controller applies after the tick.
#[derive(Default)]
pub struct PolicyApi {
    pub(crate) cmds: Vec<PolicyCmd>,
}

impl PolicyApi {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn route(&mut self, session: SessionId, agent: &str, instance: InstanceId) {
        self.cmds.push(PolicyCmd::RouteSession { session, agent: agent.into(), instance });
    }

    pub fn route_weights(&mut self, agent: &str, weights: Vec<(InstanceId, f64)>) {
        self.cmds.push(PolicyCmd::RouteWeights { agent: agent.into(), weights });
    }

    pub fn set_priority(&mut self, session: SessionId, priority: i32) {
        self.cmds.push(PolicyCmd::SetPriority { session, priority, agent: None });
    }

    pub fn set_priority_at(&mut self, session: SessionId, priority: i32, agent: &str) {
        self.cmds.push(PolicyCmd::SetPriority { session, priority, agent: Some(agent.into()) });
    }

    pub fn migrate(&mut self, session: SessionId, from: InstanceId, to: InstanceId) {
        self.cmds.push(PolicyCmd::Migrate { session, from, to });
    }

    pub fn kill(&mut self, instance: InstanceId) {
        self.cmds.push(PolicyCmd::Kill(instance));
    }

    pub fn provision(&mut self, agent: &str) {
        self.cmds.push(PolicyCmd::Provision { agent: agent.into() });
    }

    pub fn install_order(&mut self, instance: InstanceId, order: LocalOrder) {
        self.cmds.push(PolicyCmd::InstallOrder { instance, order });
    }

    pub fn route_control(&mut self, slack_fast_s: f64, headroom_large: f64, quality_floor: f64) {
        self.cmds.push(PolicyCmd::RouteControl { slack_fast_s, headroom_large, quality_floor });
    }

    pub fn commands(&self) -> &[PolicyCmd] {
        &self.cmds
    }

    /// Consume the buffered commands (e.g. to hand to
    /// `GlobalController::apply` when driving policies by hand).
    pub fn take_commands(self) -> Vec<PolicyCmd> {
        self.cmds
    }
}

/// An operator policy. `tick` runs once per global-controller period with
/// a fresh cluster view; decisions go through the [`PolicyApi`].
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi);
}

/// Policy registry (config `policies: [...]` resolves here).
pub fn make_policy(name: &str) -> Option<Box<dyn Policy>> {
    use crate::coordinator::policies::*;
    Some(match name {
        "load_balance" => Box::new(LoadBalance::default()),
        "hol_migration" => Box::new(HolMigration::default()),
        "resource_realloc" => Box::new(ResourceRealloc::default()),
        "overload_provision" => Box::new(OverloadProvision::default()),
        "srtf" => Box::new(Srtf::default()),
        "lpt" => Box::new(Lpt::default()),
        "fcfs" => Box::new(Fcfs),
        "jit_route" => Box::new(JitRoute::default()),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn api_buffers_commands_in_order() {
        let mut api = PolicyApi::new();
        api.set_priority(SessionId(1), 10);
        api.migrate(SessionId(1), InstanceId::new("a", 0), InstanceId::new("a", 1));
        api.provision("dev");
        assert_eq!(api.commands().len(), 3);
        assert!(matches!(api.commands()[0], PolicyCmd::SetPriority { priority: 10, .. }));
        assert!(matches!(api.commands()[2], PolicyCmd::Provision { .. }));
    }

    #[test]
    fn registry_resolves_known_policies() {
        for p in [
            "load_balance",
            "hol_migration",
            "resource_realloc",
            "overload_provision",
            "srtf",
            "lpt",
            "fcfs",
            "jit_route",
        ] {
            assert!(make_policy(p).is_some(), "{p} missing");
        }
        assert!(make_policy("nope").is_none());
    }
}
