//! Socket-level integration tests for the HTTP serving plane: a real
//! `HttpServer` bound to an ephemeral port, driven through `HttpClient`
//! round trips and raw `TcpStream` abuse. These prove the wire contract
//! end to end — admission headers, park/poll/cancel lifecycle, the
//! status-code mapping (`429` + `Retry-After`, `408`, `409`, `404`) —
//! and the operational invariants: malformed or abandoned connections
//! never panic a worker, never leak an in-flight slot, and `stop()`
//! reports zero open connections once every client is gone.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use nalar::ingress::{AdmissionPolicy, Ingress, SchedulerOpts};
use nalar::server::http::{HttpClient, HttpResponse, HttpServer};
use nalar::server::Deployment;
use nalar::workflow::WorkflowKind;

/// Router deployment + ingress + HTTP server on an ephemeral port.
/// Capacity policies stay out (a reallocation kill would fail futures
/// retryably, orthogonal to the wire contract).
fn serve(
    time_scale: f64,
    admission: AdmissionPolicy,
    workers: usize,
    max_in_flight: usize,
) -> (Deployment, Arc<Ingress>, HttpServer) {
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = time_scale;
    cfg.control.global_period_ms = 10;
    cfg.policies = vec!["load_balance".into()];
    let d = Deployment::launch(cfg).unwrap();
    let ing = Arc::new(Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router],
        admission,
        SchedulerOpts::new(workers, max_in_flight),
    ));
    let srv = HttpServer::start(&d, ing.clone(), &[WorkflowKind::Router], "127.0.0.1:0").unwrap();
    (d, ing, srv)
}

/// Block (wall clock, bounded) until `cond` holds.
fn settle(what: &str, cond: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(10), "timed out settling: {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Poll `GET /v1/requests/{id}` until it stops answering `202 running`.
fn poll_until_terminal(c: &mut HttpClient, id: u64) -> HttpResponse {
    let t0 = Instant::now();
    loop {
        let r = c.request("GET", &format!("/v1/requests/{id}"), &[], "").unwrap();
        if r.status != 202 {
            return r;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "request {id} never became terminal");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Park a submit (`X-Nalar-Wait: 0`) and return the assigned request id.
fn park(c: &mut HttpClient, deadline_ms: &str) -> u64 {
    let r = c
        .request(
            "POST",
            "/v1/workflows/router/requests",
            &[("x-nalar-wait", "0"), ("x-nalar-deadline-ms", deadline_ms)],
            r#"{"prompt": "park me", "class": "chat"}"#,
        )
        .unwrap();
    assert_eq!(r.status, 202, "park submit must answer 202: {}", r.body);
    let v = r.json().unwrap();
    assert_eq!(v.get("status").as_str(), Some("accepted"));
    v.get("request").as_u64().expect("202 carries the request id")
}

/// Tear down in the documented order and assert the clean-shutdown gate.
fn teardown(d: Deployment, ing: Arc<Ingress>, srv: HttpServer) {
    settle("connections close", || srv.open_connections() == 0);
    assert_eq!(srv.stop(), 0, "no connection may survive stop()");
    ing.stop();
    d.shutdown();
}

#[test]
fn sync_post_round_trips_the_result_and_metrics_report_it() {
    let (d, ing, srv) = serve(0.002, AdmissionPolicy::Unbounded, 2, 64);
    let mut c = HttpClient::new(srv.addr().to_string());

    let health = c.request("GET", "/healthz", &[], "").unwrap();
    assert_eq!(health.status, 200);

    let r = c
        .request(
            "POST",
            "/v1/workflows/router/requests",
            &[("x-nalar-deadline-ms", "60000")],
            r#"{"prompt": "classify me", "class": "chat"}"#,
        )
        .unwrap();
    assert_eq!(r.status, 200, "sync submit waits for the result: {}", r.body);
    let v = r.json().unwrap();
    assert!(v.get("request").as_u64().is_some(), "response carries the request id");
    assert!(!v.get("result").is_null(), "response carries the workflow output");
    assert!(v.get("latency_ms").as_f64().is_some());

    let m = c.request("GET", "/metrics", &[], "").unwrap();
    assert_eq!(m.status, 200);
    let mv = m.json().unwrap();
    assert!(mv.get("time_scale").as_f64().is_some());
    assert!(mv.get("open_connections").as_u64().is_some());
    let ingress = mv.get("ingress").as_arr().expect("per-workflow snapshots").clone();
    let router = ingress
        .iter()
        .find(|e| e.get("workflow").as_str() == Some("router"))
        .expect("router snapshot");
    assert_eq!(router.get("completed").as_u64(), Some(1));
    assert!(router.get("tenants").as_arr().is_some_and(|t| !t.is_empty()));

    teardown(d, ing, srv);
}

#[test]
fn park_poll_and_delete_follow_the_ticket_lifecycle() {
    // One worker, one in-flight slot, slow service: submits after the
    // first queue deterministically, so a DELETE can land pre-start.
    let (d, ing, srv) = serve(0.1, AdmissionPolicy::Unbounded, 1, 1);
    let mut c = HttpClient::new(srv.addr().to_string());

    let r1 = park(&mut c, "120000");
    let r2 = park(&mut c, "120000");
    let r3 = park(&mut c, "120000");

    // r3 is still queued behind r1 (in flight) and r2: cancel delivers.
    let del = c.request("DELETE", &format!("/v1/requests/{r3}"), &[], "").unwrap();
    assert_eq!(del.status, 200, "queued request must be cancellable: {}", del.body);
    let gone = c.request("GET", &format!("/v1/requests/{r3}"), &[], "").unwrap();
    assert_eq!(gone.status, 404, "a delivered DELETE consumes the parked ticket");

    // r1 completes; its terminal GET consumes the registry entry.
    let done = poll_until_terminal(&mut c, r1);
    assert_eq!(done.status, 200, "{}", done.body);
    assert_eq!(done.json().unwrap().get("request").as_u64(), Some(r1));
    let again = c.request("GET", &format!("/v1/requests/{r1}"), &[], "").unwrap();
    assert_eq!(again.status, 404, "a delivered result consumes the parked ticket");
    assert_eq!(poll_until_terminal(&mut c, r2).status, 200);

    // Cancel-after-completion: park r4, wait (via /metrics) for it to
    // finish unpolled, then DELETE — 409, and the result stays claimable.
    let r4 = park(&mut c, "120000");
    settle("r4 completes server-side", || {
        ing.metrics(WorkflowKind::Router).unwrap().completed >= 3
    });
    let late = c.request("DELETE", &format!("/v1/requests/{r4}"), &[], "").unwrap();
    assert_eq!(late.status, 409, "cancel after completion reports the lost race");
    let res = c.request("GET", &format!("/v1/requests/{r4}"), &[], "").unwrap();
    assert_eq!(res.status, 200, "a failed cancel must not eat the result");

    // Unknown ids: both verbs answer 404.
    assert_eq!(c.request("GET", "/v1/requests/999999999", &[], "").unwrap().status, 404);
    assert_eq!(c.request("DELETE", "/v1/requests/999999999", &[], "").unwrap().status, 404);

    // Exactly one terminal outcome each: 3 completed + 1 cancelled.
    settle("counters agree", || {
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        m.completed == 3 && m.cancelled == 1 && m.failed == 0 && m.in_flight == 0 && m.depth == 0
    });
    teardown(d, ing, srv);
}

#[test]
fn wire_statuses_map_sheds_deadlines_and_bad_requests() {
    // Token bucket: one burst token, then sheds — the 429 contract.
    let (d, ing, srv) =
        serve(0.1, AdmissionPolicy::TokenBucket { rate: 2.0, burst: 1.0 }, 1, 8);
    let mut c = HttpClient::new(srv.addr().to_string());
    let _admitted = park(&mut c, "120000");
    let shed = c
        .request(
            "POST",
            "/v1/workflows/router/requests",
            &[("x-nalar-wait", "0"), ("x-nalar-deadline-ms", "120000")],
            r#"{"prompt": "shed me", "class": "chat"}"#,
        )
        .unwrap();
    assert_eq!(shed.status, 429, "an empty token bucket sheds: {}", shed.body);
    let retry: u64 = shed
        .header("retry-after")
        .expect("429 must carry Retry-After")
        .parse()
        .expect("Retry-After is whole seconds");
    assert!(retry >= 1, "ceil(1/rate) at rate 2.0 is 1s");
    let sv = shed.json().unwrap();
    assert_eq!(sv.get("retryable").as_bool(), Some(true), "sheds are retryable");

    // 408: a 1ms deadline expires before the slow service finishes; the
    // synchronous POST maps the scheduler's Deadline error onto the wire.
    let expired = c
        .request(
            "POST",
            "/v1/workflows/router/requests",
            &[("x-nalar-deadline-ms", "1")],
            r#"{"prompt": "too slow", "class": "chat"}"#,
        )
        .unwrap();
    assert_eq!(expired.status, 408, "{}", expired.body);

    // Client errors: bad deadline header, non-JSON body, unknown
    // workflow kind, method not allowed.
    let bad_hdr = c
        .request(
            "POST",
            "/v1/workflows/router/requests",
            &[("x-nalar-deadline-ms", "zero")],
            "{}",
        )
        .unwrap();
    assert_eq!(bad_hdr.status, 400);
    let bad_body = c
        .request("POST", "/v1/workflows/router/requests", &[], "not json")
        .unwrap();
    assert_eq!(bad_body.status, 400);
    let unknown = c.request("POST", "/v1/workflows/nope/requests", &[], "{}").unwrap();
    assert_eq!(unknown.status, 404);
    let bad_method = c.request("POST", "/metrics", &[], "{}").unwrap();
    assert_eq!(bad_method.status, 405);

    // The shed/expiry traffic drains fully before teardown.
    settle("tables drain", || {
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        m.in_flight == 0 && m.depth == 0
    });
    teardown(d, ing, srv);
}

#[test]
fn trace_endpoint_tracks_the_request_lifetime_and_prom_exposes() {
    // One slow worker so the parked request is observably running when
    // the first trace GET lands.
    let (d, ing, srv) = serve(0.1, AdmissionPolicy::Unbounded, 1, 1);
    let mut c = HttpClient::new(srv.addr().to_string());

    let id = park(&mut c, "120000");

    // Running: the timeline already holds the admission events (recorded
    // before the 202 was written), plus the stage decomposition so far.
    let live = c.request("GET", &format!("/v1/requests/{id}/trace"), &[], "").unwrap();
    assert_eq!(live.status, 200, "a running request has a trace: {}", live.body);
    let lv = live.json().unwrap();
    assert_eq!(lv.get("request").as_u64(), Some(id));
    let kinds: Vec<String> = lv
        .get("events")
        .as_arr()
        .expect("events array")
        .iter()
        .map(|e| e.get("kind").as_str().unwrap().to_string())
        .collect();
    assert!(kinds.first().is_some_and(|k| k == "admitted"), "{kinds:?}");
    assert!(kinds.contains(&"queued".to_string()), "{kinds:?}");
    assert!(lv.get("stages").get("total_ns").as_u64().is_some());

    // Terminal but unconsumed: the trace persists and ends in `done`.
    settle("request completes server-side", || {
        ing.metrics(WorkflowKind::Router).unwrap().completed >= 1
    });
    let done = c.request("GET", &format!("/v1/requests/{id}/trace"), &[], "").unwrap();
    assert_eq!(done.status, 200, "{}", done.body);
    let dv = done.json().unwrap();
    let last = dv.get("events").as_arr().unwrap().last().cloned().expect("events");
    assert_eq!(last.get("kind").as_str(), Some("done"), "terminal event recorded");
    let stages = dv.get("stages");
    let parts = stages.get("queue_wait_ns").as_u64().unwrap()
        + stages.get("sched_delay_ns").as_u64().unwrap()
        + stages.get("poll_ns").as_u64().unwrap()
        + stages.get("future_wait_ns").as_u64().unwrap();
    assert_eq!(
        Some(parts),
        stages.get("total_ns").as_u64(),
        "additive stages partition the timeline"
    );

    // Consuming the result evicts the trace with the registry entry.
    assert_eq!(poll_until_terminal(&mut c, id).status, 200);
    let gone = c.request("GET", &format!("/v1/requests/{id}/trace"), &[], "").unwrap();
    assert_eq!(gone.status, 404, "result consumption evicts the trace");
    assert_eq!(
        c.request("GET", "/v1/requests/zzz/trace", &[], "").unwrap().status,
        400,
        "non-integer ids are client errors"
    );

    // The Prometheus rendering of the same counters, behind ?format=prom.
    let prom = c.request("GET", "/metrics?format=prom", &[], "").unwrap();
    assert_eq!(prom.status, 200);
    assert!(
        prom.header("content-type").is_some_and(|ct| ct.starts_with("text/plain")),
        "prom exposition is text, not JSON"
    );
    for line in prom.body.lines() {
        assert!(line.starts_with("# ") || line.starts_with("nalar_"), "bad line: {line}");
    }
    assert!(
        prom.body
            .contains("nalar_ingress_completed_total{workflow=\"router\",tenant=\"default\"} 1"),
        "{}",
        prom.body
    );
    assert!(prom.body.contains("nalar_stage_latency_seconds{workflow=\"router\""));
    // the JSON document still answers on the bare path
    assert_eq!(c.request("GET", "/metrics", &[], "").unwrap().status, 200);

    teardown(d, ing, srv);
}

// --------------------------------------------------------- raw sockets

fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

/// Read one HTTP response off a raw socket: status code + body.
fn read_response(s: &mut TcpStream) -> (u16, String) {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 1024];
    let head_end = loop {
        if let Some(i) = find(&buf, b"\r\n\r\n") {
            break i;
        }
        let n = s.read(&mut tmp).unwrap();
        assert!(n > 0, "server closed before a full response head");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    let clen: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            if k.trim().eq_ignore_ascii_case("content-length") {
                v.trim().parse().ok()
            } else {
                None
            }
        })
        .unwrap_or(0);
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < clen {
        let n = s.read(&mut tmp).unwrap();
        assert!(n > 0, "server closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    (status, String::from_utf8_lossy(&body[..clen]).to_string())
}

#[test]
fn raw_socket_abuse_never_panics_or_leaks() {
    let (d, ing, srv) = serve(0.002, AdmissionPolicy::Unbounded, 2, 64);
    let addr = srv.addr();

    // Garbage request line: one 400, then the server closes the socket.
    let mut garbage = TcpStream::connect(addr).unwrap();
    garbage.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    garbage.write_all(b"NOT-AN-HTTP-LINE\r\n\r\n").unwrap();
    assert_eq!(read_response(&mut garbage).0, 400);
    drop(garbage);

    // Oversized headers: the server answers 431 without waiting for a
    // terminator and closes. It may close with some of our flood still
    // unread (an RST that can discard the response in flight), so accept
    // a reset too — the parser unit tests pin the 431 itself; this path
    // proves no panic and no leak.
    let mut oversized = TcpStream::connect(addr).unwrap();
    oversized.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = oversized.write_all(b"GET /healthz HTTP/1.1\r\nx-big: ");
    let _ = oversized.write_all(&vec![b'a'; 20 << 10]);
    let mut flood_reply = Vec::new();
    let _ = oversized.read_to_end(&mut flood_reply);
    if !flood_reply.is_empty() {
        assert!(
            flood_reply.starts_with(b"HTTP/1.1 431"),
            "oversized headers answer 431, got: {}",
            String::from_utf8_lossy(&flood_reply[..flood_reply.len().min(64)])
        );
    }
    drop(oversized);

    // Abrupt disconnect mid-body: nothing was submitted, nothing leaks.
    let mut abandoned = TcpStream::connect(addr).unwrap();
    abandoned
        .write_all(
            b"POST /v1/workflows/router/requests HTTP/1.1\r\ncontent-length: 100\r\n\r\n{\"pro",
        )
        .unwrap();
    drop(abandoned);

    // Pipelined requests on one socket: both answered, in order.
    let mut pipelined = TcpStream::connect(addr).unwrap();
    pipelined.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    pipelined
        .write_all(b"GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\n\r\n")
        .unwrap();
    let (s1, _) = read_response(&mut pipelined);
    let (s2, body2) = read_response(&mut pipelined);
    assert_eq!((s1, s2), (200, 200), "pipelined requests are served in sequence");
    assert!(body2.contains("ingress"), "second response is the metrics document");
    drop(pipelined);

    // The abuse left no half-admitted work and no open connection.
    let m = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!((m.in_flight, m.depth), (0, 0), "no in-flight slot may leak");
    assert_eq!(m.accepted, 0, "none of the abuse reached admission");
    teardown(d, ing, srv);
}
