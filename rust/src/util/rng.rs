//! Deterministic PRNG + distributions (rand/rand_distr substitute).
//!
//! xoshiro256++ seeded via SplitMix64. Distributions cover what the
//! workload generators need: uniform, exponential inter-arrivals,
//! lognormal token counts, Poisson, Zipf session popularity, and
//! Box-Muller gaussians. All workloads are reproducible from a single
//! `seed` in the deployment config.

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed (recommended by the authors).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Independent stream for a sub-component (stable derivation).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method.
        if n == 0 {
            return 0;
        }
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [a, b).
    pub fn range(&mut self, a: f64, b: f64) -> f64 {
        a + (b - a) * self.f64()
    }

    pub fn range_usize(&mut self, a: usize, b: usize) -> usize {
        debug_assert!(b > a);
        a + self.below((b - a) as u64) as usize
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller).
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (mean = 1/rate). Inter-arrival
    /// times of a Poisson arrival process.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Lognormal parameterized by the *mean* of the distribution and the
    /// sigma of the underlying normal (how workloads state token counts).
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.gauss()).exp()
    }

    /// Poisson via Knuth (small lambda) / normal approximation (large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            (lambda + lambda.sqrt() * self.gauss()).round().max(0.0) as u64
        }
    }

    /// Zipf over {0..n-1} with exponent `s` (session popularity skew).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on the harmonic weights; O(n) setup avoided by
        // rejection-free scan (n is small in our workloads).
        let h: f64 = (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= 1.0 / (k as f64).powf(s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(1);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn lognormal_mean_matches() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.lognormal_mean(120.0, 0.6)).sum::<f64>() / n as f64;
        assert!((mean - 120.0).abs() < 4.0, "{mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(5);
        for lambda in [3.0, 80.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < lambda * 0.05, "λ={lambda} got {mean}");
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(6);
        let mut counts = [0u32; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[0] > 2 * counts[9]);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(10);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
