//! Financial-analyst workflow (paper §6, Fig. 9a).
//!
//! An analyst agent fans out to stock / bond / market-research agents and
//! a web/news search, then summarizes for the user. Sessions are stateful
//! — the user issues follow-ups after long delays, and the summary history
//! lives in a `managedList` so NALAR (not the developer) owns its
//! placement; the analyst's KV cache makes session placement matter.

use std::time::Duration;

use crate::error::Result;
use crate::futures::Value;
use crate::json;
use crate::workflow::Env;

const ANALYSTS: [&str; 3] = ["stock_analysis", "bond_market", "market_research"];

/// One user request (initial question or follow-up) through the workflow.
pub fn run(env: &Env, input: &Value, timeout: Duration) -> Result<Value> {
    let question = input.get("question").as_str().unwrap_or("market update");
    // Generation budget: small in PJRT quickstarts (so multi-turn sessions
    // fit the model context and KV reuse shows), full-size in sim runs.
    let max_new = input.get("max_new").as_usize().unwrap_or(128);

    // Fan out to the specialist agents + web search — all futures, all
    // non-blocking (Op 1); the driver blocks only when joining.
    let specialists: Vec<_> = ANALYSTS
        .iter()
        .map(|a| {
            env.ctx.agent(a).call(
                "analyze",
                json!({"prompt": question, "max_new_tokens": max_new.min(96)}),
            )
        })
        .collect();
    let web = env
        .ctx
        .agent("web_search")
        .call("search", json!({"query": question}));

    // Join. Specialist failures are fatal (retryable by the caller); a web
    // failure degrades gracefully — exactly the "driver decides" model.
    let mut parts: Vec<String> = Vec::new();
    for f in &specialists {
        let v = f.value(timeout)?;
        parts.push(v.get("text").as_str().unwrap_or_default().to_string());
    }
    let web_part = web
        .value(timeout)
        .map(|v| v.to_string())
        .unwrap_or_else(|_| "[web search unavailable]".into());

    // Session history: managed state, not driver-managed placement (§3.3).
    let history = env.state_list("history");
    let history_tokens = 48 * history.len(); // prior summaries in the KV context

    let deps: Vec<_> = specialists.iter().map(|f| f.id()).collect();
    let summary = env.ctx.deeper().agent("analyst").call_with(
        "summarize",
        json!({
            "prompt": format!("{question}\n{}\n{web_part}", parts.join("\n")),
            "max_new_tokens": max_new,
            "history_tokens": history_tokens,
        }),
        &deps,
        0,
    );
    let out = summary.value(timeout)?;

    history.push(json!({
        "question": question,
        "summary": out.get("text").as_str().unwrap_or_default(),
    }));

    Ok(json!({
        "summary": out.get("text").as_str().unwrap_or_default(),
        "kv": out.get("kv").as_str().unwrap_or(""),
        "turn": history.len(),
        "specialists": parts.len(),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Deployment;
    use crate::workflow::WorkflowKind;

    #[test]
    fn end_to_end_with_followup() {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.time_scale = 0.0005; // fast test
        let d = Deployment::launch(cfg).unwrap();
        let session = d.new_session();
        let timeout = Duration::from_secs(20);

        let env = Env::new(&d, session);
        let out = run(&env, &json!({"question": "How did FCF change?"}), timeout).unwrap();
        assert_eq!(out.get("turn").as_i64(), Some(1));
        assert_eq!(out.get("specialists").as_i64(), Some(3));

        // follow-up in the same session sees the history
        let env2 = Env::new(&d, session);
        let out2 = run(&env2, &json!({"question": "break that down"}), timeout).unwrap();
        assert_eq!(out2.get("turn").as_i64(), Some(2));
        d.shutdown();
    }

    #[test]
    fn sessions_are_sticky_on_analyst() {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let session = d.new_session();
        let timeout = Duration::from_secs(20);
        for _ in 0..2 {
            let env = Env::new(&d, session);
            run(&env, &json!({"question": "q"}), timeout).unwrap();
        }
        // managed-state agent => session pinned to one instance
        assert!(d.router().sticky_of(session, "analyst").is_some());
        d.shutdown();
    }
}
