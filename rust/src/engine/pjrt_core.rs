//! Real-compute engine core over the AOT artifacts.
//!
//! Continuous batching against the compiled decode variants: active
//! sequences keep their own [`SeqKv`]; each `step` scatters them into a
//! batched KV tensor, runs one decode, and gathers back. Session
//! continuation reuses the saved KV (incremental decode of the new prompt
//! tokens) when the KV manager reports a hit; a miss re-prefills the whole
//! context — the recompute penalty NALAR's hint policy exists to avoid.

use std::collections::HashMap;
use std::sync::Arc;

use crate::engine::tokenizer::{argmax, Tokenizer};
use crate::engine::{EngineCore, EngineDone, EngineReq, GenOut};
use crate::error::{Error, Result};
use crate::ids::SessionId;
use crate::runtime::{KvBatch, PjrtModel, SeqKv};
use crate::state::kvcache::{KvCacheManager, Residency};

struct ActiveSeq {
    tag: u64,
    session: SessionId,
    kv: SeqKv,
    /// Prompt tokens not yet fed (incremental prefill via decode steps).
    pending_prompt: Vec<i32>,
    last_token: i32,
    generated: Vec<i32>,
    prompt_tokens: usize,
    max_new: usize,
    kv_outcome: &'static str,
}

/// See module docs.
pub struct PjrtCore {
    model: PjrtModel,
    tok: Tokenizer,
    kv_mgr: Arc<KvCacheManager>,
    active: Vec<ActiveSeq>,
    /// Saved per-session caches for continuation (the engine-side KV pool;
    /// residency accounting lives in `kv_mgr`).
    saved: HashMap<SessionId, (SeqKv, Vec<i32>)>, // (kv, full token history)
    max_batch: usize,
}

impl PjrtCore {
    pub fn new(model: PjrtModel, kv_mgr: Arc<KvCacheManager>) -> Self {
        let dims = model.dims();
        PjrtCore {
            tok: Tokenizer::new(&dims),
            max_batch: 8.min(dims.max_seq), // decode variants go up to b8
            model,
            kv_mgr,
            active: Vec::new(),
            saved: HashMap::new(),
        }
    }

    /// Prefill a fresh (or evicted) context and activate the sequence.
    fn start_fresh(
        &mut self,
        req: &EngineReq,
        tokens: Vec<i32>,
        kv_outcome: &'static str,
    ) -> Result<()> {
        let out = self.model.prefill(&[tokens.clone()])?;
        let dims = self.model.dims();
        let kv = out.kv.gather(&dims, 0, tokens.len());
        let first = argmax(&out.logits[0]);
        self.active.push(ActiveSeq {
            tag: req.tag,
            session: req.session,
            kv,
            pending_prompt: Vec::new(),
            last_token: first,
            generated: vec![first],
            prompt_tokens: tokens.len(),
            max_new: req.max_new_tokens,
            kv_outcome,
        });
        Ok(())
    }
}

impl EngineCore for PjrtCore {
    fn admit(&mut self, req: EngineReq) {
        let dims = self.model.dims();
        let reserve = req.max_new_tokens.min(dims.max_seq / 2) + 1;
        let new_tokens: Vec<i32> = self.tok.encode(&req.prompt, reserve);

        let result: Result<()> = (|| {
            match self.saved.remove(&req.session) {
                Some((kv, history))
                    if history.len() + new_tokens.len() < dims.max_seq - reserve =>
                {
                    let ctx_bytes = dims.kv_bytes_per_seq();
                    let residency =
                        self.kv_mgr.ensure_resident(req.session, ctx_bytes, history.len() as u32);
                    match residency {
                        Residency::Hit | Residency::Promoted { .. } => {
                            // Incremental: feed only the new prompt tokens.
                            self.active.push(ActiveSeq {
                                tag: req.tag,
                                session: req.session,
                                kv,
                                // skip BOS: already in the saved context
                                pending_prompt: new_tokens[1..].to_vec(),
                                last_token: *new_tokens.get(1).unwrap_or(&dims.bos),
                                generated: Vec::new(),
                                prompt_tokens: new_tokens.len(),
                                max_new: req.max_new_tokens,
                                kv_outcome: "hit",
                            });
                            Ok(())
                        }
                        Residency::Miss => {
                            // Evicted: recompute history + prompt.
                            let mut full = history;
                            full.extend_from_slice(&new_tokens[1..]);
                            full.truncate(dims.max_seq - reserve);
                            self.start_fresh(&req, full, "miss")
                        }
                    }
                }
                _ => {
                    self.kv_mgr.ensure_resident(
                        req.session,
                        dims.kv_bytes_per_seq(),
                        new_tokens.len() as u32,
                    );
                    self.start_fresh(&req, new_tokens, "miss")
                }
            }
        })();
        if let Err(e) = result {
            // surface as a completed-failed sequence on the next step
            self.active.push(ActiveSeq {
                tag: req.tag,
                session: req.session,
                kv: SeqKv::zeros(&self.model.dims()),
                pending_prompt: Vec::new(),
                last_token: self.model.dims().eos,
                generated: Vec::new(),
                prompt_tokens: 0,
                max_new: 0,
                kv_outcome: "error",
            });
            let _ = e; // detailed error reported at completion below
        }
    }

    fn step(&mut self) -> Vec<EngineDone> {
        let mut completions = Vec::new();
        if self.active.is_empty() {
            return completions;
        }
        let dims = self.model.dims();
        let b = self.active.len().min(self.max_batch);

        // Assemble the batch.
        let mut kvb = KvBatch::zeros(&dims, b);
        let mut token = Vec::with_capacity(b);
        let mut pos = Vec::with_capacity(b);
        for (slot, seq) in self.active.iter().take(b).enumerate() {
            kvb.scatter(&dims, slot, &seq.kv);
            // If prompt tokens remain, feed the next one; else feed the
            // last generated token.
            let t = seq.pending_prompt.first().copied().unwrap_or(seq.last_token);
            token.push(t);
            pos.push(seq.kv.pos as i32);
        }

        let out = match self.model.decode(&token, &pos, kvb) {
            Ok(o) => o,
            Err(e) => {
                // Fail the whole batch (engine fault, §5: report upward).
                for seq in self.active.drain(..b) {
                    completions.push(EngineDone {
                        tag: seq.tag,
                        session: seq.session,
                        result: Err(Error::Engine(format!("decode failed: {e}"))),
                    });
                }
                return completions;
            }
        };

        // Scatter results back; collect completions.
        let mut idx = 0;
        let mut slot = 0;
        while idx < self.active.len() && slot < b {
            let seq = &mut self.active[idx];
            seq.kv = out.kv.gather(&dims, slot, seq.kv.pos + 1);
            let next = argmax(&out.logits[slot]);
            if !seq.pending_prompt.is_empty() {
                // consumed one prompt token; generation starts after the last
                seq.pending_prompt.remove(0);
                if seq.pending_prompt.is_empty() {
                    seq.generated.push(next);
                    seq.last_token = next;
                }
            } else {
                seq.generated.push(next);
                seq.last_token = next;
            }
            slot += 1;

            let ctx_full = seq.kv.pos + 2 >= dims.max_seq;
            let finished = seq.kv_outcome == "error"
                || (seq.pending_prompt.is_empty()
                    && (seq.generated.len() >= seq.max_new
                        || seq.last_token == dims.eos
                        || ctx_full));
            if finished {
                let seq = self.active.remove(idx);
                let result = if seq.kv_outcome == "error" {
                    Err(Error::Engine("admission failed (prompt too long?)".into()))
                } else {
                    // Save the session KV for continuation.
                    let mut history = Vec::new(); // token ids are implicit in kv; keep count only
                    history.resize(seq.kv.pos.min(dims.max_seq), dims.pad);
                    let text = self.tok.decode(&seq.generated);
                    let done = GenOut {
                        text,
                        prompt_tokens: seq.prompt_tokens,
                        generated_tokens: seq.generated.len(),
                        kv_outcome: seq.kv_outcome,
                    };
                    self.saved.insert(seq.session, (seq.kv, history));
                    Ok(done)
                };
                completions.push(EngineDone { tag: seq.tag, session: seq.session, result });
            } else {
                idx += 1;
            }
        }
        completions
    }

    fn active(&self) -> usize {
        self.active.len()
    }

    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn kv_manager(&self) -> &Arc<KvCacheManager> {
        &self.kv_mgr
    }

    fn evict_session(&mut self, session: SessionId) {
        self.saved.remove(&session);
        self.kv_mgr.drop_session(session);
    }
}
