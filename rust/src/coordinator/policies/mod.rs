//! Operator policies (paper §4.2, §6).
//!
//! The three **defaults** used in the end-to-end evaluation (§6.1, "<100
//! lines cumulatively"): [`LoadBalance`] (routing by load), [`HolMigration`]
//! (migrate sessions stuck behind head-of-line blocking), and
//! [`ResourceRealloc`] (move instances from cold to hot agent types).
//!
//! The two **§6.2 studies**, each the paper's "12 lines of Python" in
//! spirit — the `tick` bodies here are the same dozen lines of logic:
//! [`Srtf`] (minimize JCT: prioritize later-stage calls) and [`Lpt`]
//! (control makespan: prioritize jobs that re-entered the graph).
//!
//! [`Fcfs`] is the do-nothing baseline order (LangGraph-style).

use std::collections::HashSet;

use crate::coordinator::component::LocalOrder;
use crate::coordinator::global::ClusterView;
use crate::coordinator::policy::{Policy, PolicyApi};
use crate::ids::InstanceId;

/// Route each agent type's traffic inversely to instance load.
#[derive(Default)]
pub struct LoadBalance;

impl Policy for LoadBalance {
    fn name(&self) -> &'static str {
        "load_balance"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        for agent in view.agents() {
            let insts: Vec<_> = view.instances_of(&agent).collect();
            if insts.len() < 2 {
                continue;
            }
            let weights: Vec<(InstanceId, f64)> = insts
                .iter()
                .map(|i| {
                    let load = (i.m.queue_len + i.m.active) as f64;
                    (i.id.clone(), 1.0 / (1.0 + load * load))
                })
                .collect();
            api.route_weights(&agent, weights);
        }
    }
}

/// Migrate the longest-waiting session away from instances showing
/// head-of-line blocking (paper §4.1's motivating example; also the shape
/// of Figure 6's example policy).
pub struct HolMigration {
    /// Queue-wait (ms, wall clock) that counts as HOL-blocked.
    pub threshold_ms: u64,
}

impl Default for HolMigration {
    fn default() -> Self {
        HolMigration { threshold_ms: 150 }
    }
}

impl Policy for HolMigration {
    fn name(&self) -> &'static str {
        "hol_migration"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        for agent in view.agents() {
            let insts: Vec<_> = view.instances_of(&agent).collect();
            if insts.len() < 2 {
                continue;
            }
            // damping: at most one migration per agent type per tick —
            // repeated commands within one period thrash (observed in the
            // Fig-9a tuning; see EXPERIMENTS.md §Perf).
            let mut migrated = false;
            for blocked in &insts {
                if migrated || blocked.m.oldest_wait_ms < self.threshold_ms {
                    continue;
                }
                // a strictly less-loaded peer is the migration target
                let Some(target) = insts
                    .iter()
                    .filter(|t| t.id != blocked.id)
                    .min_by_key(|t| t.m.queue_len + t.m.active)
                else {
                    continue;
                };
                if target.m.queue_len + target.m.active + 1
                    >= blocked.m.queue_len + blocked.m.active
                {
                    continue; // no imbalance worth a migration
                }
                if let Some((session, _wait)) = blocked.m.waiting_sessions.first() {
                    api.migrate(*session, blocked.id.clone(), target.id.clone());
                    migrated = true;
                }
            }
        }
    }
}

/// Reassign instances from under-loaded agent types to overloaded ones
/// (paper §6.1: the router/SWE workflows win through dynamic reallocation).
pub struct ResourceRealloc {
    /// Mean load above which an agent type is "hot".
    pub hot: f64,
    /// Mean load below which an agent type is "cold".
    pub cold: f64,
    /// Ticks to wait between reallocation actions (damping).
    pub cooldown: u32,
    since_last: u32,
}

impl Default for ResourceRealloc {
    fn default() -> Self {
        ResourceRealloc { hot: 4.0, cold: 0.5, cooldown: 3, since_last: u32::MAX / 2 }
    }
}

impl Policy for ResourceRealloc {
    fn name(&self) -> &'static str {
        "resource_realloc"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        self.since_last = self.since_last.saturating_add(1);
        if self.since_last < self.cooldown {
            return;
        }
        let agents = view.agents();
        let hot = agents
            .iter()
            .filter(|a| view.mean_load(a) >= self.hot)
            .max_by(|a, b| view.mean_load(a).total_cmp(&view.mean_load(b)));
        let cold = agents
            .iter()
            .filter(|a| view.mean_load(a) <= self.cold && view.instances_of(a).count() > 1)
            .min_by(|a, b| view.mean_load(a).total_cmp(&view.mean_load(b)));
        if let (Some(hot), Some(cold)) = (hot, cold) {
            if hot != cold {
                // free a slot from the cold type, give it to the hot one
                if let Some(idle) = view
                    .instances_of(cold)
                    .filter(|i| i.m.queue_len + i.m.active == 0)
                    .last()
                {
                    api.kill(idle.id.clone());
                    api.provision(hot);
                    self.since_last = 0;
                }
            }
        }
    }
}

/// React to ingress overload: when the front door reports deep queues or
/// fresh sheds, provision another instance of the hottest agent type. This
/// is the control loop that lets NALAR *absorb* load the admission
/// controller would otherwise keep shedding — the paper's "sustains 80 RPS
/// where baselines fail" capacity story (§6): baselines have neither the
/// telemetry nor the `provision` primitive.
pub struct OverloadProvision {
    /// Fraction of a bounded queue's cap that counts as overloaded.
    pub depth_frac: f64,
    /// Absolute depth that counts as overloaded on unbounded queues.
    pub depth_abs: usize,
    /// In-flight requests per scheduler thread that count as saturated
    /// (the event-driven ingress parks requests instead of blocking
    /// threads, so a high multiplexing factor means work is piling up in
    /// the in-flight table even when the admission queue looks shallow).
    pub sat_multiplex: f64,
    /// Ticks to wait between provisions (damping).
    pub cooldown: u32,
    since_last: u32,
    last_shed: u64,
}

impl Default for OverloadProvision {
    fn default() -> Self {
        OverloadProvision {
            depth_frac: 0.5,
            depth_abs: 64,
            sat_multiplex: 16.0,
            cooldown: 5,
            since_last: u32::MAX / 2,
            last_shed: 0,
        }
    }
}

impl Policy for OverloadProvision {
    fn name(&self) -> &'static str {
        "overload_provision"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        self.since_last = self.since_last.saturating_add(1);
        if self.since_last < self.cooldown {
            // don't commit `last_shed` while cooling down: sheds observed
            // in the window still count at the first post-cooldown tick
            return;
        }
        let total_shed: u64 = view.ingress.iter().map(|i| i.shed).sum();
        let shedding = total_shed > self.last_shed;
        self.last_shed = total_shed;
        let deep = view.ingress.iter().any(|i| {
            if i.cap > 0 {
                i.depth as f64 >= self.depth_frac * i.cap as f64
            } else {
                i.depth >= self.depth_abs
            }
        });
        let saturated = view
            .ingress
            .iter()
            .any(|i| i.workers > 0 && i.in_flight as f64 >= self.sat_multiplex * i.workers as f64);
        if !(shedding || deep || saturated) {
            return;
        }
        // The bottleneck is the agent type with the highest mean queue —
        // give it capacity. `provision` is a no-op past max_instances.
        let hottest = view
            .agents()
            .into_iter()
            .max_by(|a, b| view.mean_load(a).total_cmp(&view.mean_load(b)));
        if let Some(agent) = hottest {
            if view.mean_load(&agent) > 0.0 {
                api.provision(&agent);
                self.since_last = 0;
            }
        }
    }
}

/// §6.2 "Minimize JCT": SRTF via the call-graph stage heuristic — calls
/// from later stages of the graph have the least remaining work, so they
/// get higher priority. (The paper: 12 lines; so is this tick.)
#[derive(Default)]
pub struct Srtf {
    installed: HashSet<InstanceId>,
}

impl Policy for Srtf {
    fn name(&self) -> &'static str {
        "srtf"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        for i in &view.instances {
            if self.installed.insert(i.id.clone()) {
                api.install_order(i.id.clone(), LocalOrder::Priority);
            }
        }
        for i in &view.instances {
            for (session, _wait) in &i.m.waiting_sessions {
                // stage encoded by the stub on each future; boosting the
                // session boosts its later-stage (deepest pending) calls
                api.set_priority_at(*session, 1, &i.m.agent);
            }
        }
    }
}

/// §6.2 "Control Makespan": LPT — jobs that re-entered the graph (failed
/// and requeued) are the longest-processing; run them first.
#[derive(Default)]
pub struct Lpt {
    installed: HashSet<InstanceId>,
}

impl Policy for Lpt {
    fn name(&self) -> &'static str {
        "lpt"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        for i in &view.instances {
            if self.installed.insert(i.id.clone()) {
                api.install_order(i.id.clone(), LocalOrder::Priority);
            }
        }
        // Retried futures carry retry_count in their metadata; the apply
        // step maps session priority onto them. Sessions still waiting
        // after a retry are exactly the re-entrants.
        for i in &view.instances {
            for (session, wait) in &i.m.waiting_sessions {
                if *wait > 0 {
                    api.set_priority(*session, (*wait / 100) as i32);
                }
            }
        }
    }
}

/// Close the JIT-routing control loop from the global side (DESIGN.md
/// §13). Steady state: install the operator's thresholds once — urgency
/// below `slack_fast_s` of deadline slack, the largest variant only past
/// `headroom_large × estimate`, and a `quality_floor` that keeps healthy
/// traffic on good variants. Under front-door pressure (fresh sheds /
/// in-queue expiries or deep queues on a workflow running `route =
/// "jit"`): push relief thresholds — urgency kicks in a second earlier,
/// the largest variant needs twice the headroom, and the floor drops to
/// `relief_floor` so goodput wins over quality until pressure clears.
/// Component controllers enforce whichever floor is installed at engine
/// admit; workflows running `fixed` routes are left alone.
pub struct JitRoute {
    pub slack_fast_s: f64,
    pub headroom_large: f64,
    pub quality_floor: f64,
    /// Quality floor pushed while the front door is overloaded.
    pub relief_floor: f64,
    /// Absolute depth that counts as pressure on unbounded queues.
    pub depth_abs: usize,
    last_pressure: u64,
    installed: Option<(f64, f64, f64)>,
}

impl Default for JitRoute {
    fn default() -> Self {
        JitRoute {
            slack_fast_s: 0.0,
            headroom_large: 4.0,
            quality_floor: 0.9,
            relief_floor: 0.0,
            depth_abs: 32,
            last_pressure: 0,
            installed: None,
        }
    }
}

impl Policy for JitRoute {
    fn name(&self) -> &'static str {
        "jit_route"
    }

    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        let jit: Vec<_> = view.ingress.iter().filter(|i| i.route == "jit").collect();
        if jit.is_empty() {
            return;
        }
        let pressure_now: u64 = jit.iter().map(|i| i.shed + i.expired_in_queue).sum();
        let rising = pressure_now > self.last_pressure;
        self.last_pressure = pressure_now;
        let deep = jit
            .iter()
            .any(|i| if i.cap > 0 { i.depth * 2 >= i.cap } else { i.depth >= self.depth_abs });
        let target = if rising || deep {
            (self.slack_fast_s + 1.0, self.headroom_large * 2.0, self.relief_floor)
        } else {
            (self.slack_fast_s, self.headroom_large, self.quality_floor)
        };
        // idempotent: re-push only when the target moves
        if self.installed != Some(target) {
            api.route_control(target.0, target.1, target.2);
            self.installed = Some(target);
        }
    }
}

/// Baseline: best-effort FCFS, no control (LangGraph-style, §2.3).
pub struct Fcfs;

impl Policy for Fcfs {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn tick(&mut self, _view: &ClusterView, _api: &mut PolicyApi) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::global::InstanceView;
    use crate::coordinator::policy::PolicyCmd;
    use crate::coordinator::InstanceMetrics;
    use crate::ids::{NodeId, SessionId};

    fn iv(agent: &str, idx: u32, queue: usize, oldest_ms: u64) -> InstanceView {
        InstanceView {
            id: InstanceId::new(agent, idx),
            node: NodeId(0),
            m: InstanceMetrics {
                agent: agent.into(),
                queue_len: queue,
                oldest_wait_ms: oldest_ms,
                waiting_sessions: if queue > 0 {
                    vec![(SessionId(idx as u64), oldest_ms)]
                } else {
                    vec![]
                },
                ..Default::default()
            },
        }
    }

    fn view(instances: Vec<InstanceView>) -> ClusterView {
        ClusterView { instances, ..Default::default() }
    }

    #[test]
    fn load_balance_prefers_idle() {
        let v = view(vec![iv("dev", 0, 10, 0), iv("dev", 1, 0, 0)]);
        let mut api = PolicyApi::new();
        LoadBalance.tick(&v, &mut api);
        let PolicyCmd::RouteWeights { weights, .. } = &api.commands()[0] else {
            panic!()
        };
        let w0 = weights.iter().find(|(i, _)| i.index == 0).unwrap().1;
        let w1 = weights.iter().find(|(i, _)| i.index == 1).unwrap().1;
        assert!(w1 > 10.0 * w0, "idle instance should dominate: {w0} vs {w1}");
    }

    #[test]
    fn hol_migrates_from_blocked_to_idle() {
        let v = view(vec![iv("dev", 0, 8, 500), iv("dev", 1, 0, 0)]);
        let mut api = PolicyApi::new();
        HolMigration::default().tick(&v, &mut api);
        assert!(api
            .commands()
            .iter()
            .any(|c| matches!(c, PolicyCmd::Migrate { from, to, .. }
                if from.index == 0 && to.index == 1)));
    }

    #[test]
    fn hol_no_migration_when_balanced() {
        let v = view(vec![iv("dev", 0, 3, 500), iv("dev", 1, 3, 480)]);
        let mut api = PolicyApi::new();
        HolMigration::default().tick(&v, &mut api);
        assert!(api.commands().is_empty());
    }

    #[test]
    fn realloc_moves_capacity_to_hot_agent() {
        let v = view(vec![
            iv("coder", 0, 10, 0),
            iv("chat", 0, 0, 0),
            iv("chat", 1, 0, 0),
        ]);
        let mut api = PolicyApi::new();
        ResourceRealloc::default().tick(&v, &mut api);
        let cmds = api.commands();
        assert!(cmds.iter().any(|c| matches!(c, PolicyCmd::Kill(i) if i.agent.as_str() == "chat")));
        assert!(cmds
            .iter()
            .any(|c| matches!(c, PolicyCmd::Provision { agent } if agent == "coder")));
    }

    #[test]
    fn realloc_never_kills_last_instance() {
        let v = view(vec![iv("coder", 0, 10, 0), iv("chat", 0, 0, 0)]);
        let mut api = PolicyApi::new();
        ResourceRealloc::default().tick(&v, &mut api);
        assert!(api.commands().is_empty(), "chat has only one instance");
    }

    #[test]
    fn realloc_cooldown_damps() {
        let v = view(vec![
            iv("coder", 0, 10, 0),
            iv("chat", 0, 0, 0),
            iv("chat", 1, 0, 0),
        ]);
        let mut p = ResourceRealloc::default();
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        let first = api.commands().len();
        let mut api2 = PolicyApi::new();
        p.tick(&v, &mut api2); // immediately after acting: cooldown
        assert!(first > 0 && api2.commands().is_empty());
    }

    #[test]
    fn overload_provision_reacts_to_shed_and_depth() {
        use crate::coordinator::IngressMetrics;
        let mut v = view(vec![iv("coder", 0, 12, 0)]);
        v.ingress = vec![IngressMetrics {
            workflow: "router".into(),
            depth: 40,
            cap: 64,
            policy: "bounded".into(),
            accepted: 100,
            shed: 5,
            ..Default::default()
        }];
        let mut p = OverloadProvision::default();
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api); // first tick sees shed going 0 -> 5 and depth >= cap/2
        assert!(api
            .commands()
            .iter()
            .any(|c| matches!(c, PolicyCmd::Provision { agent } if agent == "coder")));
        // immediately after acting: cooldown damps
        let mut api2 = PolicyApi::new();
        p.tick(&v, &mut api2);
        assert!(api2.commands().is_empty());
    }

    #[test]
    fn overload_provision_reacts_to_multiplexing_saturation() {
        use crate::coordinator::IngressMetrics;
        // No sheds, shallow queue — but the in-flight table carries 16x
        // the scheduler's threads: the thread-decoupled front door is
        // saturated and capacity must grow.
        let mut v = view(vec![iv("coder", 0, 9, 0)]);
        v.ingress = vec![IngressMetrics {
            workflow: "router".into(),
            depth: 2,
            in_flight: 128,
            workers: 8,
            cap: 256,
            policy: "bounded".into(),
            accepted: 500,
            ..Default::default()
        }];
        let mut p = OverloadProvision::default();
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        assert!(api
            .commands()
            .iter()
            .any(|c| matches!(c, PolicyCmd::Provision { agent } if agent == "coder")));
    }

    #[test]
    fn overload_provision_idle_ingress_is_inert() {
        use crate::coordinator::IngressMetrics;
        let mut v = view(vec![iv("coder", 0, 2, 0)]);
        v.ingress = vec![IngressMetrics {
            workflow: "router".into(),
            depth: 1,
            cap: 64,
            policy: "bounded".into(),
            accepted: 100,
            ..Default::default()
        }];
        let mut p = OverloadProvision::default();
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        assert!(api.commands().is_empty(), "no shed, shallow queue: no action");
    }

    #[test]
    fn jit_route_installs_once_and_pushes_relief_under_pressure() {
        use crate::coordinator::IngressMetrics;
        let mut p = JitRoute::default();
        let steady_floor = p.quality_floor;
        // no workflow running jit: stay silent
        let mut api = PolicyApi::new();
        p.tick(&view(vec![]), &mut api);
        assert!(api.commands().is_empty(), "no jit front door: no commands");
        // healthy jit ingress: install the steady-state thresholds, once
        let mut v = view(vec![]);
        v.ingress = vec![IngressMetrics {
            workflow: "router".into(),
            route: "jit".into(),
            ..Default::default()
        }];
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        let PolicyCmd::RouteControl { quality_floor, .. } = &api.commands()[0] else {
            panic!()
        };
        assert_eq!(*quality_floor, steady_floor);
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        assert!(api.commands().is_empty(), "unchanged target: no re-install");
        // sheds tick up: relief thresholds with the floor dropped
        v.ingress[0].shed = 5;
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        let PolicyCmd::RouteControl { quality_floor, .. } = &api.commands()[0] else {
            panic!()
        };
        assert!(*quality_floor < steady_floor, "pressure must drop the floor");
        // pressure clears (sheds flat, shallow queue): restore steady state
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        let PolicyCmd::RouteControl { quality_floor, .. } = &api.commands()[0] else {
            panic!()
        };
        assert_eq!(*quality_floor, steady_floor, "recovery restores the floor");
    }

    #[test]
    fn srtf_installs_priority_order_once() {
        let v = view(vec![iv("dev", 0, 1, 10)]);
        let mut p = Srtf::default();
        let mut api = PolicyApi::new();
        p.tick(&v, &mut api);
        let installs = api
            .commands()
            .iter()
            .filter(|c| matches!(c, PolicyCmd::InstallOrder { .. }))
            .count();
        assert_eq!(installs, 1);
        let mut api2 = PolicyApi::new();
        p.tick(&v, &mut api2);
        assert!(!api2
            .commands()
            .iter()
            .any(|c| matches!(c, PolicyCmd::InstallOrder { .. })));
    }

    #[test]
    fn fcfs_is_inert() {
        let v = view(vec![iv("dev", 0, 5, 999)]);
        let mut api = PolicyApi::new();
        Fcfs.tick(&v, &mut api);
        assert!(api.commands().is_empty());
    }
}
