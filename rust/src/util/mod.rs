//! Self-built utility substrates.
//!
//! The build environment is fully offline (zero external dependencies),
//! so the usual ecosystem crates are implemented here from scratch
//! (DESIGN.md §3 substitution table):
//!
//! * [`json`] — serde_json substitute: value model, parser, writer, `json!`.
//! * [`rng`] — rand/rand_distr substitute: xoshiro256++, exp/lognormal/
//!   Poisson/Zipf samplers.
//! * [`cli`] — clap substitute: flag/option/positional parsing.
//! * [`bench`] — criterion substitute: timing loops + table printer
//!   (figure-level reporting lives in [`crate::bench`]).
//! * [`clock`] — injectable time source (wall or manually-advanced) for
//!   the ingress scheduler; [`crate::testkit`] re-exports it.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod rng;
