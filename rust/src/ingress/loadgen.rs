//! `nalar loadgen` — the open-loop saturation sweep (paper §6).
//!
//! For each (offered RPS, system) point this drives the ingress front door
//! with a Poisson arrival process ([`Arrivals::schedule`]): submits never
//! block on completion — exactly the open-loop discipline under which the
//! paper's capacity claim is stated. Each point reports goodput (requests
//! completed *within deadline* per second), shed rate, and latency
//! quantiles; the sweep across RPS produces the §6 saturation curve where
//! NALAR sustains 80 RPS and the baselines' goodput collapses (their
//! unbounded queues turn overload into divergent p99 instead of sheds).
//!
//! Output: `BENCH_rps_sweep.json` in the `nalar-bench/v1` schema
//! (validated by [`crate::bench::validate`]; `latency` is censored at the
//! deadline so baseline p99 divergence is visible, `latency_ok` is
//! completions only).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::baselines::SystemUnderTest;
use crate::bench;
use crate::config::{DeploymentConfig, ModelVariant, TenantSettings};
use crate::error::{Error, Result};
use crate::ids::SessionId;
use crate::ingress::{Ingress, RouteMode, SchedulePolicy, SubmitRequest, Ticket};
use crate::json;
use crate::metrics::{goodput, shed_rate, LatencyRecorder};
use crate::server::http::HttpClient;
use crate::server::Deployment;
use crate::util::bench::Table;
use crate::util::json::{self as json_util, Value};
use crate::util::rng::Rng;
use crate::workflow::harness::input_for;
use crate::workflow::WorkflowKind;
use crate::workload::Arrivals;

/// One tenant of the offered load (`--tenants`): `share` splits the
/// Poisson arrival stream (relative, not normalised), `weight` is the
/// DRR weight installed into the deployment's `ingress.tenants`.
#[derive(Debug, Clone)]
pub struct TenantLoad {
    pub name: String,
    pub share: f64,
    pub weight: f64,
}

/// The noisy-neighbor profile (`--tenants noisy`): two *equal-weight*
/// tenants where `hog` offers 10x `meek`'s rate — the ISSUE's fairness
/// scenario. Under a single shared queue the hog's backlog starves the
/// meek tenant past its deadlines; under DRR the meek tenant's goodput
/// tracks its weight share of capacity.
pub fn noisy_neighbor() -> Vec<TenantLoad> {
    vec![
        TenantLoad { name: "hog".into(), share: 10.0, weight: 1.0 },
        TenantLoad { name: "meek".into(), share: 1.0, weight: 1.0 },
    ]
}

/// Parse a `--schedule` axis spec — a comma list of front-door orderings,
/// e.g. `fifo,deadline_slack`. Every entry is checked against the
/// scheduler's own name authority ([`SchedulePolicy::parse`]) so a typo
/// dies at flag-parse time, not minutes into a sweep. Returns `None` on
/// unknown names, duplicates or an empty spec.
pub fn parse_schedule_axis(spec: &str) -> Option<Vec<String>> {
    let mut out: Vec<String> = Vec::new();
    for part in spec.split(',') {
        let s = part.trim();
        SchedulePolicy::parse(s)?;
        if out.iter().any(|x| x == s) {
            return None;
        }
        out.push(s.to_string());
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Parse a `--route` axis spec — a comma list of routing modes, e.g.
/// `fixed,jit` or `jit,fixed-large`. Checked against the router's name
/// authority ([`RouteMode::parse`]): shape errors die at flag-parse time
/// (an unknown *variant* in a `fixed-<v>` pin can only be caught against
/// the deployment's variant table, at launch). Returns `None` on unknown
/// modes, duplicates or an empty spec.
pub fn parse_route_axis(spec: &str) -> Option<Vec<String>> {
    let mut out: Vec<String> = Vec::new();
    for part in spec.split(',') {
        let s = part.trim();
        RouteMode::parse(s)?;
        if out.iter().any(|x| x == s) {
            return None;
        }
        out.push(s.to_string());
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Parse a `--tenants` spec: the literal `noisy` (the profile above) or
/// a comma list of `name:share[:weight]`, e.g. `a:10,b:1` or
/// `hog:10:1,meek:1:3`. Returns `None` on malformed specs, non-positive
/// shares/weights or duplicate names.
pub fn parse_tenant_mix(spec: &str) -> Option<Vec<TenantLoad>> {
    if spec == "noisy" {
        return Some(noisy_neighbor());
    }
    let mut out: Vec<TenantLoad> = Vec::new();
    for part in spec.split(',') {
        let fields: Vec<&str> = part.trim().split(':').collect();
        let (name, share, weight) = match fields.as_slice() {
            [name, share] => (*name, *share, "1"),
            [name, share, weight] => (*name, *share, *weight),
            _ => return None,
        };
        if name.is_empty() || out.iter().any(|t| t.name == name) {
            return None;
        }
        let share: f64 = share.parse().ok()?;
        let weight: f64 = weight.parse().ok()?;
        if !(share > 0.0 && share.is_finite() && weight > 0.0 && weight.is_finite()) {
            return None;
        }
        out.push(TenantLoad { name: name.to_string(), share, weight });
    }
    if out.is_empty() {
        return None;
    }
    Some(out)
}

/// One `nalar loadgen` invocation.
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    pub workflow: WorkflowKind,
    pub systems: Vec<SystemUnderTest>,
    /// Offered load points (wall-clock requests/second).
    pub rates: Vec<f64>,
    /// Measurement window per point (wall-clock seconds).
    pub secs: u64,
    /// CI-smoke profile flag (stamped into the report).
    pub quick: bool,
    pub out_dir: PathBuf,
    /// Sessions drawn Zipf-skewed, as in the Fig-9 harness.
    pub session_pool: usize,
    /// Per-request deadline in paper seconds (scaled by `time_scale`).
    pub timeout_paper_s: f64,
    /// Override the config's `time_scale` (None = keep the config's).
    pub time_scale: Option<f64>,
    pub seed: u64,
    /// Deployment config file (None = the workflow's builtin config).
    pub config: Option<PathBuf>,
    /// Override the config's `ingress.workers` scheduler thread count
    /// (None = keep the config's). The event-driven scheduler multiplexes
    /// in-flight requests over these threads, so a small value with a
    /// large offered load is the thread-decoupling stress test.
    pub workers: Option<usize>,
    /// Override the deployment's policy list (None = keep the config's /
    /// the system's defaults). The hc gate pins this to `load_balance`
    /// only: `resource_realloc` may kill an instance mid-run, failing its
    /// queued futures retryably — legitimate in the saturation sweep,
    /// noise in a must-complete-everything functional gate.
    pub policies: Option<Vec<String>>,
    /// Fail the run if any point completes fewer requests than it
    /// admitted (offered − shed − cancelled) — the CI gate for the
    /// scheduler: with in-flight ≫ threads, every admitted request must
    /// still finish.
    pub expect_admitted_complete: bool,
    /// Probability an admitted request is cancelled (`Ticket::cancel`)
    /// at a seeded uniform point inside its deadline window — the
    /// lifecycle-control knob (`--cancel-rate`): cancelled work must
    /// neither leak scheduler-table entries nor distort the goodput
    /// accounting of the surviving requests.
    pub cancel_rate: f64,
    /// Scheduling-policy axis: run every (rate, system) point once per
    /// listed `ingress.schedule` (None = the config's). Baselines are
    /// forced back to `fifo` by `SystemUnderTest::apply`, so the axis
    /// measures NALAR's front-door SRTF against its own FIFO.
    pub schedules: Option<Vec<String>>,
    /// Routing-mode axis (`--route`): run every (rate, system) point once
    /// per listed `ingress.route` mode — `jit` against `fixed` /
    /// `fixed-<variant>` pins is the goodput-at-equal-quality comparison
    /// `nalar bench routing` runs. None = the config's route. Meaningful
    /// only when the config declares `engine.variants`; without them every
    /// mode collapses to the inert fixed path.
    pub routes: Option<Vec<String>>,
    /// Override the config's `engine.variants` table (None = keep the
    /// config's). `nalar bench routing` injects its three-variant curve
    /// here so both comparison arms run one known latency/quality table
    /// regardless of what the workflow's builtin config declares.
    pub variants: Option<Vec<ModelVariant>>,
    /// Multi-tenant offered load (`--tenants`): splits the arrival
    /// stream across named tenants by `share` and installs their DRR
    /// `weight`s into `ingress.tenants`. Baselines are forced back to
    /// the single-tenant queue by `SystemUnderTest::apply` (submitted
    /// tenant names collapse onto it), so the per-tenant report rows
    /// show exactly the starvation DRR prevents. None = the config's
    /// tenants (requests submit as the default tenant).
    pub tenants: Option<Vec<TenantLoad>>,
    /// Drive a live `nalar serve --listen` socket instead of an
    /// in-process deployment (`--remote addr:port`). The sweep keeps its
    /// open-loop discipline by submitting in async-park mode
    /// (`X-Nalar-Wait: 0` → `202` + id) and draining via
    /// `GET /v1/requests/{id}` polls, so every point additionally
    /// exercises the wire protocol: 429 sheds with `Retry-After`, 408
    /// deadline expiries, `DELETE` cancels. The server owns its own
    /// config (system, schedule, workers, time scale), so those local
    /// axes do not apply; report points carry `"transport": "http"`.
    pub remote: Option<String>,
}

impl LoadgenOpts {
    /// CI-smoke profile: two points, two systems, seconds of wall time.
    pub fn quick(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            workflow,
            systems: vec![SystemUnderTest::Nalar, SystemUnderTest::AutoGenLike],
            rates: vec![40.0, 80.0],
            secs: 1,
            quick: true,
            out_dir: PathBuf::from("."),
            session_pool: 16,
            timeout_paper_s: 30.0,
            time_scale: Some(0.002),
            seed: 0x10AD,
            config: None,
            workers: None,
            policies: None,
            expect_admitted_complete: false,
            cancel_rate: 0.0,
            schedules: None,
            routes: None,
            variants: None,
            tenants: None,
            remote: None,
        }
    }

    /// The full §6 sweep: all four systems across the saturation range.
    /// `time_scale` 0.1 (only a 10x speedup) puts the workload's capacity
    /// cliff inside the swept range, so 80 RPS is a genuine saturation
    /// point rather than a trivial one.
    pub fn full(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            workflow,
            systems: SystemUnderTest::all().to_vec(),
            rates: vec![20.0, 40.0, 60.0, 80.0, 100.0, 120.0, 160.0],
            secs: 8,
            quick: false,
            out_dir: PathBuf::from("."),
            session_pool: 48,
            timeout_paper_s: 30.0,
            time_scale: Some(0.1),
            seed: 0x10AD,
            config: None,
            workers: None,
            policies: None,
            expect_admitted_complete: false,
            cancel_rate: 0.0,
            schedules: None,
            routes: None,
            variants: None,
            tenants: None,
            remote: None,
        }
    }

    /// High-concurrency CI gate: one point offering ~640 requests in 2s
    /// onto a 4-thread scheduler (in-flight ≫ threads), failing the run
    /// if any admitted request does not complete. The generous deadline
    /// makes this a functional gate on the event-driven scheduler, not a
    /// latency benchmark.
    pub fn hc_smoke(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![320.0],
            secs: 2,
            session_pool: 32,
            timeout_paper_s: 600.0,
            time_scale: Some(0.0005),
            workers: Some(4),
            // `resource_realloc` may kill an instance mid-run, failing its
            // queued futures retryably — legitimate in the saturation
            // sweep, noise in a must-complete-everything gate.
            policies: Some(vec!["load_balance".into()]),
            expect_admitted_complete: true,
            // Run the gate under the non-default ordering: deadline-slack
            // pops must preserve the every-admitted-request-completes and
            // no-table-leak invariants just like FIFO.
            schedules: Some(vec!["deadline_slack".into()]),
            ..Self::quick(workflow)
        }
    }
}

/// Run the sweep and write `BENCH_rps_sweep.json`. Returns the path.
pub fn run(opts: &LoadgenOpts) -> Result<PathBuf> {
    if opts.rates.is_empty() || opts.systems.is_empty() {
        return Err(Error::Config("loadgen needs at least one rate and one system".into()));
    }
    let mut table = Table::new(&[
        "system", "sched", "route", "rps", "offered", "ok", "shed", "expired", "cancel", "fail",
        "goodput", "p50(s)", "p99(s)",
    ]);
    // The scheduling-policy axis: None = keep whatever the config says.
    let schedules: Vec<Option<String>> = match &opts.schedules {
        Some(list) => list.iter().map(|s| Some(s.clone())).collect(),
        None => vec![None],
    };
    // The routing-mode axis, same shape; the grid is their product.
    let routes: Vec<Option<String>> = match &opts.routes {
        Some(list) => list.iter().map(|r| Some(r.clone())).collect(),
        None => vec![None],
    };
    let mut grid: Vec<(Option<String>, Option<String>)> = Vec::new();
    for sched in &schedules {
        for route in &routes {
            grid.push((sched.clone(), route.clone()));
        }
    }
    let mut points = Vec::new();
    // `--remote`: the server owns the deployment (its system, schedule
    // and workers are whatever `nalar serve` launched), so the sweep
    // collapses to the rate axis and every point goes over the wire.
    if let Some(addr) = &opts.remote {
        for &rps in &opts.rates {
            let t0 = Instant::now();
            let p = run_point_remote(opts, rps, addr)?;
            println!(
                "[loadgen] {} http://{addr} ({}) @ {:.0} rps done in {:.1?}",
                opts.workflow.name(),
                p.get("schedule").as_str().unwrap_or("?"),
                rps,
                t0.elapsed()
            );
            table.row(&sweep_row(&p));
            points.push(p);
        }
        return write_sweep(opts, &format!("http://{addr}"), &table, points);
    }
    for &rps in &opts.rates {
        for &system in &opts.systems {
            for (gi, (sched, route)) in grid.iter().enumerate() {
                // Baselines are forced back to `fifo` by `apply` and have
                // no model router, so every axis entry would measure the
                // identical configuration — run each baseline cell once
                // instead of once per entry.
                if gi > 0 && system != SystemUnderTest::Nalar {
                    continue;
                }
                let t0 = Instant::now();
                let p = run_point(opts, rps, system, sched.as_deref(), route.as_deref())?;
                println!(
                    "[loadgen] {} {} ({}) @ {:.0} rps done in {:.1?}",
                    opts.workflow.name(),
                    system.name(),
                    p.get("schedule").as_str().unwrap_or("?"),
                    rps,
                    t0.elapsed()
                );
                if opts.tenants.is_some() {
                    if let Some(tm) = p.get("tenants").as_obj() {
                        for (name, t) in tm {
                            println!(
                                "[loadgen]   tenant {:<8} offered {:>5} ok {:>5} shed {:>4} \
                                 missed {:>4} goodput {:.1} rps",
                                name,
                                t.get("offered").as_u64().unwrap_or(0),
                                t.get("completed").as_u64().unwrap_or(0),
                                t.get("shed").as_u64().unwrap_or(0),
                                t.get("missed").as_u64().unwrap_or(0),
                                t.get("goodput_rps").as_f64().unwrap_or(0.0),
                            );
                        }
                    }
                }
                table.row(&sweep_row(&p));
                if opts.expect_admitted_complete {
                    let offered = p.get("offered").as_u64().unwrap_or(0);
                    let shed = p.get("shed").as_u64().unwrap_or(0);
                    let cancelled = p.get("cancelled").as_u64().unwrap_or(0);
                    let completed = p.get("completed").as_u64().unwrap_or(0);
                    if completed < offered.saturating_sub(shed + cancelled) {
                        return Err(Error::Msg(format!(
                            "high-concurrency gate: {} {} @ {:.0} rps completed only \
                             {completed} of {} admitted requests",
                            opts.workflow.name(),
                            system.name(),
                            rps,
                            offered.saturating_sub(shed + cancelled),
                        )));
                    }
                }
                points.push(p);
            }
        }
    }
    write_sweep(opts, "open loop", &table, points)
}

/// Shared tail of [`run`]: print the table, validate against the
/// `nalar-bench/v1` schema and write `BENCH_rps_sweep.json`.
fn write_sweep(
    opts: &LoadgenOpts,
    label: &str,
    table: &Table,
    points: Vec<Value>,
) -> Result<PathBuf> {
    println!("\n=== RPS sweep — {} workflow, {label} ===", opts.workflow.name());
    table.print();
    let report = bench::report(bench::RPS_SWEEP, opts.quick, "paper_s", points);
    bench::validate(&report)?;
    std::fs::create_dir_all(&opts.out_dir)?;
    bench::write_report(&opts.out_dir, bench::RPS_SWEEP, &report)
}

/// One formatted summary-table row from a report point.
fn sweep_row(p: &Value) -> [String; 13] {
    [
        p.get("system").as_str().unwrap_or("?").to_string(),
        p.get("schedule").as_str().unwrap_or("?").to_string(),
        p.get("route").as_str().unwrap_or("?").to_string(),
        format!("{:.0}", p.get("rps_wall").as_f64().unwrap_or(0.0)),
        p.get("offered").as_u64().unwrap_or(0).to_string(),
        p.get("completed").as_u64().unwrap_or(0).to_string(),
        p.get("shed").as_u64().unwrap_or(0).to_string(),
        p.get("expired_in_queue").as_u64().unwrap_or(0).to_string(),
        p.get("cancelled").as_u64().unwrap_or(0).to_string(),
        p.get("failed").as_u64().unwrap_or(0).to_string(),
        format!("{:.1}", p.get("goodput_rps").as_f64().unwrap_or(0.0)),
        format!("{:.1}", p.get("latency").get("p50").as_f64().unwrap_or(0.0)),
        format!("{:.1}", p.get("latency").get("p99").as_f64().unwrap_or(0.0)),
    ]
}

/// One (rate, system, schedule, route) cell of the sweep. `pub(crate)`
/// so `nalar bench routing` can drive the identical open-loop point once
/// per routing arm and compare goodput across them.
pub(crate) fn run_point(
    opts: &LoadgenOpts,
    rps: f64,
    system: SystemUnderTest,
    schedule: Option<&str>,
    route: Option<&str>,
) -> Result<Value> {
    let mut cfg = match &opts.config {
        Some(path) => DeploymentConfig::from_json_file(path)?,
        None => opts.workflow.config(),
    };
    if let Some(ts) = opts.time_scale {
        cfg.time_scale = ts;
    }
    if let Some(vs) = &opts.variants {
        cfg.engine.variants = vs.clone();
    }
    if let Some(w) = opts.workers {
        cfg.ingress.workers = w.max(1);
    }
    if let Some(tenants) = &opts.tenants {
        // Install the tenant mix BEFORE the system mode applies: NALAR
        // keeps the weighted-fair table, baselines get it cleared (their
        // front door is single-tenant), so the per-tenant report rows
        // compare DRR isolation against genuine shared-queue starvation.
        cfg.ingress.tenants = tenants
            .iter()
            .map(|t| TenantSettings {
                name: t.name.clone(),
                weight: t.weight,
                ..TenantSettings::default()
            })
            .collect();
    }
    if let Some(s) = schedule {
        // Validate eagerly: the config was checked before this override.
        if SchedulePolicy::parse(s).is_none() {
            return Err(Error::Config(format!(
                "unknown schedule `{s}` (known: fifo, deadline_slack, stage)"
            )));
        }
        // Set BEFORE the system mode applies, so baselines are forced
        // back to `fifo` (none of them schedules a front door) and the
        // axis compares NALAR-with-SRTF against NALAR-with-FIFO.
        cfg.ingress.schedule = s.to_string();
    }
    if let Some(r) = route {
        if RouteMode::parse(r).is_none() {
            return Err(Error::Config(format!(
                "unknown route `{r}` (known: fixed, jit, fixed-<variant>)"
            )));
        }
        cfg.ingress.route = r.to_string();
    }
    // Apply the system's serving mode FIRST (for NALAR this fills the
    // default policy trio when the config declares none — pushing ours
    // earlier would suppress that fill), then add the ingress-aware
    // provisioning loop on top. Baselines get stripped of all policies
    // (and admission control) by the same `apply`, which `launch_as`
    // re-runs idempotently. An explicit `opts.policies` override is
    // authoritative: nothing is appended to it.
    system.apply(&mut cfg);
    if let Some(policies) = &opts.policies {
        cfg.policies = policies.clone();
    } else if system == SystemUnderTest::Nalar
        && !cfg.policies.iter().any(|p| p == "overload_provision")
    {
        cfg.policies.push("overload_provision".into());
    }
    let d = Deployment::launch_as(cfg, system)?;
    let time_scale = d.cfg().time_scale;
    let timeout = Duration::from_secs_f64((opts.timeout_paper_s * time_scale).max(0.001));
    let window = Duration::from_secs(opts.secs.max(1));
    let ingress = Ingress::start(&d, &[opts.workflow]);
    let ingress_policy = ingress.metrics(opts.workflow).map(|m| m.policy).unwrap_or_default();

    let arrivals = Arrivals::new(rps, opts.seed ^ rps.to_bits()).schedule(window);
    let offered = arrivals.len() as u64;
    let sessions: Vec<SessionId> = (0..opts.session_pool.max(1)).map(|_| d.new_session()).collect();
    let mut turns = vec![0u64; sessions.len()];
    let mut rng = Rng::new(opts.seed ^ 0xFEED);

    // The logical tenant mix: submit tenant names only when `--tenants`
    // is in play. Attribution is *client-side* (the loadgen knows which
    // tenant each arrival belonged to), so per-tenant rows stay
    // comparable across systems even when a baseline's single-tenant
    // front door collapses the names server-side.
    let mix: Vec<TenantLoad> = match &opts.tenants {
        Some(t) => t.clone(),
        None => vec![TenantLoad { name: "default".into(), share: 1.0, weight: 1.0 }],
    };
    let total_share: f64 = mix.iter().map(|t| t.share).sum();
    let named_tenants = opts.tenants.is_some();
    let pick_tenant = |rng: &mut Rng| -> usize {
        let mut u = (rng.next_u64() % 1_000_000) as f64 / 1_000_000.0 * total_share;
        for (i, t) in mix.iter().enumerate() {
            u -= t.share;
            if u < 0.0 {
                return i;
            }
        }
        mix.len() - 1
    };

    // Open loop: pace submissions on the arrival schedule; never wait for
    // completions in this loop. With `--cancel-rate`, a seeded fraction
    // of admitted requests is withdrawn at a uniform point inside its
    // deadline window — cancellations fire between arrivals, racing the
    // scheduler exactly like an impatient caller would.
    let mut tickets: Vec<Ticket> = Vec::with_capacity(arrivals.len());
    let mut ticket_tenant: Vec<usize> = Vec::with_capacity(arrivals.len());
    let mut cancels: Vec<(Duration, usize)> = Vec::new(); // (due, ticket index)
    let mut shed = 0u64;
    let mut t_offered = vec![0u64; mix.len()];
    let mut t_shed = vec![0u64; mix.len()];
    let start = Instant::now();
    for at in &arrivals {
        let wait = at.saturating_sub(start.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let now = start.elapsed();
        cancels.retain(|(due, i)| {
            if *due <= now {
                let _ = tickets[*i].cancel(); // may lose to completion: fine
                false
            } else {
                true
            }
        });
        let progress = (now.as_secs_f64() / window.as_secs_f64()).min(1.0);
        let sidx = rng.zipf(sessions.len(), 1.1);
        let turn = turns[sidx];
        turns[sidx] += 1;
        let input = input_for(opts.workflow, progress, turn, &mut rng);
        let tenant = pick_tenant(&mut rng);
        t_offered[tenant] += 1;
        let mut sub = SubmitRequest::workflow(opts.workflow)
            .input(input)
            .session(sessions[sidx])
            .deadline(timeout);
        if named_tenants {
            sub = sub.tenant(mix[tenant].name.clone());
        }
        match ingress.submit(sub) {
            Ok(t) => {
                tickets.push(t);
                ticket_tenant.push(tenant);
                if opts.cancel_rate > 0.0 && rng.bool_with(opts.cancel_rate) {
                    let frac = (rng.next_u64() % 1024) as f64 / 1024.0;
                    cancels.push((now + timeout.mul_f64(frac), tickets.len() - 1));
                }
            }
            Err(_) => {
                // fast retryable rejection, already counted server-side
                shed += 1;
                t_shed[tenant] += 1;
            }
        }
    }
    // Cancels due after the offered window fire at window end (the drain
    // below would otherwise outwait them).
    for (_, i) in cancels {
        let _ = tickets[i].cancel();
    }

    // Drain: every admitted request either completes, hits its deadline
    // (the scheduler's sweep fails expired work fast, so this terminates)
    // or was cancelled above. Cancelled requests are excluded from the
    // latency distributions: they measure caller impatience, not serving.
    let ok_rec = LatencyRecorder::new(); // completions within deadline
    let tail_rec = LatencyRecorder::new(); // + timeouts censored at the deadline
    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut t_completed = vec![0u64; mix.len()];
    let mut t_cancelled = vec![0u64; mix.len()];
    let mut t_missed = vec![0u64; mix.len()];
    let mut t_failed = vec![0u64; mix.len()];
    for (t, &tenant) in tickets.iter().zip(&ticket_tenant) {
        let outcome = t.wait(timeout + Duration::from_millis(50));
        let lat = t.latency().unwrap_or(timeout);
        match outcome {
            Ok(_) if lat <= timeout => {
                completed += 1;
                t_completed[tenant] += 1;
                ok_rec.record(lat);
                tail_rec.record(lat);
            }
            Err(Error::Cancelled) => t_cancelled[tenant] += 1,
            outcome => {
                failed += 1;
                // `missed` is the starvation signal: a Deadline error OR
                // a completion that landed past its deadline (a request
                // mid-poll at expiry can still finish Ok-but-late) both
                // mean the tenant was served too slowly; everything else
                // is an execution failure.
                if matches!(outcome, Err(Error::Deadline(_))) || outcome.is_ok() {
                    t_missed[tenant] += 1;
                } else {
                    t_failed[tenant] += 1;
                }
                tail_rec.record(lat.min(timeout));
            }
        }
    }
    // Everything is drained, so the final snapshot splits the failures:
    // `expired_in_queue` never started a driver (queueing shed the work),
    // `cancelled` was withdrawn by its caller, the remainder failed in
    // execution (slow driver / agent error).
    let m_end = ingress.metrics(opts.workflow).unwrap_or_default();
    let expired_in_queue = m_end.expired_in_queue;
    let cancelled = m_end.cancelled;
    // Table-leak gate: with every ticket fulfilled, both scheduler tables
    // must be empty — including every per-tenant DRR sub-queue — and the
    // future table's per-request index must hold no entry (every terminal
    // path evicts its request). A lingering entry is a lifecycle bug
    // (bounded grace for sweep/poll bookkeeping that runs just after
    // fulfilment).
    let leak_of = |m: &crate::coordinator::IngressMetrics| {
        let tenant_depth: usize = m.tenants.iter().map(|t| t.depth).max().unwrap_or(0);
        (m.in_flight, m.depth, tenant_depth, d.table().request_index_len())
    };
    let drained_at = Instant::now();
    let mut leak = leak_of(&m_end);
    while leak != (0, 0, 0, 0) && drained_at.elapsed() < Duration::from_secs(2) {
        std::thread::sleep(Duration::from_millis(5));
        leak = leak_of(&ingress.metrics(opts.workflow).unwrap_or_default());
    }
    ingress.stop();
    d.shutdown();
    if leak != (0, 0, 0, 0) {
        return Err(Error::Msg(format!(
            "scheduler table leak after full drain: in_flight {} depth {} max-tenant-sub-queue \
             {} request-index {} ({} {} @ {:.0} rps)",
            leak.0,
            leak.1,
            leak.2,
            leak.3,
            opts.workflow.name(),
            system.name(),
            rps,
        )));
    }

    let paper = 1.0 / time_scale;
    let gput = goodput(completed, window);
    let mut p = json!({
        "workflow": opts.workflow.name(),
        "system": system.name(),
        "transport": "inproc",
        "rps_wall": rps,
        "rps_paper": rps * time_scale,
        "duration_s": opts.secs,
        "offered": offered,
        "completed": completed,
        "failed": failed.saturating_sub(expired_in_queue),
        "expired_in_queue": expired_in_queue,
        "shed": shed,
        "cancelled": cancelled,
        "cancel_rate": opts.cancel_rate,
        "schedule": m_end.schedule.as_str(),
        "route": m_end.route.as_str(),
        "goodput_rps": gput,
        "goodput_frac": gput / rps,
        "shed_rate": shed_rate(shed, offered),
        "timeout_paper_s": opts.timeout_paper_s,
        "ingress_policy": ingress_policy,
        "ingress_workers": m_end.workers
    });
    p.insert("latency", tail_rec.summary_scaled(paper).to_json());
    p.insert("latency_ok", ok_rec.summary_scaled(paper).to_json());
    // Per-variant dispatch counts (JSON object keyed by variant name;
    // empty when the config declares no model variants).
    let mut vmap = json_util::Map::new();
    for (name, n) in &m_end.variants {
        vmap.insert(name.clone(), Value::Num(*n as f64));
    }
    p.insert("variants", Value::Obj(vmap));
    // Per-stage latency decomposition (queue-wait / sched-delay / poll /
    // future-wait / engine-service, DESIGN.md §10) of this point's
    // completions, in paper seconds like the latency summaries.
    p.insert("breakdown", m_end.breakdown.scaled(paper).to_json());
    // Per-tenant rows (client-side attribution; see `mix` above): the
    // ROADMAP's "report per-tenant goodput in the rps_sweep schema".
    // `missed` is deadline misses — the starvation signal the
    // noisy-neighbor profile exists to expose.
    let mut tmap = json_util::Map::new();
    for (i, t) in mix.iter().enumerate() {
        let mut row = json!({
            "weight": t.weight,
            "share": t.share,
            "offered": t_offered[i],
            "completed": t_completed[i],
            "shed": t_shed[i],
            "cancelled": t_cancelled[i],
            "missed": t_missed[i],
            "failed": t_failed[i]
        });
        row.insert("goodput_rps", goodput(t_completed[i], window));
        tmap.insert(t.name.clone(), row);
    }
    p.insert("tenants", Value::Obj(tmap));
    Ok(p)
}

/// Fetch `GET /metrics` and return `(time_scale, ingress snapshot)` for
/// `workflow`. Errors if the server does not serve that workflow — the
/// first thing a remote sweep checks, before offering any load.
fn fetch_metrics(client: &mut HttpClient, workflow: &str) -> Result<(f64, Value)> {
    let resp = client.request("GET", "/metrics", &[], "")?;
    if resp.status != 200 {
        return Err(Error::Msg(format!("GET /metrics -> {}", resp.status)));
    }
    let v = resp.json()?;
    let time_scale = v.get("time_scale").as_f64().unwrap_or(1.0);
    let entry = v
        .get("ingress")
        .as_arr()
        .and_then(|a| a.iter().find(|m| m.get("workflow").as_str() == Some(workflow)))
        .cloned()
        .ok_or_else(|| Error::Msg(format!("remote server does not serve workflow `{workflow}`")))?;
    Ok((time_scale, entry))
}

/// One rate point against a live `nalar serve --listen` socket: the same
/// open-loop arrival discipline as [`run_point`], but every submit is a
/// real HTTP request in async-park mode (`X-Nalar-Wait: 0` → `202` +
/// request id), so the pacing loop never blocks on a completion.
/// Outcomes drain through `GET /v1/requests/{id}` polls (`200` done,
/// `202` running, `408` expired, `409` cancelled) and `--cancel-rate`
/// withdraws via `DELETE` — the point proves the wire semantics,
/// including `429` sheds carrying `Retry-After`, under genuine
/// connection reuse. The server owns its deployment: `time_scale`,
/// schedule, admission policy and worker count come back from
/// `GET /metrics`, and its cumulative counters are differenced around
/// the point. The `system` label is taken from the caller's `--systems`
/// head (the wire cannot reveal what mode the server launched in).
fn run_point_remote(opts: &LoadgenOpts, rps: f64, addr: &str) -> Result<Value> {
    // Persistent connections the submit/drain traffic round-robins over.
    const CONNS: usize = 8;
    let mut clients: Vec<HttpClient> = (0..CONNS).map(|_| HttpClient::new(addr)).collect();
    let workflow = opts.workflow.name();
    let (time_scale, m0) = fetch_metrics(&mut clients[0], workflow)?;
    let timeout = Duration::from_secs_f64((opts.timeout_paper_s * time_scale).max(0.001));
    let deadline_hdr = timeout.as_millis().max(1).to_string();
    let window = Duration::from_secs(opts.secs.max(1));

    let arrivals = Arrivals::new(rps, opts.seed ^ rps.to_bits()).schedule(window);
    let offered = arrivals.len() as u64;
    let mut rng = Rng::new(opts.seed ^ 0xFEED);
    let mix: Vec<TenantLoad> = match &opts.tenants {
        Some(t) => t.clone(),
        None => vec![TenantLoad { name: "default".into(), share: 1.0, weight: 1.0 }],
    };
    let total_share: f64 = mix.iter().map(|t| t.share).sum();
    let named_tenants = opts.tenants.is_some();
    let pick_tenant = |rng: &mut Rng| -> usize {
        let mut u = (rng.next_u64() % 1_000_000) as f64 / 1_000_000.0 * total_share;
        for (i, t) in mix.iter().enumerate() {
            u -= t.share;
            if u < 0.0 {
                return i;
            }
        }
        mix.len() - 1
    };

    struct Parked {
        id: u64,
        tenant: usize,
        /// Terminal outcome already collected (a delivered `DELETE`).
        done: bool,
    }
    let submit_path = format!("/v1/workflows/{workflow}/requests");
    let mut parked: Vec<Parked> = Vec::with_capacity(arrivals.len());
    let mut cancels: Vec<(Duration, usize)> = Vec::new(); // (due, parked index)
    let mut shed = 0u64;
    let mut t_offered = vec![0u64; mix.len()];
    let mut t_shed = vec![0u64; mix.len()];
    let mut t_cancelled = vec![0u64; mix.len()];
    let mut next_conn = 0usize;
    let start = Instant::now();
    for at in &arrivals {
        let wait = at.saturating_sub(start.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let now = start.elapsed();
        // Fire due cancels over the wire. A DELETE may lose to
        // completion (409) — the drain below collects the real outcome.
        let mut due: Vec<usize> = Vec::new();
        cancels.retain(|(when, i)| if *when <= now { due.push(*i); false } else { true });
        for i in due {
            let c = &mut clients[next_conn % CONNS];
            next_conn += 1;
            let resp = c.request("DELETE", &format!("/v1/requests/{}", parked[i].id), &[], "")?;
            if resp.status == 200 {
                parked[i].done = true;
                t_cancelled[parked[i].tenant] += 1;
            }
        }
        let progress = (now.as_secs_f64() / window.as_secs_f64()).min(1.0);
        let input = input_for(opts.workflow, progress, 0, &mut rng);
        let tenant = pick_tenant(&mut rng);
        t_offered[tenant] += 1;
        let tname = mix[tenant].name.clone();
        let mut headers: Vec<(&str, &str)> =
            vec![("x-nalar-wait", "0"), ("x-nalar-deadline-ms", &deadline_hdr)];
        if named_tenants {
            headers.push(("x-nalar-tenant", &tname));
        }
        let c = &mut clients[next_conn % CONNS];
        next_conn += 1;
        let resp = c.request("POST", &submit_path, &headers, &input.to_string())?;
        match resp.status {
            202 => {
                let id = resp
                    .json()?
                    .get("request")
                    .as_u64()
                    .ok_or_else(|| Error::Msg("202 accepted without a request id".into()))?;
                parked.push(Parked { id, tenant, done: false });
                if opts.cancel_rate > 0.0 && rng.bool_with(opts.cancel_rate) {
                    let frac = (rng.next_u64() % 1024) as f64 / 1024.0;
                    cancels.push((now + timeout.mul_f64(frac), parked.len() - 1));
                }
            }
            429 => {
                // The shed contract on the wire: the Retry-After hint is
                // part of a 429, not optional.
                if resp.header("retry-after").is_none() {
                    return Err(Error::Msg("429 shed without a Retry-After header".into()));
                }
                shed += 1;
                t_shed[tenant] += 1;
            }
            s => {
                return Err(Error::Msg(format!(
                    "POST {submit_path} -> unexpected {s}: {}",
                    resp.body
                )))
            }
        }
    }
    // Cancels due after the offered window fire at window end.
    for (_, i) in cancels {
        let c = &mut clients[next_conn % CONNS];
        next_conn += 1;
        let resp = c.request("DELETE", &format!("/v1/requests/{}", parked[i].id), &[], "")?;
        if resp.status == 200 {
            parked[i].done = true;
            t_cancelled[parked[i].tenant] += 1;
        }
    }

    // Drain: poll every parked id until it is terminal. The server's
    // deadline sweep turns stragglers into 408s, so this terminates; the
    // cap is a safety net against a wedged server.
    let ok_rec = LatencyRecorder::new();
    let tail_rec = LatencyRecorder::new();
    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut t_completed = vec![0u64; mix.len()];
    let mut t_missed = vec![0u64; mix.len()];
    let mut t_failed = vec![0u64; mix.len()];
    let mut open: Vec<usize> =
        parked.iter().enumerate().filter(|(_, p)| !p.done).map(|(i, _)| i).collect();
    let drain_start = Instant::now();
    let drain_cap = timeout + Duration::from_secs(5);
    while !open.is_empty() {
        let mut still = Vec::new();
        for &i in &open {
            let req = &parked[i];
            let c = &mut clients[next_conn % CONNS];
            next_conn += 1;
            let resp = c.request("GET", &format!("/v1/requests/{}", req.id), &[], "")?;
            match resp.status {
                202 => still.push(i),
                200 => {
                    // Server-side latency, so the distribution measures
                    // serving (comparable to inproc points), not the
                    // client's polling cadence.
                    let ms = resp.json()?.get("latency_ms").as_f64().unwrap_or(0.0);
                    let lat = Duration::from_secs_f64((ms / 1000.0).max(0.0));
                    if lat <= timeout {
                        completed += 1;
                        t_completed[req.tenant] += 1;
                        ok_rec.record(lat);
                        tail_rec.record(lat);
                    } else {
                        // Finished, but past its deadline: served too slow.
                        failed += 1;
                        t_missed[req.tenant] += 1;
                        tail_rec.record(timeout);
                    }
                }
                408 => {
                    failed += 1;
                    t_missed[req.tenant] += 1;
                    tail_rec.record(timeout);
                }
                409 => t_cancelled[req.tenant] += 1,
                _ => {
                    failed += 1;
                    t_failed[req.tenant] += 1;
                    tail_rec.record(timeout);
                }
            }
        }
        if still.is_empty() {
            break;
        }
        if drain_start.elapsed() > drain_cap {
            return Err(Error::Msg(format!(
                "{} remote requests still unresolved past their deadlines",
                still.len()
            )));
        }
        open = still;
        std::thread::sleep(Duration::from_millis(5));
    }

    // Leak gate over the wire: with every outcome collected, the
    // server's scheduler tables for this workflow must drain to empty
    // (bounded grace for sweep bookkeeping, as in the inproc gate).
    let (_, mut m1) = fetch_metrics(&mut clients[0], workflow)?;
    let leak_of = |m: &Value| {
        let tenant_depth = m
            .get("tenants")
            .as_arr()
            .map(|a| a.iter().map(|t| t.get("depth").as_u64().unwrap_or(0)).max().unwrap_or(0))
            .unwrap_or(0);
        (
            m.get("in_flight").as_u64().unwrap_or(0),
            m.get("depth").as_u64().unwrap_or(0),
            tenant_depth,
        )
    };
    let drained_at = Instant::now();
    while leak_of(&m1) != (0, 0, 0) && drained_at.elapsed() < Duration::from_secs(2) {
        std::thread::sleep(Duration::from_millis(5));
        m1 = fetch_metrics(&mut clients[0], workflow)?.1;
    }
    let leak = leak_of(&m1);
    if leak != (0, 0, 0) {
        return Err(Error::Msg(format!(
            "remote scheduler table leak after full drain: in_flight {} depth {} \
             max-tenant-sub-queue {} ({workflow} @ {rps:.0} rps via {addr})",
            leak.0, leak.1, leak.2,
        )));
    }
    // The server's counters are cumulative across points; deltas against
    // the pre-point snapshot are this point's share.
    let delta = |key: &str| {
        m1.get(key).as_u64().unwrap_or(0).saturating_sub(m0.get(key).as_u64().unwrap_or(0))
    };
    let expired_in_queue = delta("expired_in_queue");
    let cancelled = delta("cancelled");

    let paper = 1.0 / time_scale;
    let gput = goodput(completed, window);
    let system = opts.systems.first().map(|s| s.name()).unwrap_or("nalar");
    let mut p = json!({
        "workflow": workflow,
        "system": system,
        "transport": "http",
        "remote": addr,
        "rps_wall": rps,
        "rps_paper": rps * time_scale,
        "duration_s": opts.secs,
        "offered": offered,
        "completed": completed,
        "failed": failed.saturating_sub(expired_in_queue),
        "expired_in_queue": expired_in_queue,
        "shed": shed,
        "cancelled": cancelled,
        "cancel_rate": opts.cancel_rate,
        "schedule": m1.get("schedule").as_str().unwrap_or("?"),
        "route": m1.get("route").as_str().unwrap_or("?"),
        "goodput_rps": gput,
        "goodput_frac": gput / rps,
        "shed_rate": shed_rate(shed, offered),
        "timeout_paper_s": opts.timeout_paper_s,
        "ingress_policy": m1.get("policy").as_str().unwrap_or("?"),
        "ingress_workers": m1.get("workers").as_u64().unwrap_or(0)
    });
    p.insert("latency", tail_rec.summary_scaled(paper).to_json());
    p.insert("latency_ok", ok_rec.summary_scaled(paper).to_json());
    // Per-stage decomposition from the server's snapshot, rescaled to
    // paper seconds. Histogram buckets cannot be differenced the way the
    // counters above are, so remote points carry the server's cumulative
    // distribution up to this point — comparable across a sweep only in
    // aggregate, unlike the per-point inproc breakdowns.
    let src = m1.get("breakdown");
    let mut bd = json_util::Map::new();
    for stage in crate::metrics::STAGE_NAMES {
        let stat = src.get(stage);
        let mut row = json!({ "count": stat.get("count").as_u64().unwrap_or(0) });
        row.insert("p50", stat.get("p50").as_f64().unwrap_or(0.0) * paper);
        row.insert("p95", stat.get("p95").as_f64().unwrap_or(0.0) * paper);
        row.insert("p99", stat.get("p99").as_f64().unwrap_or(0.0) * paper);
        bd.insert(stage.to_string(), row);
    }
    p.insert("breakdown", Value::Obj(bd));
    let mut tmap = json_util::Map::new();
    for (i, t) in mix.iter().enumerate() {
        let mut row = json!({
            "weight": t.weight,
            "share": t.share,
            "offered": t_offered[i],
            "completed": t_completed[i],
            "shed": t_shed[i],
            "cancelled": t_cancelled[i],
            "missed": t_missed[i],
            "failed": t_failed[i]
        });
        row.insert("goodput_rps", goodput(t_completed[i], window));
        tmap.insert(t.name.clone(), row);
    }
    p.insert("tenants", Value::Obj(tmap));
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_writes_schema_valid_report() {
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![30.0],
            session_pool: 8,
            timeout_paper_s: 60.0,
            time_scale: Some(0.0005),
            out_dir: dir.clone(),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let path = run(&opts).unwrap();
        assert!(path.ends_with("BENCH_rps_sweep.json"));
        bench::check_files(&dir, &[bench::RPS_SWEEP]).unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let pts = report.get("points").as_arr().unwrap();
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert!(p.get("completed").as_u64().unwrap() > 0, "nothing completed");
        assert_eq!(p.get("transport").as_str(), Some("inproc"), "local points are in-process");
        assert_eq!(p.get("ingress_policy").as_str(), Some("bounded"));
        assert!(p.get("expired_in_queue").as_u64().is_some(), "new-schema field missing");
        assert_eq!(p.get("cancelled").as_u64(), Some(0), "no --cancel-rate: none cancelled");
        assert_eq!(p.get("schedule").as_str(), Some("fifo"), "config default ordering");
        assert_eq!(p.get("route").as_str(), Some("fixed"), "routing is inert by default");
        assert!(p.get("ingress_workers").as_u64().unwrap() >= 1);
        assert!(p.get("latency").get("p99").as_f64().is_some());
        // per-stage decomposition: all five components present, and the
        // fold saw every completion
        let bd = p.get("breakdown").as_obj().expect("breakdown map required");
        assert_eq!(bd.len(), crate::metrics::STAGE_NAMES.len());
        for stage in crate::metrics::STAGE_NAMES {
            let row = p.get("breakdown").get(stage);
            assert!(row.get("p95").as_f64().is_some(), "{stage} needs quantiles");
            // folds once per server-side success: at least the
            // within-deadline completions, never more than was offered
            let count = row.get("count").as_u64().unwrap();
            assert!(count >= p.get("completed").as_u64().unwrap(), "{stage} undercounted");
            assert!(count <= p.get("offered").as_u64().unwrap(), "{stage} overcounted");
        }
        // no --tenants: the per-tenant map still exists, with everything
        // attributed to the single logical `default` tenant
        let tenants = p.get("tenants").as_obj().expect("tenants map required");
        assert_eq!(tenants.len(), 1);
        let def = p.get("tenants").get("default");
        assert_eq!(def.get("offered").as_u64(), p.get("offered").as_u64());
        assert_eq!(def.get("completed").as_u64(), p.get("completed").as_u64());
        assert!(def.get("goodput_rps").as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn axis_specs_reject_typos_at_parse_time() {
        // --schedule: every entry checked against the scheduler's own
        // name authority, so a typo dies at flag-parse time
        assert_eq!(
            parse_schedule_axis("fifo,deadline_slack").unwrap(),
            vec!["fifo".to_string(), "deadline_slack".to_string()]
        );
        assert_eq!(parse_schedule_axis(" stage ").unwrap(), vec!["stage".to_string()]);
        for bad in ["", "fifo,", "sjf", "deadline-slack", "fifo,fifo"] {
            assert!(parse_schedule_axis(bad).is_none(), "must reject `{bad}`");
        }
        // --route: same contract against the router's name authority
        assert_eq!(
            parse_route_axis("fixed,jit").unwrap(),
            vec!["fixed".to_string(), "jit".to_string()]
        );
        assert_eq!(parse_route_axis("jit,fixed-large").unwrap().len(), 2);
        for bad in ["", "jit,", "jti", "fixed-", "adaptive", "jit,jit"] {
            assert!(parse_route_axis(bad).is_none(), "must reject `{bad}`");
        }
    }

    #[test]
    fn unknown_route_axis_fails_fast() {
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![10.0],
            routes: Some(vec!["warp".into()]),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let err = run(&opts).unwrap_err();
        assert!(err.to_string().contains("unknown route"), "{err}");
    }

    #[test]
    fn parse_tenant_mix_specs() {
        let noisy = parse_tenant_mix("noisy").unwrap();
        assert_eq!(noisy.len(), 2);
        assert_eq!(noisy[0].name, "hog");
        assert_eq!(noisy[0].share, 10.0);
        assert_eq!(noisy[0].weight, noisy[1].weight, "noisy neighbors have equal weights");
        let mix = parse_tenant_mix("a:10,b:1:3").unwrap();
        assert_eq!(mix[0].weight, 1.0, "weight defaults to 1");
        assert_eq!((mix[1].share, mix[1].weight), (1.0, 3.0));
        for bad in ["", "a", "a:0", "a:-1", "a:1:0", "a:1,a:2", ":1", "a:x", "a:1:1:1"] {
            assert!(parse_tenant_mix(bad).is_none(), "must reject `{bad}`");
        }
    }

    #[test]
    fn noisy_neighbor_axis_reports_per_tenant_rows() {
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-nn-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![40.0],
            session_pool: 8,
            timeout_paper_s: 60.0,
            time_scale: Some(0.0005),
            out_dir: dir.clone(),
            tenants: Some(noisy_neighbor()),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let path = run(&opts).unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let p = &report.get("points").as_arr().unwrap()[0];
        let tenants = p.get("tenants").as_obj().expect("per-tenant map");
        assert_eq!(tenants.len(), 2, "hog + meek");
        let (hog, meek) = (p.get("tenants").get("hog"), p.get("tenants").get("meek"));
        let offered_sum =
            hog.get("offered").as_u64().unwrap() + meek.get("offered").as_u64().unwrap();
        assert_eq!(Some(offered_sum), p.get("offered").as_u64(), "shares partition arrivals");
        assert!(
            hog.get("offered").as_u64().unwrap() > meek.get("offered").as_u64().unwrap(),
            "a 10:1 share split must make the hog dominate the offered load"
        );
        assert_eq!(hog.get("weight").as_f64(), Some(1.0));
        assert!(hog.get("completed").as_u64().unwrap() > 0, "uncontended point must complete");
        // exact per-tenant accounting: every arrival of a tenant lands in
        // exactly one of its terminal columns
        for row in [&hog, &meek] {
            let accounted = row.get("completed").as_u64().unwrap()
                + row.get("shed").as_u64().unwrap()
                + row.get("cancelled").as_u64().unwrap()
                + row.get("missed").as_u64().unwrap()
                + row.get("failed").as_u64().unwrap();
            assert_eq!(Some(accounted), row.get("offered").as_u64());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancel_rate_and_schedule_axis_flow_into_the_report() {
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-cx-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // One slow worker serializes the burst, so queueing delay dwarfs
        // service time and a fair share of the seeded cancels land while
        // their request is still queued or parked.
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![60.0],
            session_pool: 8,
            timeout_paper_s: 120.0,
            time_scale: Some(0.01),
            workers: Some(1),
            out_dir: dir.clone(),
            cancel_rate: 0.5,
            schedules: Some(vec!["fifo".into(), "deadline_slack".into()]),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let path = run(&opts).unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let pts = report.get("points").as_arr().unwrap();
        assert_eq!(pts.len(), 2, "one point per schedule-axis entry");
        assert_eq!(pts[0].get("schedule").as_str(), Some("fifo"));
        assert_eq!(pts[1].get("schedule").as_str(), Some("deadline_slack"));
        let cancelled: u64 = pts.iter().map(|p| p.get("cancelled").as_u64().unwrap()).sum();
        assert!(cancelled > 0, "a 50% cancel rate against a backed-up queue must land some");
        for p in pts {
            assert_eq!(p.get("cancel_rate").as_f64(), Some(0.5));
            let offered = p.get("offered").as_u64().unwrap();
            let accounted = p.get("completed").as_u64().unwrap()
                + p.get("failed").as_u64().unwrap()
                + p.get("expired_in_queue").as_u64().unwrap()
                + p.get("shed").as_u64().unwrap()
                + p.get("cancelled").as_u64().unwrap();
            assert_eq!(accounted, offered, "every request has exactly one terminal outcome");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hc_gate_fails_when_admitted_work_cannot_complete() {
        // A zero-second deadline guarantees nothing completes; the
        // completion gate must turn that into an error instead of a
        // quietly-degraded report.
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-hc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            rates: vec![50.0],
            secs: 1,
            session_pool: 4,
            // 1ms effective deadline against ~12ms of service time:
            // nothing admitted can finish in time.
            timeout_paper_s: 0.0,
            time_scale: Some(0.01),
            out_dir: dir.clone(),
            workers: Some(2),
            expect_admitted_complete: true,
            ..LoadgenOpts::hc_smoke(WorkflowKind::Router)
        };
        let err = run(&opts).unwrap_err();
        assert!(err.to_string().contains("high-concurrency gate"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
