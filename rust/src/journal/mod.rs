//! Durable request journal: the append-only event log crash recovery
//! replays (DESIGN.md §12).
//!
//! Every front-door request leaves a per-node trail of JSON-lines
//! records here — admission (with its tenant/queue assignment), start,
//! stage transitions (with the driver's serialized continuation state
//! and the future ids it parked on), future resolutions, and exactly
//! one terminal outcome. On restart, [`load`] folds the log into a
//! [`RecoveryPlan`]: requests whose terminal record made it to disk are
//! *skipped* (their outcome already reached the caller — replaying them
//! would double-execute side effects), requests that were in flight
//! when the node died are *re-admitted* with their original
//! request/session ids and re-parked by the scheduler, re-issuing the
//! stage's unresolved futures instead of failing the request.
//!
//! Design points, in the order they matter:
//!
//! * **Append-only, one JSON object per line.** A torn final line —
//!   the normal signature of a crash mid-append — parses as garbage
//!   and is *tolerated*: [`load`] counts it (`corrupt`) and keeps
//!   going. Everything before the tear is intact because records are
//!   only ever appended.
//! * **Per-request causal order is file order.** The `admitted` record
//!   is written under the owning scheduler shard's lock, before any
//!   worker can pop the request, so it strictly precedes every other
//!   record of that request. Recovery re-admissions append a *fresh*
//!   `admitted` record for the same request id — latest-admit-wins in
//!   [`load`], which is what lets one journal file span any number of
//!   crash/recover cycles.
//! * **Exactly one terminal record.** Terminal appends are gated on
//!   winning the ticket's `fulfil` race (the same arbitration the
//!   counters use), so however completion, expiry and cancellation
//!   race, the journal agrees with the ticket.
//! * **Fsync policy** ([`FsyncPolicy`], config `ingress.journal.fsync`):
//!   `always` syncs every record (crash-consistent to the last record,
//!   slowest), `batch` syncs every [`BATCH_SYNC_EVERY`] records
//!   (bounded loss window), `never` only flushes to the OS (survives
//!   process death, not power loss). All three flush the userspace
//!   buffer per record, so an in-process reader — and the kill-and-
//!   recover bench — always sees a complete prefix.
//!
//! The writer is deliberately dumb: no index, no compaction, no mmap.
//! Recovery cost is one sequential read, and the file is bounded in
//! practice by rotation at the deployment layer (out of scope here —
//! see DESIGN.md §12 for the rotation story).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// How often the `batch` policy issues an fsync, in records.
pub const BATCH_SYNC_EVERY: u64 = 64;

/// Durability level for journal appends (`ingress.journal.fsync`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every record: the journal is crash-consistent to the last
    /// appended record, at one disk sync per lifecycle event.
    Always,
    /// fsync every [`BATCH_SYNC_EVERY`] records: bounded loss window on
    /// power loss, near-`never` throughput. The default.
    Batch,
    /// Flush to the OS only: survives process death (SIGKILL), not
    /// kernel panic or power loss.
    Never,
}

impl FsyncPolicy {
    pub fn parse(name: &str) -> Result<FsyncPolicy> {
        match name {
            "always" => Ok(FsyncPolicy::Always),
            "batch" => Ok(FsyncPolicy::Batch),
            "never" => Ok(FsyncPolicy::Never),
            other => Err(Error::Config(format!(
                "ingress.journal.fsync must be `always`, `batch` or `never`, got `{other}`"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::Batch => "batch",
            FsyncPolicy::Never => "never",
        }
    }
}

struct Writer {
    out: BufWriter<File>,
    /// Records appended since the last sync (the `batch` counter).
    since_sync: u64,
}

/// An open append-only journal file. Shared by every scheduler shard;
/// appends serialize on one internal mutex (a single fd has one append
/// position anyway).
pub struct Journal {
    path: PathBuf,
    fsync: FsyncPolicy,
    w: Mutex<Writer>,
    records: AtomicU64,
    errors: AtomicU64,
}

impl Journal {
    /// Open (creating if absent) `path` for appending. An existing file
    /// is *kept* — recovery appends to the same log it replayed, so one
    /// file spans crash/recover cycles.
    pub fn open(path: &Path, fsync: FsyncPolicy) -> Result<Arc<Journal>> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(Error::Io)?;
        Ok(Arc::new(Journal {
            path: path.to_path_buf(),
            fsync,
            w: Mutex::new(Writer { out: BufWriter::new(file), since_sync: 0 }),
            records: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        }))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Records appended through this handle (not counting what the file
    /// already held when opened).
    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    /// Append failures since open. A failing journal must not take the
    /// serving path down with it — appends report here (and once to
    /// stderr) instead of panicking; durability is degraded, serving is
    /// not.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Append one record as a single compact JSON line, then flush and
    /// (per policy) sync.
    pub fn append(&self, rec: &Value) {
        let mut g = self.w.lock().unwrap();
        let r = writeln!(g.out, "{rec}").and_then(|()| g.out.flush()).and_then(|()| {
            g.since_sync += 1;
            let due = match self.fsync {
                FsyncPolicy::Always => true,
                FsyncPolicy::Batch => g.since_sync >= BATCH_SYNC_EVERY,
                FsyncPolicy::Never => false,
            };
            if due {
                g.since_sync = 0;
                g.out.get_ref().sync_data()
            } else {
                Ok(())
            }
        });
        drop(g);
        match r {
            Ok(()) => {
                self.records.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                if self.errors.fetch_add(1, Ordering::Relaxed) == 0 {
                    eprintln!("journal: append to {} failed: {e}", self.path.display());
                }
            }
        }
    }

    /// Force an fsync now (shutdown path for `batch`/`never`).
    pub fn sync(&self) {
        let mut g = self.w.lock().unwrap();
        g.since_sync = 0;
        let _ = g.out.flush().and_then(|()| g.out.get_ref().sync_data());
    }
}

/// The journal slot every scheduler hot path writes through: `Disabled`
/// (the default — every append is one enum-discriminant branch) or an
/// open [`Journal`]. Mirrors [`crate::trace::TraceSink`]'s shape so
/// call sites guard expensive record construction with
/// [`Self::enabled`].
#[derive(Clone)]
pub enum JournalSink {
    Disabled,
    Writing(Arc<Journal>),
}

impl std::fmt::Debug for JournalSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JournalSink::Disabled => f.write_str("JournalSink::Disabled"),
            JournalSink::Writing(j) => write!(f, "JournalSink({})", j.path().display()),
        }
    }
}

impl JournalSink {
    pub fn disabled() -> JournalSink {
        JournalSink::Disabled
    }

    /// Open `path` for appending and wrap it as a sink.
    pub fn open(path: &Path, fsync: FsyncPolicy) -> Result<JournalSink> {
        Ok(JournalSink::Writing(Journal::open(path, fsync)?))
    }

    pub fn enabled(&self) -> bool {
        matches!(self, JournalSink::Writing(_))
    }

    pub fn append(&self, rec: &Value) {
        if let JournalSink::Writing(j) = self {
            j.append(rec);
        }
    }

    pub fn sync(&self) {
        if let JournalSink::Writing(j) = self {
            j.sync();
        }
    }

    pub fn journal(&self) -> Option<&Arc<Journal>> {
        match self {
            JournalSink::Writing(j) => Some(j),
            JournalSink::Disabled => None,
        }
    }
}

// ---------------------------------------------------------------------
// Record taxonomy (constructors keep every emission site on one schema;
// DESIGN.md §12 documents the wire shape).

fn record(t: &str, request: u64) -> Value {
    let mut r = Value::Obj(json::Map::new());
    r.insert("t", t);
    r.insert("request", request);
    r
}

/// Admission: the request exists, charged to `tenant` in `workflow`'s
/// queue. Carries everything re-admission needs to rebuild the request
/// from scratch.
pub fn admitted(
    request: u64,
    session: u64,
    tenant: &str,
    workflow: &str,
    input: &Value,
    timeout_ms: u64,
) -> Value {
    let mut r = record("admitted", request);
    r.insert("session", session);
    r.insert("tenant", tenant);
    r.insert("workflow", workflow);
    r.insert("input", input.clone());
    r.insert("timeout_ms", timeout_ms);
    r
}

/// The scheduler popped the request and built (or restored) its driver.
pub fn started(request: u64) -> Value {
    record("started", request)
}

/// The driver suspended at `stage`: `state` is its serialized
/// continuation ([`crate::workflow::Driver::serialize_state`]),
/// `waiting` the future ids it parked on. The *latest* parked record
/// wins at replay — it supersedes earlier stages.
pub fn parked(request: u64, stage: u32, state: Value, waiting: &[u64]) -> Value {
    let mut r = record("parked", request);
    r.insert("stage", stage);
    r.insert("state", state);
    r.insert("waiting", waiting);
    r
}

/// A future the request parked on reached a terminal state (the waker
/// fired). Evidence for the crash window between a resolve and the
/// requester's resume; replay re-issues the stage's futures afresh
/// rather than trusting this record, so a resolve that raced the crash
/// is never double-consumed.
pub fn resolved(request: u64, future: u64) -> Value {
    let mut r = record("resolved", request);
    r.insert("future", future);
    r
}

/// The request's single terminal outcome. `outcome` is one of
/// `done | failed | expired | cancelled | shed`; `detail` is the result
/// value for `done` and the error string otherwise. Exactly one of
/// these per request per (crash-free) lifetime — gated on winning the
/// ticket's fulfil race.
pub fn terminal(request: u64, outcome: &str, detail: Value) -> Value {
    let mut r = record("terminal", request);
    r.insert("outcome", outcome);
    r.insert("detail", detail);
    r
}

// ---------------------------------------------------------------------
// Replay.

/// One in-flight request reconstructed from the journal: everything
/// re-admission needs. `state`/`stage` are from its latest `parked`
/// record (`Null`/0 if it never parked — it replays from the workflow
/// input alone).
#[derive(Debug)]
pub struct ReplayEntry {
    pub request: u64,
    pub session: u64,
    pub tenant: String,
    pub workflow: String,
    pub input: Value,
    pub timeout_ms: u64,
    pub stage: u32,
    pub state: Value,
}

/// What [`load`] recovered from a journal file.
#[derive(Debug, Default)]
pub struct RecoveryPlan {
    /// Requests admitted but without a terminal record: re-admit these.
    /// Ordered by request id (admission order — ids are monotonic).
    pub inflight: Vec<ReplayEntry>,
    /// Requests whose terminal outcome reached the journal: skipped
    /// (their caller already has the result).
    pub completed: u64,
    /// Unparseable or malformed lines — normally the single torn line a
    /// crash leaves at the tail.
    pub corrupt: u64,
    /// Highest ids observed anywhere in the log. The recovering node
    /// advances its generators past these so fresh ids never collide
    /// with replayed ones.
    pub max_session: u64,
    pub max_request: u64,
    pub max_future: u64,
}

#[derive(Default)]
struct PendingEntry {
    admitted: bool,
    session: u64,
    tenant: String,
    workflow: String,
    input: Value,
    timeout_ms: u64,
    stage: u32,
    state: Value,
    terminal: bool,
}

/// Fold a journal file into a [`RecoveryPlan`]. A missing file is an
/// empty plan (first boot); unreadable *content* is tolerated line by
/// line (counted `corrupt`), because the one guaranteed artifact of a
/// crash is a torn final line.
pub fn load(path: &Path) -> Result<RecoveryPlan> {
    let file = match File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(RecoveryPlan::default()),
        Err(e) => return Err(Error::Io(e)),
    };
    let mut plan = RecoveryPlan::default();
    let mut entries: BTreeMap<u64, PendingEntry> = BTreeMap::new();
    let mut reader = BufReader::new(file);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e) => return Err(Error::Io(e)),
        }
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        let Ok(rec) = json::parse(text) else {
            plan.corrupt += 1;
            continue;
        };
        let (Some(request), Some(t)) = (rec.get("request").as_u64(), rec.get("t").as_str())
        else {
            plan.corrupt += 1;
            continue;
        };
        plan.max_request = plan.max_request.max(request);
        match t {
            "admitted" => {
                let session = rec.u64_or("session", 0);
                plan.max_session = plan.max_session.max(session);
                let e = entries.entry(request).or_default();
                // Latest-admit-wins: a re-admission after recovery
                // restarts this request's lifecycle in the same file.
                e.admitted = true;
                e.terminal = false;
                e.session = session;
                e.tenant = rec.str_or("tenant", "default").to_string();
                e.workflow = rec.str_or("workflow", "").to_string();
                e.input = rec.get("input").clone();
                e.timeout_ms = rec.u64_or("timeout_ms", 0);
                e.stage = 0;
                e.state = Value::Null;
            }
            "started" => {}
            "parked" => {
                if let Value::Arr(ids) = rec.get("waiting") {
                    for id in ids {
                        plan.max_future = plan.max_future.max(id.as_u64().unwrap_or(0));
                    }
                }
                if let Some(e) = entries.get_mut(&request) {
                    e.stage = rec.u64_or("stage", 0) as u32;
                    e.state = rec.get("state").clone();
                }
            }
            "resolved" => {
                plan.max_future = plan.max_future.max(rec.u64_or("future", 0));
            }
            "terminal" => {
                entries.entry(request).or_default().terminal = true;
            }
            _ => plan.corrupt += 1,
        }
    }
    for (request, e) in entries {
        if e.terminal {
            plan.completed += 1;
        } else if e.admitted {
            plan.inflight.push(ReplayEntry {
                request,
                session: e.session,
                tenant: e.tenant,
                workflow: e.workflow,
                input: e.input,
                timeout_ms: e.timeout_ms,
                stage: e.stage,
                state: e.state,
            });
        } else {
            // records for a request whose admission never hit the disk
            // (lost to an fsync window): nothing to replay
            plan.corrupt += 1;
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nalar-journal-{}-{name}.jsonl", std::process::id()))
    }

    #[test]
    fn fsync_policy_parses_and_rejects() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("batch").unwrap(), FsyncPolicy::Batch);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(FsyncPolicy::Batch.name(), "batch");
        let err = FsyncPolicy::parse("sometimes").unwrap_err();
        assert!(matches!(err, Error::Config(..)), "{err}");
    }

    #[test]
    fn missing_file_is_an_empty_plan() {
        let plan = load(Path::new("/nonexistent/nalar-test-journal.jsonl")).unwrap();
        assert!(plan.inflight.is_empty());
        assert_eq!((plan.completed, plan.corrupt), (0, 0));
    }

    #[test]
    fn append_load_roundtrip_separates_completed_from_inflight() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let j = Journal::open(&path, FsyncPolicy::Never).unwrap();
        // request 1: full lifecycle, terminal on disk -> skipped
        j.append(&admitted(1, 10, "default", "router", &json!({"prompt": "a"}), 30_000));
        j.append(&started(1));
        j.append(&parked(1, 1, json!({"at": "classify"}), &[100]));
        j.append(&resolved(1, 100));
        j.append(&terminal(1, "done", json!({"reply": "ok"})));
        // request 2: parked mid-flight, no terminal -> replayed
        j.append(&admitted(2, 11, "meek", "router", &json!({"prompt": "b"}), 5_000));
        j.append(&started(2));
        j.append(&parked(2, 2, json!({"at": "chat"}), &[101, 102]));
        assert_eq!(j.records(), 8);
        assert_eq!(j.errors(), 0);
        drop(j);
        let plan = load(&path).unwrap();
        assert_eq!(plan.completed, 1);
        assert_eq!(plan.corrupt, 0);
        assert_eq!(plan.inflight.len(), 1);
        let e = &plan.inflight[0];
        assert_eq!((e.request, e.session), (2, 11));
        assert_eq!(e.tenant, "meek");
        assert_eq!(e.workflow, "router");
        assert_eq!(e.timeout_ms, 5_000);
        assert_eq!(e.stage, 2);
        assert_eq!(e.state.get("at").as_str(), Some("chat"));
        assert_eq!(e.input.get("prompt").as_str(), Some("b"));
        assert_eq!(plan.max_session, 11);
        assert_eq!(plan.max_request, 2);
        assert_eq!(plan.max_future, 102, "waker-side futures count into the high-water mark");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_tail_is_tolerated_not_fatal() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let j = Journal::open(&path, FsyncPolicy::Always).unwrap();
        j.append(&admitted(7, 3, "default", "swe", &json!({"task": "t"}), 1_000));
        drop(j);
        // simulate a crash mid-append: a half-written final line
        use std::io::Write as _;
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"t\": \"termi").unwrap();
        drop(f);
        let plan = load(&path).unwrap();
        assert_eq!(plan.corrupt, 1, "the torn line is counted, not fatal");
        assert_eq!(plan.inflight.len(), 1, "the intact prefix still replays");
        assert_eq!(plan.inflight[0].request, 7);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn latest_admission_wins_across_recovery_cycles() {
        let path = tmp("cycles");
        let _ = std::fs::remove_file(&path);
        let j = Journal::open(&path, FsyncPolicy::Batch).unwrap();
        // first lifetime: parked, then the node died
        j.append(&admitted(5, 2, "default", "financial", &json!({"question": "q"}), 9_000));
        j.append(&parked(5, 1, json!({"at": "join"}), &[50]));
        // recovery re-admitted it into the same file, and it completed
        j.append(&admitted(5, 2, "default", "financial", &json!({"question": "q"}), 9_000));
        j.append(&terminal(5, "done", json!("summary")));
        drop(j);
        let plan = load(&path).unwrap();
        assert_eq!(plan.completed, 1, "the re-admitted lifecycle reached terminal");
        assert!(plan.inflight.is_empty(), "nothing left to replay");
        // ...and a third lifetime would start from a clean slate again
        let j = Journal::open(&path, FsyncPolicy::Batch).unwrap();
        j.append(&admitted(5, 2, "default", "financial", &json!({"question": "q"}), 9_000));
        drop(j);
        let plan = load(&path).unwrap();
        assert_eq!(plan.completed, 0);
        assert_eq!(plan.inflight.len(), 1, "latest admission reopens the request");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn disabled_sink_is_a_no_op() {
        let sink = JournalSink::disabled();
        assert!(!sink.enabled());
        sink.append(&terminal(1, "done", Value::Null)); // must not panic
        sink.sync();
        assert!(sink.journal().is_none());
    }
}
