//! Futures as first-class runtime objects (paper §3.2, §4.3.1).
//!
//! A NALAR future represents one long-running agent/tool invocation. Unlike
//! Ray/CIEL futures it is *selectively mutable*: the value is immutable once
//! materialized, but metadata (executor, consumers, priority) stays mutable
//! so the control plane can late-bind and migrate work after it has been
//! routed (Property 1). The three runtime operations (Figure 7):
//!
//! * **Op 1 — create** (non-blocking): the stub allocates the cell and hands
//!   the call to the target's component controller.
//! * **Op 2 — register consumer** (non-blocking): first access from a driver
//!   or agent records it in `consumers`, feeding dynamic dependency-graph
//!   extraction (Property 2).
//! * **Op 3 — return** (blocking): `value().await` parks on the cell until
//!   the producer pushes readiness (Property 3).

mod future;
mod graph;
mod table;

pub use future::{FutureCell, FutureHandle, FutureMeta, FutureState, Value, WakeSignal, Waker};
pub use graph::DepGraph;
pub use table::FutureTable;
