//! The future cell: immutable-once value, mutable metadata, push readiness.
//!
//! Readiness is delivered two ways from the same resolution site:
//!
//! * **Blocking** (`value(timeout)`): the caller parks on the cell's
//!   condvar exactly like the paper's `future.value(timeout=t)` blocks the
//!   Python caller. Component controllers and the closed-loop harness use
//!   this path — they own their threads.
//! * **Push** (`subscribe`): a [`Waker`] callback fired exactly once when
//!   the cell reaches a terminal state. Resumable workflow drivers
//!   ([`crate::workflow::Driver`]) and the event-driven ingress scheduler
//!   use this path — an in-flight request is a stored continuation, not a
//!   parked thread, so readiness must come to *it*.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::ids::{AgentType, FutureId, InstanceId, Location, RequestId, SessionId};
use crate::util::json;

/// Payload carried by a resolved future. JSON keeps the driver programming
/// model close to the paper's "ordinary Python" values.
pub type Value = json::Value;

/// Lifecycle of a future. `Ready`/`Failed` are terminal; the value never
/// changes after either (Property 1: immutable data).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FutureState {
    /// Created by a stub, not yet accepted by a component controller.
    Created,
    /// In some instance's local queue.
    Queued,
    /// Executing on its `executor` instance.
    Running,
    /// Value materialized and pushed to consumers.
    Ready,
    /// Failed; drivers observe the error and may retry (paper §5).
    Failed,
}

/// Structured metadata (paper Table 3) — everything component-level
/// controllers need to route, migrate and propagate without the global
/// controller supervising each step.
#[derive(Debug, Clone)]
pub struct FutureMeta {
    pub id: FutureId,
    pub session: SessionId,
    pub request: RequestId,
    /// Agent type that computes this future.
    pub agent: AgentType,
    /// Method name on the agent (from the stub's declaration).
    pub method: String,
    /// Who created the call (Table 3 `creator`).
    pub creator: Location,
    /// Where it is slated to execute (Table 3 `executor`) — mutable until
    /// the future starts running; migration rewrites it.
    pub executor: Option<InstanceId>,
    /// Registered consumers (Table 3 `consumers`) — mutable.
    pub consumers: Vec<Location>,
    /// Upstream futures whose values feed this call (Table 3 `dependencies`).
    pub dependencies: Vec<FutureId>,
    /// Scheduling priority (higher = sooner); set by `set_priority`.
    pub priority: i32,
    /// Call-graph depth of the creating frame (SRTF stage heuristic, §6.2).
    pub stage: u32,
    /// How many times this logical task re-entered the graph (LPT, §6.2).
    pub retry_count: u32,
    /// Estimated service cost in scaled seconds (engine profile estimate).
    pub est_cost: f64,
    /// When the future was created (queue-wait measurement).
    pub created_at: Instant,
}

impl FutureMeta {
    pub fn new(
        id: FutureId,
        session: SessionId,
        request: RequestId,
        agent: AgentType,
        method: impl Into<String>,
        creator: Location,
    ) -> Self {
        FutureMeta {
            id,
            session,
            request,
            agent,
            method: method.into(),
            creator,
            executor: None,
            consumers: Vec::new(),
            dependencies: Vec::new(),
            priority: 0,
            stage: 0,
            retry_count: 0,
            est_cost: 0.0,
            created_at: Instant::now(),
        }
    }
}

/// Push-readiness callback: fired exactly once, after the cell reaches
/// `Ready` or `Failed` (or immediately at subscription if it already has).
/// Always invoked *outside* the cell lock, so a waker may freely take
/// other locks (the ingress scheduler's ready-queue lock, for one).
pub type Waker = Box<dyn FnOnce() + Send>;

struct Inner {
    state: FutureState,
    value: Option<Arc<Value>>,
    error: Option<String>,
    meta: FutureMeta,
    /// Busy-time actually spent executing (telemetry).
    service_us: u64,
    /// Wakers to fire on the transition to a terminal state.
    wakers: Vec<Waker>,
}

/// Shared future cell. Producers resolve it exactly once; consumers block
/// on the condvar until push-based readiness. All metadata mutation goes
/// through here so controllers and drivers see one consistent view.
pub struct FutureCell {
    pub id: FutureId,
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl FutureCell {
    pub fn new(meta: FutureMeta) -> Arc<Self> {
        Arc::new(FutureCell {
            id: meta.id,
            inner: Mutex::new(Inner {
                state: FutureState::Created,
                value: None,
                error: None,
                meta,
                service_us: 0,
                wakers: Vec::new(),
            }),
            ready: Condvar::new(),
        })
    }

    // ----------------------------------------------------------- state
    pub fn state(&self) -> FutureState {
        self.inner.lock().unwrap().state
    }

    /// `future.available()` from the paper's futures API.
    pub fn available(&self) -> bool {
        matches!(self.state(), FutureState::Ready | FutureState::Failed)
    }

    pub fn mark_queued(&self, instance: InstanceId) {
        let mut i = self.inner.lock().unwrap();
        if matches!(i.state, FutureState::Created | FutureState::Queued) {
            i.state = FutureState::Queued;
            i.meta.executor = Some(instance);
        }
    }

    pub fn mark_running(&self) {
        let mut i = self.inner.lock().unwrap();
        if i.state == FutureState::Queued {
            i.state = FutureState::Running;
        }
    }

    /// Time spent waiting so far (HOL-blocking detection).
    pub fn queue_wait(&self) -> Duration {
        let i = self.inner.lock().unwrap();
        match i.state {
            FutureState::Created | FutureState::Queued => i.meta.created_at.elapsed(),
            _ => Duration::ZERO,
        }
    }

    /// Materialize the value (Op 3 producer side). The value is immutable:
    /// a second resolution is ignored (debug-asserted) — Property 1. A
    /// resolve *after* `fail` is also ignored, but silently: cancellation
    /// (and instance kills) fail a future from the control plane while the
    /// engine may legitimately still be computing it, so the engine's late
    /// resolve is a lost race, not a programming error.
    pub fn resolve(&self, value: Value, service_us: u64) {
        let mut i = self.inner.lock().unwrap();
        if matches!(i.state, FutureState::Ready | FutureState::Failed) {
            debug_assert!(i.state == FutureState::Failed, "double resolve of {}", self.id);
            return;
        }
        i.value = Some(Arc::new(value));
        i.state = FutureState::Ready;
        i.service_us = service_us;
        let wakers = std::mem::take(&mut i.wakers);
        drop(i);
        self.ready.notify_all();
        for w in wakers {
            w();
        }
    }

    pub fn fail(&self, err: impl Into<String>) {
        let mut i = self.inner.lock().unwrap();
        if matches!(i.state, FutureState::Ready | FutureState::Failed) {
            return;
        }
        i.error = Some(err.into());
        i.state = FutureState::Failed;
        let wakers = std::mem::take(&mut i.wakers);
        drop(i);
        self.ready.notify_all();
        for w in wakers {
            w();
        }
    }

    /// Register a push-readiness callback (the event-driven counterpart of
    /// parking on `value`). Fired exactly once when the cell turns terminal;
    /// if it already is, the waker fires inline before `subscribe` returns —
    /// a subscriber that checks `try_value` *after* subscribing can never
    /// miss the wakeup.
    pub fn subscribe(&self, waker: Waker) {
        let mut i = self.inner.lock().unwrap();
        if matches!(i.state, FutureState::Ready | FutureState::Failed) {
            drop(i);
            waker();
            return;
        }
        i.wakers.push(waker);
    }

    // ----------------------------------------------------------- metadata
    pub fn meta(&self) -> FutureMeta {
        self.inner.lock().unwrap().meta.clone()
    }

    pub fn with_meta<R>(&self, f: impl FnOnce(&FutureMeta) -> R) -> R {
        f(&self.inner.lock().unwrap().meta)
    }

    pub fn executor(&self) -> Option<InstanceId> {
        self.inner.lock().unwrap().meta.executor.clone()
    }

    pub fn session(&self) -> SessionId {
        self.inner.lock().unwrap().meta.session
    }

    pub fn priority(&self) -> i32 {
        self.inner.lock().unwrap().meta.priority
    }

    pub fn set_priority(&self, p: i32) {
        self.inner.lock().unwrap().meta.priority = p;
    }

    /// Rewrite the slated executor (late binding / migration). Only legal
    /// before the future starts running; returns false otherwise.
    pub fn set_executor(&self, instance: InstanceId) -> bool {
        let mut i = self.inner.lock().unwrap();
        match i.state {
            FutureState::Created | FutureState::Queued => {
                i.meta.executor = Some(instance);
                true
            }
            _ => false,
        }
    }

    /// Op 2: record a consumer (first value access registers the caller).
    pub fn register_consumer(&self, who: Location) {
        let mut i = self.inner.lock().unwrap();
        if !i.meta.consumers.contains(&who) {
            i.meta.consumers.push(who);
        }
    }

    pub fn service_us(&self) -> u64 {
        self.inner.lock().unwrap().service_us
    }

    // ----------------------------------------------------------- value
    pub fn try_value(&self) -> Option<Result<Arc<Value>>> {
        let i = self.inner.lock().unwrap();
        Self::terminal_result(&i, self.id)
    }

    fn terminal_result(i: &Inner, id: FutureId) -> Option<Result<Arc<Value>>> {
        match i.state {
            FutureState::Ready => Some(Ok(i.value.clone().expect("ready without value"))),
            FutureState::Failed => Some(Err(Error::FutureFailed(
                id,
                i.meta
                    .executor
                    .clone()
                    .unwrap_or_else(|| InstanceId::new("?", 0)),
                i.error.clone().unwrap_or_default(),
            ))),
            _ => None,
        }
    }

    /// Op 3: block until materialized, up to `timeout`.
    pub fn value(&self, timeout: Duration) -> Result<Arc<Value>> {
        let deadline = Instant::now() + timeout;
        let mut i = self.inner.lock().unwrap();
        loop {
            if let Some(v) = Self::terminal_result(&i, self.id) {
                return v;
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::FutureTimeout(self.id, timeout));
            }
            let (guard, res) = self
                .ready
                .wait_timeout(i, deadline - now)
                .expect("future lock poisoned");
            i = guard;
            if res.timed_out() {
                if let Some(v) = Self::terminal_result(&i, self.id) {
                    return v;
                }
                return Err(Error::FutureTimeout(self.id, timeout));
            }
        }
    }
}

/// What driver code holds: a cheap handle mirroring the paper's two-method
/// futures API (`available()` / `value(timeout)`), plus consumer
/// registration on first access.
#[derive(Clone)]
pub struct FutureHandle {
    pub cell: Arc<FutureCell>,
    /// Identity of the holder, recorded as consumer on first access.
    holder: Location,
}

impl FutureHandle {
    pub fn new(cell: Arc<FutureCell>, holder: Location) -> Self {
        FutureHandle { cell, holder }
    }

    pub fn id(&self) -> FutureId {
        self.cell.id
    }

    /// `future.available()` — non-blocking readiness probe.
    pub fn available(&self) -> bool {
        self.cell.available()
    }

    /// `future.value(timeout=t)` — registers the holder as consumer (Op 2)
    /// then blocks until push-based readiness (Op 3).
    pub fn value(&self, timeout: Duration) -> Result<Arc<Value>> {
        self.cell.register_consumer(self.holder.clone());
        self.cell.value(timeout)
    }

    /// Non-blocking value probe (drivers polling a retry loop, Fig. 4 #3).
    pub fn try_value(&self) -> Option<Result<Arc<Value>>> {
        self.cell.register_consumer(self.holder.clone());
        self.cell.try_value()
    }

    pub fn meta(&self) -> FutureMeta {
        self.cell.meta()
    }

    /// Register a push-readiness callback on the underlying cell.
    pub fn subscribe(&self, waker: Waker) {
        self.cell.subscribe(waker);
    }
}

/// A one-thread wake flag: the bridge between push-based future readiness
/// and a thread that still wants to block (the compat shim driving a
/// resumable [`crate::workflow::Driver`] to completion). `wake` may be
/// called from any number of wakers; `wait` consumes at most one wakeup.
#[derive(Default)]
pub struct WakeSignal {
    woken: Mutex<bool>,
    cv: Condvar,
}

impl WakeSignal {
    pub fn new() -> Arc<WakeSignal> {
        Arc::new(WakeSignal::default())
    }

    /// Record a wakeup and rouse the waiter (idempotent).
    pub fn wake(&self) {
        let mut g = self.woken.lock().unwrap();
        *g = true;
        drop(g);
        self.cv.notify_all();
    }

    /// Block until `wake` is called or `timeout` passes, then clear the
    /// flag. A `wake` that raced ahead of `wait` is not lost: the flag
    /// stays set until consumed here. Returns true if woken.
    pub fn wait(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.woken.lock().unwrap();
        while !*g {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
        let woken = *g;
        *g = false;
        woken
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn meta(id: u64) -> FutureMeta {
        FutureMeta::new(
            FutureId(id),
            SessionId(0),
            RequestId(0),
            AgentType::new("dev"),
            "implement",
            Location::Driver(RequestId(0)),
        )
    }

    #[test]
    fn resolve_then_value() {
        let c = FutureCell::new(meta(1));
        assert!(!c.available());
        c.resolve(json!({"ok": true}), 10);
        assert!(c.available());
        let v = c.value(Duration::from_millis(10)).unwrap();
        assert_eq!(v.get("ok").as_bool(), Some(true));
        assert_eq!(c.service_us(), 10);
    }

    #[test]
    fn value_blocks_until_push() {
        let c = FutureCell::new(meta(2));
        let c2 = c.clone();
        let waiter = std::thread::spawn(move || c2.value(Duration::from_secs(2)));
        std::thread::sleep(Duration::from_millis(20));
        c.resolve(json!(42), 0);
        let v = waiter.join().unwrap().unwrap();
        assert_eq!(v.as_i64(), Some(42));
    }

    #[test]
    fn timeout_errors() {
        let c = FutureCell::new(meta(3));
        let t0 = Instant::now();
        let e = c.value(Duration::from_millis(30)).unwrap_err();
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert!(matches!(e, Error::FutureTimeout(..)));
        assert!(e.retryable());
    }

    #[test]
    fn failure_propagates() {
        let c = FutureCell::new(meta(4));
        c.mark_queued(InstanceId::new("dev", 1));
        c.fail("boom");
        let e = c.value(Duration::from_millis(10)).unwrap_err();
        match e {
            Error::FutureFailed(id, inst, msg) => {
                assert_eq!(id, FutureId(4));
                assert_eq!(inst.to_string(), "dev:1");
                assert_eq!(msg, "boom");
            }
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn resolve_after_fail_is_a_lost_race_not_a_panic() {
        // Cancellation fails futures from the control plane while the
        // engine may still be computing them; the engine's late resolve
        // must be swallowed and the failure must stand.
        let c = FutureCell::new(meta(15));
        c.fail("request cancelled");
        c.resolve(json!(99), 0);
        assert_eq!(c.state(), FutureState::Failed);
        assert!(c.try_value().unwrap().is_err());
    }

    #[test]
    fn value_immutable_after_ready() {
        let c = FutureCell::new(meta(5));
        c.resolve(json!(1), 0);
        // late failure must not clobber the value
        c.fail("late");
        assert_eq!(c.try_value().unwrap().unwrap().as_i64(), Some(1));
        assert_eq!(c.state(), FutureState::Ready);
    }

    #[test]
    fn executor_mutable_until_running() {
        let c = FutureCell::new(meta(6));
        assert!(c.set_executor(InstanceId::new("dev", 0)));
        c.mark_queued(InstanceId::new("dev", 0));
        assert!(c.set_executor(InstanceId::new("dev", 1)), "queued is still migratable");
        c.mark_running();
        assert!(!c.set_executor(InstanceId::new("dev", 2)), "running is pinned");
        assert_eq!(c.executor().unwrap().to_string(), "dev:1");
    }

    #[test]
    fn consumer_registration_dedup() {
        let c = FutureCell::new(meta(7));
        let d = Location::Driver(RequestId(9));
        c.register_consumer(d.clone());
        c.register_consumer(d);
        assert_eq!(c.meta().consumers.len(), 1);
    }

    #[test]
    fn handle_registers_consumer_on_access() {
        let c = FutureCell::new(meta(8));
        let h = FutureHandle::new(c.clone(), Location::Driver(RequestId(3)));
        c.resolve(json!("x"), 0);
        let _ = h.value(Duration::from_millis(5)).unwrap();
        assert_eq!(c.meta().consumers, vec![Location::Driver(RequestId(3))]);
    }

    #[test]
    fn queue_wait_tracks_unstarted_only() {
        let c = FutureCell::new(meta(9));
        std::thread::sleep(Duration::from_millis(5));
        assert!(c.queue_wait() >= Duration::from_millis(4));
        c.mark_queued(InstanceId::new("dev", 0));
        c.mark_running();
        assert_eq!(c.queue_wait(), Duration::ZERO);
    }

    #[test]
    fn subscribe_fires_on_resolve_and_fail() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let fired = Arc::new(AtomicUsize::new(0));
        let c = FutureCell::new(meta(11));
        let f1 = fired.clone();
        c.subscribe(Box::new(move || {
            f1.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(fired.load(Ordering::SeqCst), 0, "waker must wait for the terminal state");
        c.resolve(json!(1), 0);
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        // a late failure is ignored: the waker must not fire twice
        c.fail("late");
        assert_eq!(fired.load(Ordering::SeqCst), 1);

        let failed = FutureCell::new(meta(12));
        let f2 = fired.clone();
        failed.subscribe(Box::new(move || {
            f2.fetch_add(10, Ordering::SeqCst);
        }));
        failed.fail("boom");
        assert_eq!(fired.load(Ordering::SeqCst), 11, "failure is terminal too");
    }

    #[test]
    fn subscribe_after_terminal_fires_inline() {
        use std::sync::atomic::{AtomicBool, Ordering};
        let c = FutureCell::new(meta(13));
        c.resolve(json!("done"), 0);
        let fired = Arc::new(AtomicBool::new(false));
        let f = fired.clone();
        c.subscribe(Box::new(move || f.store(true, Ordering::SeqCst)));
        assert!(fired.load(Ordering::SeqCst), "no wakeup may be missed");
    }

    #[test]
    fn wake_signal_is_not_lost_when_racing_ahead() {
        let s = WakeSignal::new();
        s.wake(); // wake before anyone waits
        assert!(s.wait(Duration::from_millis(1)), "pre-wait wake must be consumed");
        assert!(!s.wait(Duration::from_millis(1)), "wakeup was consumed, flag cleared");
    }

    #[test]
    fn wake_signal_bridges_subscription_to_a_blocking_thread() {
        let c = FutureCell::new(meta(14));
        let s = WakeSignal::new();
        let s2 = s.clone();
        c.subscribe(Box::new(move || s2.wake()));
        let c2 = c.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            c2.resolve(json!(5), 0);
        });
        assert!(s.wait(Duration::from_secs(2)), "push readiness must arrive");
        assert_eq!(c.try_value().unwrap().unwrap().as_i64(), Some(5));
        producer.join().unwrap();
    }

    #[test]
    fn many_waiters_all_wake() {
        let c = FutureCell::new(meta(10));
        let mut joins = vec![];
        for _ in 0..8 {
            let c2 = c.clone();
            joins.push(std::thread::spawn(move || {
                c2.value(Duration::from_secs(2)).unwrap().as_i64()
            }));
        }
        std::thread::sleep(Duration::from_millis(10));
        c.resolve(json!(7), 0);
        for j in joins {
            assert_eq!(j.join().unwrap(), Some(7));
        }
    }
}
