//! Error type for the NALAR runtime.
//!
//! Per the paper's fault-tolerance stance (§5): NALAR does not mask faults;
//! failed requests are reported back to the driver with the workflow path,
//! the failing agent and the underlying cause, and the driver decides
//! whether to retry.

use crate::ids::{FutureId, InstanceId};

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("future {0} failed at {agent}: {cause}", agent = .1, cause = .2)]
    FutureFailed(FutureId, InstanceId, String),

    #[error("future {0} timed out after {1:?}")]
    FutureTimeout(FutureId, std::time::Duration),

    #[error("no instance available for agent type `{0}`")]
    NoInstance(String),

    #[error("unknown agent type `{0}`")]
    UnknownAgent(String),

    #[error("instance {0} was killed")]
    InstanceKilled(InstanceId),

    #[error("engine error: {0}")]
    Engine(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("state error: {0}")]
    State(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json: {0}")]
    Json(#[from] crate::util::json::ParseError),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }

    /// True when the driver may meaningfully retry (per-§5 semantics).
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            Error::FutureFailed(..)
                | Error::FutureTimeout(..)
                | Error::InstanceKilled(..)
                | Error::NoInstance(..)
        )
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_classification() {
        assert!(Error::FutureTimeout(FutureId(1), std::time::Duration::from_secs(1)).retryable());
        assert!(Error::NoInstance("x".into()).retryable());
        assert!(!Error::Config("bad".into()).retryable());
        assert!(!Error::Engine("x".into()).retryable());
    }

    #[test]
    fn display_includes_context() {
        let e = Error::FutureFailed(FutureId(7), InstanceId::new("dev", 1), "oom".into());
        let s = e.to_string();
        assert!(s.contains("f7") && s.contains("dev:1") && s.contains("oom"));
    }
}
