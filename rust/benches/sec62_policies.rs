//! §6.2 reproduction: adding new policies in ~12 lines.
//!
//! * Minimize JCT — SRTF (prioritize later-stage calls) vs FCFS on the
//!   financial workflow: paper reports avg JCT -2.4%, P95 +3.3%.
//! * Control makespan — LPT (prioritize re-entrant jobs) vs FCFS on the
//!   SWE workflow, closed batch: paper reports makespan -5.8%, P95 +2.6%.

use std::time::{Duration, Instant};

use nalar::baselines::SystemUnderTest;
use nalar::json;
use nalar::server::Deployment;
use nalar::util::bench::Table;
use nalar::util::rng::Rng;
use nalar::workflow::{run_open_loop, run_request, RunConfig, WorkflowKind};
use nalar::workload;

fn jct_study() {
    println!("=== §6.2 Minimize JCT — SRTF vs FCFS (financial) ===");
    let mut table = Table::new(&["policy", "avg JCT(s)", "p95(s)", "ok"]);
    let mut results = Vec::new();
    for policy in ["fcfs", "srtf"] {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.policies = vec!["load_balance".into(), policy.into()];
        let d = Deployment::launch_as(cfg, SystemUnderTest::Nalar).unwrap();
        let rc = RunConfig {
            workflow: WorkflowKind::Financial,
            rps: 110.0,
            duration: Duration::from_secs(5),
            session_pool: 48,
            request_timeout: Duration::from_secs(8),
            seed: 62,
        };
        let (stats, rec) = run_open_loop(&d, &rc);
        let paper = rec.summary_scaled(1.0 / stats.time_scale);
        table.row(&[
            policy.to_string(),
            format!("{:.1}", paper.avg),
            format!("{:.1}", paper.p95),
            stats.completed.to_string(),
        ]);
        results.push((paper.avg, paper.p95));
        d.shutdown();
    }
    table.print();
    if results.len() == 2 {
        println!(
            "SRTF vs FCFS: avg JCT {:+.1}%  p95 {:+.1}%   (paper: -2.4% / +3.3%)",
            100.0 * (results[1].0 - results[0].0) / results[0].0,
            100.0 * (results[1].1 - results[0].1) / results[0].1
        );
    }
}

fn makespan_study() {
    println!("\n=== §6.2 Control Makespan — LPT vs FCFS (SWE, closed batch) ===");
    let batch = 36;
    let mut table = Table::new(&["policy", "makespan(s)", "p95 JCT(s)", "ok"]);
    let mut results = Vec::new();
    for policy in ["fcfs", "lpt"] {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.policies = vec!["load_balance".into(), policy.into()];
        let d = Deployment::launch_as(cfg, SystemUnderTest::Nalar).unwrap();
        let mut rng = Rng::new(62);
        let t0 = Instant::now();
        let mut lat = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..batch {
                let session = d.new_session();
                let input = json!({"task": workload::swe_task(&mut rng)});
                let d = &d;
                handles.push(scope.spawn(move || {
                    let t = Instant::now();
                    let ok = run_request(d, WorkflowKind::Swe, session, &input, Duration::from_secs(30)).is_ok();
                    (t.elapsed(), ok)
                }));
            }
            for h in handles {
                lat.push(h.join().unwrap());
            }
        });
        let makespan = t0.elapsed().as_secs_f64() / d.cfg().time_scale;
        let ok = lat.iter().filter(|(_, o)| *o).count();
        let mut l: Vec<f64> = lat.iter().map(|(d_, _)| d_.as_secs_f64()).collect();
        l.sort_by(|a, b| a.total_cmp(b));
        let p95 = l[(l.len() - 1) * 95 / 100] / d.cfg().time_scale;
        table.row(&[
            policy.to_string(),
            format!("{makespan:.1}"),
            format!("{p95:.1}"),
            ok.to_string(),
        ]);
        results.push((makespan, p95));
        d.shutdown();
    }
    table.print();
    if results.len() == 2 {
        println!(
            "LPT vs FCFS: makespan {:+.1}%  p95 {:+.1}%   (paper: -5.8% / +2.6%)",
            100.0 * (results[1].0 - results[0].0) / results[0].0,
            100.0 * (results[1].1 - results[0].1) / results[0].1
        );
    }
}

fn main() {
    jct_study();
    makespan_study();
}
