"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compute layer. Hypothesis
sweeps shapes, lengths, block sizes and dtypes; fixed cases pin the
regression corners (length==1, length==T, pos==0, pos==S-1).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    decode_attention,
    flash_attention_prefill,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def assert_prefill_matches(b, h, t, dh, lengths, dtype, block_q=32, block_k=32, seed=0):
    q = _rand(seed, (b, h, t, dh), dtype)
    k = _rand(seed + 1, (b, h, t, dh), dtype)
    v = _rand(seed + 2, (b, h, t, dh), dtype)
    length = jnp.asarray(lengths, jnp.int32)
    out = flash_attention_prefill(q, k, v, length, block_q=block_q, block_k=block_k)
    want = jax.vmap(ref.attention_prefill_ref)(q, k, v, length)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    for i in range(b):
        # only rows < length are consumed downstream
        np.testing.assert_allclose(
            np.asarray(out[i, :, : lengths[i]], np.float32),
            np.asarray(want[i, :, : lengths[i]], np.float32),
            rtol=tol,
            atol=tol,
        )


def assert_decode_matches(b, h, s, dh, poss, dtype, block_k=32, seed=0):
    q = _rand(seed, (b, h, dh), dtype)
    k = _rand(seed + 1, (b, h, s, dh), dtype)
    v = _rand(seed + 2, (b, h, s, dh), dtype)
    pos = jnp.asarray(poss, jnp.int32)
    out = decode_attention(q, k, v, pos, block_k=block_k)
    want = jax.vmap(ref.attention_decode_ref)(q, k, v, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


# ---------------------------------------------------------------- fixed pins
class TestPrefillPinned:
    def test_basic(self):
        assert_prefill_matches(2, 4, 64, 16, [40, 64], jnp.float32)

    def test_length_one(self):
        assert_prefill_matches(1, 2, 32, 8, [1], jnp.float32)

    def test_full_length(self):
        assert_prefill_matches(2, 2, 32, 8, [32, 32], jnp.float32)

    def test_single_head(self):
        assert_prefill_matches(1, 1, 32, 16, [17], jnp.float32)

    def test_block_larger_than_t(self):
        # block sizes shrink to T
        assert_prefill_matches(1, 2, 16, 8, [9], jnp.float32, block_q=64, block_k=64)

    def test_uneven_blocks(self):
        assert_prefill_matches(1, 2, 64, 16, [33], jnp.float32, block_q=16, block_k=32)

    def test_bf16(self):
        assert_prefill_matches(2, 4, 64, 16, [50, 64], jnp.bfloat16)

    def test_model_shape(self):
        # exact shape used by the served LM
        assert_prefill_matches(4, 4, 128, 16, [1, 37, 100, 128], jnp.float32)

    def test_non_tileable_raises(self):
        q = jnp.zeros((1, 1, 48, 8), jnp.float32)
        with pytest.raises(ValueError):
            flash_attention_prefill(q, q, q, jnp.array([48], jnp.int32), block_q=32, block_k=32)


class TestDecodePinned:
    def test_basic(self):
        assert_decode_matches(2, 4, 64, 16, [5, 63], jnp.float32)

    def test_pos_zero(self):
        assert_decode_matches(1, 2, 32, 8, [0], jnp.float32)

    def test_pos_last(self):
        assert_decode_matches(1, 2, 32, 8, [31], jnp.float32)

    def test_bf16(self):
        assert_decode_matches(2, 4, 64, 16, [10, 50], jnp.bfloat16)

    def test_model_shape(self):
        assert_decode_matches(8, 4, 128, 16, [0, 1, 17, 31, 64, 100, 126, 127], jnp.float32)

    def test_small_block(self):
        assert_decode_matches(1, 4, 64, 16, [20], jnp.float32, block_k=8)


# ------------------------------------------------------------ hypothesis sweeps
@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t_blocks=st.integers(1, 4),
    dh=st.sampled_from([8, 16]),
    data=st.data(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_prefill_sweep(b, h, t_blocks, dh, data, dtype):
    t = 16 * t_blocks
    lengths = [data.draw(st.integers(1, t)) for _ in range(b)]
    assert_prefill_matches(b, h, t, dh, lengths, dtype, block_q=16, block_k=16)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    dh=st.sampled_from([8, 16]),
    data=st.data(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_decode_sweep(b, h, s_blocks, dh, data, dtype):
    s = 16 * s_blocks
    poss = [data.draw(st.integers(0, s - 1)) for _ in range(b)]
    assert_decode_matches(b, h, s, dh, poss, dtype, block_k=16)


# ------------------------------------------------------------- perf estimates
def test_vmem_footprint_within_budget():
    # default tiles for the served model must fit a 16 MiB VMEM with slack
    assert vmem_footprint_bytes(dh=16, t=128) < 16 * 2**20 // 8


def test_mxu_estimate_monotone_in_tiles():
    assert mxu_utilization_estimate(64, 64, 16) >= mxu_utilization_estimate(32, 32, 16)
    assert 0 < mxu_utilization_estimate() <= 1
