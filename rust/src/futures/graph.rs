//! Dynamic dependency-graph extraction (paper §4.3.1, Property 2).
//!
//! NALAR never asks the developer for a DAG. Instead it reconstructs the
//! workflow's dataflow graph from the three observed future operations:
//! creation (node + dependency edges), consumer registration (consumer
//! edges) and resolution. Policies read the graph to reason about stages
//! (SRTF prioritizes later stages, §6.2) and re-entry (LPT prioritizes
//! retried jobs).

use std::collections::{HashMap, HashSet};

use std::sync::RwLock;

use crate::ids::{FutureId, Location, RequestId};

#[derive(Debug, Default, Clone)]
struct Node {
    deps: Vec<FutureId>,
    dependents: Vec<FutureId>,
    consumers: Vec<Location>,
    request: Option<RequestId>,
    stage: u32,
    resolved: bool,
}

/// Append-only view of the evolving computation graph.
#[derive(Default)]
pub struct DepGraph {
    nodes: RwLock<HashMap<FutureId, Node>>,
}

impl DepGraph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Op 1 observed: future created with explicit dependencies.
    /// `stage` is the creator's call-graph depth + 1.
    pub fn on_create(&self, id: FutureId, request: RequestId, deps: &[FutureId], stage: u32) {
        let mut g = self.nodes.write().unwrap();
        for d in deps {
            g.entry(*d).or_default().dependents.push(id);
        }
        let node = g.entry(id).or_default();
        node.deps = deps.to_vec();
        node.request = Some(request);
        node.stage = stage;
    }

    /// Op 2 observed: someone consumed the future.
    pub fn on_consume(&self, id: FutureId, who: Location) {
        let mut g = self.nodes.write().unwrap();
        let node = g.entry(id).or_default();
        if !node.consumers.contains(&who) {
            node.consumers.push(who);
        }
    }

    /// Op 3 observed.
    pub fn on_resolve(&self, id: FutureId) {
        if let Some(n) = self.nodes.write().unwrap().get_mut(&id) {
            n.resolved = true;
        }
    }

    pub fn stage(&self, id: FutureId) -> u32 {
        self.nodes.read().unwrap().get(&id).map(|n| n.stage).unwrap_or(0)
    }

    pub fn dependencies(&self, id: FutureId) -> Vec<FutureId> {
        self.nodes.read().unwrap().get(&id).map(|n| n.deps.clone()).unwrap_or_default()
    }

    pub fn dependents(&self, id: FutureId) -> Vec<FutureId> {
        self.nodes
            .read().unwrap()
            .get(&id)
            .map(|n| n.dependents.clone())
            .unwrap_or_default()
    }

    /// All unresolved deps — a future is ready-to-run when this is empty.
    pub fn unresolved_deps(&self, id: FutureId) -> Vec<FutureId> {
        let g = self.nodes.read().unwrap();
        g.get(&id)
            .map(|n| {
                n.deps
                    .iter()
                    .filter(|d| g.get(d).map(|dn| !dn.resolved).unwrap_or(true))
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Remaining-work estimate for a request: unresolved futures reachable
    /// downstream of any of the request's unresolved futures. SRTF uses
    /// this to rank requests by least remaining work.
    pub fn remaining_futures(&self, request: RequestId) -> usize {
        let g = self.nodes.read().unwrap();
        let mut seen: HashSet<FutureId> = HashSet::new();
        let mut stack: Vec<FutureId> = g
            .iter()
            .filter(|(_, n)| n.request == Some(request) && !n.resolved)
            .map(|(id, _)| *id)
            .collect();
        while let Some(id) = stack.pop() {
            if !seen.insert(id) {
                continue;
            }
            if let Some(n) = g.get(&id) {
                for d in &n.dependents {
                    if g.get(d).map(|dn| !dn.resolved).unwrap_or(false) {
                        stack.push(*d);
                    }
                }
            }
        }
        seen.len()
    }

    pub fn len(&self) -> usize {
        self.nodes.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_records_edges_both_ways() {
        let g = DepGraph::new();
        g.on_create(FutureId(1), RequestId(0), &[], 1);
        g.on_create(FutureId(2), RequestId(0), &[FutureId(1)], 2);
        assert_eq!(g.dependencies(FutureId(2)), vec![FutureId(1)]);
        assert_eq!(g.dependents(FutureId(1)), vec![FutureId(2)]);
        assert_eq!(g.stage(FutureId(2)), 2);
    }

    #[test]
    fn readiness_via_unresolved_deps() {
        let g = DepGraph::new();
        g.on_create(FutureId(1), RequestId(0), &[], 1);
        g.on_create(FutureId(2), RequestId(0), &[FutureId(1)], 2);
        assert_eq!(g.unresolved_deps(FutureId(2)), vec![FutureId(1)]);
        g.on_resolve(FutureId(1));
        assert!(g.unresolved_deps(FutureId(2)).is_empty());
    }

    #[test]
    fn remaining_work_shrinks() {
        let g = DepGraph::new();
        let r = RequestId(7);
        g.on_create(FutureId(1), r, &[], 1);
        g.on_create(FutureId(2), r, &[FutureId(1)], 2);
        g.on_create(FutureId(3), r, &[FutureId(1)], 2);
        assert_eq!(g.remaining_futures(r), 3);
        g.on_resolve(FutureId(1));
        assert_eq!(g.remaining_futures(r), 2);
        g.on_resolve(FutureId(2));
        g.on_resolve(FutureId(3));
        assert_eq!(g.remaining_futures(r), 0);
    }

    #[test]
    fn consumer_edges_dedup() {
        let g = DepGraph::new();
        g.on_create(FutureId(1), RequestId(0), &[], 0);
        let who = Location::Driver(RequestId(0));
        g.on_consume(FutureId(1), who.clone());
        g.on_consume(FutureId(1), who);
        let nodes = g.nodes.read().unwrap();
        assert_eq!(nodes[&FutureId(1)].consumers.len(), 1);
    }
}
