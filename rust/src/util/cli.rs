//! Tiny argument parser (clap substitute): `--key value`, `--flag`,
//! `--key=value`, positionals.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_flags_positionals() {
        let a = parse("serve --config x.json --rps 8.5 --verbose --n=3 run");
        assert_eq!(a.positional, vec!["serve", "run"]);
        assert_eq!(a.str_or("config", ""), "x.json");
        assert_eq!(a.f64_or("rps", 0.0), 8.5);
        assert_eq!(a.u64_or("n", 0), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.u64_or("missing", 7), 7);
    }

    #[test]
    fn flag_before_positional() {
        // A `--flag` followed by another `--opt` stays a flag; a `--flag`
        // followed by a bare word consumes it as a value, so positionals
        // that must survive go before the flag (or use `--opt=value`).
        let a = parse("--dry-run --out=file.txt pos");
        assert!(a.flag("dry-run"));
        assert_eq!(a.str_or("out", ""), "file.txt");
        assert_eq!(a.positional, vec!["pos"]);
        let b = parse("pos --verbose");
        assert!(b.flag("verbose"));
        assert_eq!(b.positional, vec!["pos"]);
    }
}
