//! `artifacts/manifest.json` — the contract between aot.py and the runtime.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json;

/// Model architecture constants (must match `compile.model.ModelConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
}

impl ModelDims {
    /// Floats in one sequence's KV cache: `L * 2 * H * S * Dh`.
    pub fn kv_floats_per_seq(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.max_seq * self.head_dim
    }

    /// Bytes of one sequence's KV cache (f32).
    pub fn kv_bytes_per_seq(&self) -> u64 {
        (self.kv_floats_per_seq() * 4) as u64
    }
}

/// One weight tensor's slice of `params.bin`.
#[derive(Debug, Clone)]
pub struct ParamSlice {
    pub name: String,
    pub shape: Vec<i64>,
    pub offset: usize,
    pub len: usize,
}

/// One compiled entry point (e.g. `decode_b4`).
#[derive(Debug, Clone)]
pub struct EntrySig {
    pub name: String,
    pub file: String,
    /// `(name, shape, is_int)` for each data input, in call order after
    /// the weights.
    pub data_inputs: Vec<(String, Vec<i64>, bool)>,
}

impl EntrySig {
    /// Batch size encoded in the entry name (`prefill_b4` -> 4).
    pub fn batch(&self) -> usize {
        self.name
            .rsplit_once('b')
            .and_then(|(_, b)| b.parse().ok())
            .unwrap_or(1)
    }

    pub fn phase(&self) -> &str {
        self.name.split('_').next().unwrap_or("")
    }
}

/// Parsed manifest + weight blob.
pub struct Manifest {
    pub dims: ModelDims,
    pub params: Vec<ParamSlice>,
    pub entries: Vec<EntrySig>,
    pub weights: Vec<f32>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "{}: {e} (run `make artifacts` first)",
                dir.join("manifest.json").display()
            ))
        })?;
        let v = json::parse(&text)?;
        let m = v.get("model");
        let dims = ModelDims {
            vocab: m.u64_or("vocab", 0) as usize,
            d_model: m.u64_or("d_model", 0) as usize,
            n_heads: m.u64_or("n_heads", 0) as usize,
            head_dim: m.u64_or("head_dim", 0) as usize,
            n_layers: m.u64_or("n_layers", 0) as usize,
            max_seq: m.u64_or("max_seq", 0) as usize,
            bos: m.u64_or("bos", 256) as i32,
            eos: m.u64_or("eos", 257) as i32,
            pad: m.u64_or("pad", 258) as i32,
        };
        if dims.vocab == 0 || dims.max_seq == 0 {
            return Err(Error::Artifact("manifest missing model dims".into()));
        }

        let params = v
            .get("params")
            .as_arr()
            .ok_or_else(|| Error::Artifact("manifest missing params".into()))?
            .iter()
            .map(|p| ParamSlice {
                name: p.str_or("name", "").to_string(),
                shape: p
                    .get("shape")
                    .as_arr()
                    .map(|a| a.iter().filter_map(|d| d.as_i64()).collect())
                    .unwrap_or_default(),
                offset: p.u64_or("offset", 0) as usize,
                len: p.u64_or("len", 0) as usize,
            })
            .collect::<Vec<_>>();

        let entries = v
            .get("entries")
            .as_arr()
            .ok_or_else(|| Error::Artifact("manifest missing entries".into()))?
            .iter()
            .map(|e| EntrySig {
                name: e.str_or("name", "").to_string(),
                file: e.str_or("file", "").to_string(),
                data_inputs: e
                    .get("data_inputs")
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .map(|di| {
                                (
                                    di.str_or("name", "").to_string(),
                                    di.get("shape")
                                        .as_arr()
                                        .map(|s| s.iter().filter_map(|d| d.as_i64()).collect())
                                        .unwrap_or_default(),
                                    di.str_or("dtype", "f32") == "i32",
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_default(),
            })
            .collect::<Vec<_>>();

        // Weights blob: f32 little-endian, validated against the layout.
        let total: usize = v.u64_or("param_count", 0) as usize;
        let blob = std::fs::read(dir.join(v.str_or("params_file", "params.bin")))?;
        if blob.len() != total * 4 {
            return Err(Error::Artifact(format!(
                "params.bin is {} bytes, expected {}",
                blob.len(),
                total * 4
            )));
        }
        let mut weights = Vec::with_capacity(total);
        for chunk in blob.chunks_exact(4) {
            weights.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
        }

        Ok(Manifest { dims, params, entries, weights, dir })
    }

    /// The smallest compiled variant of `phase` with batch >= `n`.
    pub fn pick_entry(&self, phase: &str, n: usize) -> Option<&EntrySig> {
        self.entries
            .iter()
            .filter(|e| e.phase() == phase && e.batch() >= n)
            .min_by_key(|e| e.batch())
    }

    /// Largest compiled batch for a phase.
    pub fn max_batch(&self, phase: &str) -> usize {
        self.entries
            .iter()
            .filter(|e| e.phase() == phase)
            .map(|e| e.batch())
            .max()
            .unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(art_dir()).unwrap();
        assert_eq!(m.dims.vocab, 259);
        assert_eq!(m.dims.max_seq, 128);
        assert!(m.weights.len() > 100_000);
        assert_eq!(m.params[0].name, "tok_emb");
        // contiguous layout
        let mut off = 0;
        for p in &m.params {
            assert_eq!(p.offset, off);
            off += p.len;
        }
        assert_eq!(off, m.weights.len());
    }

    #[test]
    fn entry_selection() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(art_dir()).unwrap();
        assert_eq!(m.pick_entry("decode", 3).unwrap().batch(), 4);
        assert_eq!(m.pick_entry("decode", 1).unwrap().batch(), 1);
        assert_eq!(m.pick_entry("prefill", 4).unwrap().batch(), 4);
        assert!(m.pick_entry("decode", 99).is_none());
        assert_eq!(m.max_batch("decode"), 8);
    }

    #[test]
    fn kv_sizing() {
        let dims = ModelDims {
            vocab: 259,
            d_model: 64,
            n_heads: 4,
            head_dim: 16,
            n_layers: 2,
            max_seq: 128,
            bos: 256,
            eos: 257,
            pad: 258,
        };
        assert_eq!(dims.kv_floats_per_seq(), 2 * 2 * 4 * 128 * 16);
        assert_eq!(dims.kv_bytes_per_seq(), 2 * 2 * 4 * 128 * 16 * 4);
    }

    #[test]
    fn entry_sig_parsing() {
        let e = EntrySig { name: "decode_b8".into(), file: "x".into(), data_inputs: vec![] };
        assert_eq!(e.batch(), 8);
        assert_eq!(e.phase(), "decode");
    }
}
