//! Integration tests for the event-driven ingress scheduler: in-flight
//! requests are stored continuations, so a small fixed thread pool must
//! carry far more concurrent requests than it has threads, a stalled
//! agent type must park its requests without wedging unrelated work, and
//! the request-lifecycle API (`Ticket::cancel`, deadline expiry,
//! policy-ordered queues) must leave exactly one terminal outcome per
//! ticket and no entry behind in either scheduler table.
//!
//! The lifecycle tests run on the deterministic testkit: a virtual clock
//! (deadlines move when the test says so, never because CI is slow) and a
//! scripted engine (the test resolves each "agent call", so park/wake/
//! expire/cancel interleavings are replays, not timing hopes).

use std::time::{Duration, Instant};

use nalar::config::DeploymentConfig;
use nalar::error::Error;
use nalar::ingress::{
    AdmissionPolicy, Ingress, SchedulePolicy, SchedulerOpts, SubmitRequest, Ticket,
};
use nalar::journal::{self, FsyncPolicy, JournalSink};
use nalar::json;
use nalar::server::Deployment;
use nalar::testkit::{Clock, Gate, ScriptedEngine};
use nalar::workflow::WorkflowKind;

/// ≥512 concurrent in-flight requests on a 4-thread scheduler: every
/// admitted request completes. Under the old one-request-per-thread pool
/// this workload would need 512 OS threads (or serialize 128-deep per
/// thread); with resumable drivers 4 threads multiplex the whole set.
#[test]
fn four_threads_complete_512_concurrent_requests() {
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = 0.002;
    cfg.control.global_period_ms = 10;
    // Keep the capacity policies out of this test: a reallocation kill
    // would fail futures retryably, which is orthogonal to what is being
    // proven here (thread-decoupled completion).
    cfg.policies = vec!["load_balance".into()];
    let d = Deployment::launch(cfg).unwrap();
    let ing = Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router],
        AdmissionPolicy::Unbounded,
        SchedulerOpts::new(4, 1024),
    );
    let timeout = Duration::from_secs(120);
    let tickets: Vec<Ticket> = (0..512)
        .map(|i| {
            let class = if i % 4 == 0 { "coder" } else { "chat" };
            ing.submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .input(json!({"prompt": "multiplex me", "class": class}))
                    .deadline(timeout),
            )
            .unwrap()
        })
        .collect();
    // All 512 were admitted before the workload can drain: the scheduler
    // is carrying far more live requests than it has threads.
    let m = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!(m.workers, 4);
    assert!(
        m.in_flight + m.depth > 4 * m.workers,
        "in-flight ({}) + queued ({}) should dwarf {} threads right after the burst",
        m.in_flight,
        m.depth,
        m.workers
    );
    for t in &tickets {
        t.wait(timeout).unwrap();
    }
    let m = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!(m.accepted, 512);
    assert_eq!(m.completed, 512, "every admitted request must complete");
    assert_eq!(m.failed, 0);
    assert_eq!(m.expired_in_queue, 0);
    assert_eq!(m.in_flight, 0, "drained");
    ing.stop();
    d.shutdown();
}

/// Two workflows behind one 2-thread front door; the chat agent is
/// stalled (500 paper-s per reply). The router requests park on their
/// chat futures without occupying the scheduler's threads, so the SWE
/// workflow's requests keep completing — head-of-line isolation that the
/// old thread-per-request pool could not provide (6 stalled requests
/// would have pinned both threads).
#[test]
fn stalled_agent_type_parks_without_wedging_other_workflows() {
    let cfg = DeploymentConfig::from_json(
        r#"{
  "nodes": 2,
  "time_scale": 0.001,
  "seed": 5,
  "control": {"global_period_ms": 20, "hol_threshold_ms": 120},
  "engine": {"max_batch": 8, "executor": "sim", "kv_policy": "hint"},
  "ingress": {"policy": "unbounded", "workers": 2, "max_in_flight": 64},
  "policies": ["load_balance"],
  "agents": [
    {"name": "router", "kind": "llm", "instances": 1,
     "profile": {"base_s": 0.05, "mean_output_tokens": 6, "per_output_token_s": 0.01},
     "methods": ["classify"]},
    {"name": "chat", "kind": "llm", "instances": 2,
     "profile": {"base_s": 500.0, "mean_output_tokens": 1, "per_output_token_s": 0.0},
     "methods": ["reply"]},
    {"name": "coder", "kind": "llm", "instances": 1,
     "profile": {"base_s": 0.3, "mean_output_tokens": 20, "per_output_token_s": 0.01},
     "methods": ["implement"]},
    {"name": "planner", "kind": "llm", "instances": 1,
     "profile": {"base_s": 0.3, "mean_output_tokens": 60, "per_output_token_s": 0.008},
     "methods": ["plan"]},
    {"name": "developer", "kind": "llm", "instances": 2,
     "profile": {"base_s": 0.4, "mean_output_tokens": 240, "per_output_token_s": 0.011},
     "methods": ["implement"]},
    {"name": "documentation", "kind": "vector_store", "instances": 1,
     "profile": {"base_s": 0.15},
     "methods": ["get", "add", "query"]},
    {"name": "test_harness", "kind": "test_harness", "instances": 2,
     "profile": {"base_s": 0.6},
     "failure_rate": 0.1,
     "methods": ["unit_test", "integration_test"]}
  ]
}"#,
    )
    .unwrap();
    let d = Deployment::launch(cfg).unwrap();
    let ing = Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router, WorkflowKind::Swe],
        AdmissionPolicy::Unbounded,
        SchedulerOpts::new(2, 64),
    );
    let long = Duration::from_secs(60);

    // 6 requests that will all stall on the chat agent (3x the thread
    // count: the old pool would be wedged solid).
    let stalled: Vec<Ticket> = (0..6)
        .map(|_| {
            ing.submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .input(json!({"prompt": "hang", "class": "chat"}))
                    .deadline(long),
            )
            .unwrap()
        })
        .collect();
    // Wait until every stalled request has actually started (left the
    // admission queue) so the isolation claim is about parked work, not
    // work that merely never began.
    let t0 = Instant::now();
    while ing.in_flight(WorkflowKind::Router) < stalled.len() {
        assert!(t0.elapsed() < Duration::from_secs(10), "stalled requests never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    // An unrelated workflow must make progress on the same two threads.
    let swe: Vec<Ticket> = (0..6)
        .map(|_| {
            ing.submit(
                SubmitRequest::workflow(WorkflowKind::Swe)
                    .input(json!({"task": "isolate me"}))
                    .deadline(long),
            )
            .unwrap()
        })
        .collect();
    for t in &swe {
        t.wait(long).unwrap();
    }
    let m_swe = ing.metrics(WorkflowKind::Swe).unwrap();
    assert_eq!(m_swe.completed, 6, "swe must complete while router is stalled");
    // The stall (6 chats x 0.5s wall on 2 instances = >=1.5s of chat
    // service) must outlast the ~50ms SWE phase: stalled requests stay
    // parked, not failed, and don't hold the scheduler's threads. Avoid
    // asserting exactly-zero completions — on a badly overloaded runner a
    // first chat reply may sneak in — but all 6 finishing during the SWE
    // phase would mean the stall never happened.
    let m_router = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!(m_router.failed, 0, "parked requests must not be failed");
    assert!(
        m_router.in_flight >= 1,
        "stalled requests must still be parked (in_flight {}, completed {})",
        m_router.in_flight,
        m_router.completed
    );

    // Tear down without waiting out the stall: stop() fails parked work
    // fast rather than masking it — no ticket may be left hanging.
    ing.stop();
    for t in &stalled {
        let _ = t.wait(Duration::from_secs(1));
        assert!(t.latency().is_some(), "every ticket must be fulfilled (ok or failed) at stop");
    }
    d.shutdown();
}

// ------------------------------------------------------------ lifecycle
//
// Everything below runs on the deterministic testkit: `Clock::manual`
// freezes time until the test advances it, and `ScriptedEngine` drivers
// suspend on futures the test resolves. No test in this section sleeps
// its way to an assertion.

fn fast_router() -> Deployment {
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = 0.0005;
    cfg.control.global_period_ms = 10;
    // Keep capacity policies out: a reallocation kill would fail futures
    // retryably, which is orthogonal to lifecycle control.
    cfg.policies = vec!["load_balance".into()];
    Deployment::launch(cfg).unwrap()
}

/// Block (wall clock, bounded) until `cond` holds — scheduler bookkeeping
/// runs on worker threads, so gauges settle an instant after fulfilment.
fn settle(what: &str, cond: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(5), "timed out settling: {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The no-leak invariant every lifecycle path must restore: both
/// scheduler tables empty once all tickets are terminal.
fn assert_drained(ing: &Ingress, wf: WorkflowKind) {
    settle("scheduler tables drain", || {
        let m = ing.metrics(wf).unwrap();
        m.in_flight == 0 && m.depth == 0
    });
}

/// Race matrix #1 — cancel vs complete, many seeded rounds: whichever
/// side wins, the ticket observes exactly one terminal outcome, the
/// counters agree with it, and no table entry survives.
#[test]
fn cancel_vs_complete_yields_exactly_one_terminal_outcome() {
    let d = fast_router();
    let (clock, _vclock) = Clock::manual(); // frozen: deadlines stay out of this race
    let mut opts = SchedulerOpts::new(2, 64);
    opts.clock = clock;
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let rounds = 24;
    let (mut ok, mut cancelled) = (0u64, 0u64);
    for i in 0..rounds {
        let t = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver(&format!("r{i}"), 1))
                    .deadline(Duration::from_secs(1000)),
            )
            .unwrap();
        assert!(eng.wait_created(i + 1, Duration::from_secs(5)), "round {i} never started");
        let cell = eng.cell(i);
        std::thread::scope(|s| {
            s.spawn(move || cell.resolve(json!(1), 0));
            s.spawn(|| {
                t.cancel();
            });
        });
        match t.wait(Duration::from_secs(5)) {
            Ok(_) => ok += 1,
            Err(Error::Cancelled) => cancelled += 1,
            Err(e) => panic!("round {i}: impossible terminal outcome {e}"),
        }
    }
    assert_eq!(ok + cancelled, rounds as u64, "exactly one outcome per ticket");
    settle("counters agree with outcomes", || {
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        m.completed == ok && m.cancelled == cancelled && m.failed == 0
    });
    assert_drained(&ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}

/// Race matrix #2 — cancel vs deadline expiry on a virtual clock: the
/// clock jumps past the deadline while a cancel lands, repeatedly.
/// Exactly one of `Deadline`/`Cancelled` per ticket, counters split the
/// same way, tables drain.
#[test]
fn cancel_vs_deadline_expiry_yields_exactly_one_terminal_outcome() {
    let d = fast_router();
    let (clock, vclock) = Clock::manual();
    let mut opts = SchedulerOpts::new(2, 64);
    opts.clock = clock;
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let rounds = 16;
    let (mut expired, mut cancelled) = (0u64, 0u64);
    for i in 0..rounds {
        let t = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver(&format!("r{i}"), 1))
                    .deadline(Duration::from_secs(10)), // virtual seconds
            )
            .unwrap();
        assert!(eng.wait_created(i + 1, Duration::from_secs(5)), "round {i} never parked");
        std::thread::scope(|s| {
            s.spawn(|| vclock.advance(Duration::from_secs(11)));
            s.spawn(|| {
                t.cancel();
            });
        });
        match t.wait(Duration::from_secs(5)) {
            Err(Error::Deadline(_)) => expired += 1,
            Err(Error::Cancelled) => cancelled += 1,
            other => panic!("round {i}: impossible terminal outcome {other:?}"),
        }
    }
    assert_eq!(expired + cancelled, rounds as u64);
    settle("counters agree with outcomes", || {
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        // parked expiries count as execution failures, in-queue never
        // happened here (every round started before the clock moved)
        m.failed == expired && m.cancelled == cancelled && m.expired_in_queue == 0
    });
    assert_drained(&ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}

/// Race matrix #3 — double cancel and cancel-after-completion are
/// observable no-ops: `cancel` reports delivery, not outcome.
#[test]
fn double_cancel_and_cancel_after_completion_change_nothing() {
    let d = fast_router();
    let ing = Ingress::start_with(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, 2);
    let eng = ScriptedEngine::new();
    let long = Duration::from_secs(1000);

    let t1 = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("victim", 1))
                .deadline(long),
        )
        .unwrap();
    assert!(eng.wait_created(1, Duration::from_secs(5)));
    assert!(t1.cancel(), "first cancel is delivered");
    assert!(!t1.cancel(), "second cancel finds nothing to remove");
    assert!(matches!(t1.wait(Duration::from_secs(5)), Err(Error::Cancelled)));

    let t2 = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("survivor", 1))
                .deadline(long),
        )
        .unwrap();
    assert!(eng.wait_created(2, Duration::from_secs(5)));
    eng.cell(1).resolve(json!("done"), 0);
    t2.wait(Duration::from_secs(5)).unwrap();
    assert!(!t2.cancel(), "cancel after completion is a no-op");

    settle("counters", || {
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        m.cancelled == 1 && m.completed == 1 && m.failed == 0
    });
    assert_drained(&ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}

/// Race matrix #4 — cancel while still queued: the driver must never be
/// built, and the entry leaves the admission queue immediately.
#[test]
fn cancel_while_queued_never_starts_the_driver() {
    let d = fast_router();
    let ing = Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router],
        AdmissionPolicy::Unbounded,
        SchedulerOpts::new(1, 1),
    );
    let eng = ScriptedEngine::new();
    let long = Duration::from_secs(1000);
    // A gated blocker owns the single worker AND the single in-flight
    // slot, so the victim cannot start.
    let gate = Gate::new();
    let blocker = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.gated_driver("blocker", 0, gate.clone()))
                .deadline(long),
        )
        .unwrap();
    settle("blocker occupies the slot", || ing.in_flight(WorkflowKind::Router) == 1);
    let victim = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("victim", 1))
                .deadline(long),
        )
        .unwrap();
    assert_eq!(ing.depth(WorkflowKind::Router), 1, "victim must be queued");
    assert!(victim.cancel());
    assert_eq!(ing.depth(WorkflowKind::Router), 0, "cancel removes the queue entry at once");
    assert!(matches!(victim.wait(Duration::from_secs(5)), Err(Error::Cancelled)));
    gate.open();
    blocker.wait(Duration::from_secs(5)).unwrap();
    assert_eq!(eng.created_count(), 0, "the cancelled driver never issued a call");
    settle("counters", || {
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        m.cancelled == 1 && m.completed == 1 && m.expired_in_queue == 0 && m.failed == 0
    });
    assert_drained(&ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}

/// Ready-queue ordering: three parked requests wake while the single
/// worker is held hostage; under `deadline_slack` it must drain them
/// most-urgent-first, not in wake order.
#[test]
fn deadline_slack_drains_ready_work_most_urgent_first() {
    let d = fast_router();
    let mut opts = SchedulerOpts::new(1, 8);
    opts.schedule = Some(SchedulePolicy::DeadlineSlack);
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    // Reverse-urgency submit order, so FIFO would be wrong.
    let far = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("far", 1))
                .deadline(Duration::from_secs(1000)),
        )
        .unwrap();
    let mid = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("mid", 1))
                .deadline(Duration::from_secs(500)),
        )
        .unwrap();
    let near = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("near", 1))
                .deadline(Duration::from_secs(100)),
        )
        .unwrap();
    assert!(eng.wait_created(3, Duration::from_secs(5)));
    settle("all three parked", || ing.in_flight(WorkflowKind::Router) == 3);
    // Hold the worker, then wake all three in reverse-urgency order.
    let gate = Gate::new();
    let blocker = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.gated_driver("blocker", 0, gate.clone()))
                .deadline(Duration::from_secs(1000)),
        )
        .unwrap();
    settle("worker committed to the blocker", || ing.in_flight(WorkflowKind::Router) == 4);
    for i in 0..3 {
        // wake all three (in whatever order they started); they pile up
        // in the ready queue because the only worker is gated
        eng.cell(i).resolve(json!(i as i64), 0);
    }
    gate.open();
    for t in [&near, &mid, &far, &blocker] {
        t.wait(Duration::from_secs(5)).unwrap();
    }
    assert_eq!(
        eng.completions(),
        vec!["blocker", "near", "mid", "far"],
        "slack order, not wake order"
    );
    assert_drained(&ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}

/// Seeded A/B reproduction of the scheduling claim (ROADMAP "order
/// wakeups by deadline slack or graph stage"; paper §4/§6: runtime
/// scheduling control cuts tail latency): one 40-request mixed-deadline
/// trace, two runs differing ONLY in `ingress.schedule`.
///
/// **The trace** (virtual time; submitted as one burst at t=0 behind a
/// gate, so both runs pop from an identical 40-deep queue; one scripted
/// call per request; the pump prices every call at exactly 2 virtual
/// seconds; workers=1 and max_in_flight=1 make the queue discipline the
/// only variable):
///
/// * requests 3, 7, 11, …, 39 (every 4th) — deadline 30 s (tight);
/// * all others — deadline 1000 s (generous).
///
/// FIFO serves arrival order: request i completes at 2·(i+1) s, so the
/// tight requests at i ≥ 15 — 7 of 10 — expire. `deadline_slack` (EDF
/// until stage stats warm up, which only shifts every key equally here)
/// serves the 10 tight requests first: all done by t=20 s < 30 s, the
/// generous ones by t=80 s ≪ 1000 s. 0 misses vs 7 on the same trace.
#[test]
fn seeded_ab_trace_deadline_slack_strictly_reduces_deadline_misses() {
    let fifo = run_mixed_deadline_trace(SchedulePolicy::Fifo);
    let slack = run_mixed_deadline_trace(SchedulePolicy::DeadlineSlack);
    assert_eq!(fifo, 7, "FIFO must miss the tail of the tight requests");
    assert_eq!(slack, 0, "slack ordering must serve every tight request in time");
    assert!(slack < fifo, "the scheduling claim: slack strictly reduces misses");
}

fn run_mixed_deadline_trace(schedule: SchedulePolicy) -> usize {
    let d = fast_router();
    let (clock, vclock) = Clock::manual();
    let mut opts = SchedulerOpts::new(1, 1);
    opts.schedule = Some(schedule);
    opts.clock = clock;
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let gate = Gate::new();
    let blocker = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.gated_driver("blocker", 0, gate.clone()))
                .deadline(Duration::from_secs(100_000)),
        )
        .unwrap();
    settle("blocker holds the worker", || ing.in_flight(WorkflowKind::Router) == 1);
    let tickets: Vec<Ticket> = (0..40)
        .map(|i| {
            let timeout = if i % 4 == 3 {
                Duration::from_secs(30) // tight (virtual seconds)
            } else {
                Duration::from_secs(1000) // generous
            };
            ing.submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver(&format!("r{i}"), 1))
                    .deadline(timeout),
            )
            .unwrap()
        })
        .collect();
    assert_eq!(ing.depth(WorkflowKind::Router), 40, "whole trace queued before service starts");
    gate.open();
    // The pump: every started request's single call costs exactly 2
    // virtual seconds; whatever the clock leaves behind in the queue,
    // the sweep expires.
    let mut n = 0;
    while eng.wait_created(n + 1, Duration::from_secs(3)) {
        vclock.advance(Duration::from_secs(2));
        eng.cell(n).resolve(json!(n as i64), 0);
        n += 1;
    }
    blocker.wait(Duration::from_secs(5)).unwrap();
    let mut misses = 0;
    for (i, t) in tickets.iter().enumerate() {
        match t.wait(Duration::from_secs(5)) {
            Ok(_) => {}
            Err(Error::Deadline(_)) => misses += 1,
            Err(e) => panic!("request {i}: unexpected terminal outcome {e}"),
        }
    }
    assert_drained(&ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
    misses
}

/// Cross-shard stop/sweep drain (ISSUE 8): three workflows — three
/// scheduler lock domains — each carrying one parked request about to
/// expire, one parked request that will complete, and one queued request
/// the in-flight cap keeps waiting. One virtual-clock jump sweeps every
/// shard (the sweep visits lock domains one at a time); after the dust
/// settles, every shard's tables, the atomic gauges, and the future
/// index must all reach zero — sharding must not let any domain leak.
#[test]
fn cross_shard_stop_and_sweep_drain_every_shard_and_the_future_index() {
    let d = fast_router();
    let (clock, vclock) = Clock::manual();
    let kinds = [WorkflowKind::Router, WorkflowKind::Financial, WorkflowKind::Swe];
    let mut opts = SchedulerOpts::new(2, 6); // cap = exactly the parked set
    opts.clock = clock;
    let ing = Ingress::start_with_opts(&d, &kinds, AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let submit = |kind: WorkflowKind, label: &str, deadline: Duration| {
        ing.submit(
            SubmitRequest::workflow(kind)
                .driver(eng.driver(label, 1))
                .deadline(deadline),
        )
        .unwrap()
    };
    // Per shard: one short-deadline and one long-deadline request; all
    // six fit the in-flight cap, start, and park on their scripted call.
    let mut shorts = Vec::new();
    let mut longs = Vec::new();
    for kind in kinds {
        shorts.push(submit(kind, &format!("{}-short", kind.name()), Duration::from_secs(1)));
        longs.push(submit(kind, &format!("{}-long", kind.name()), Duration::from_secs(3600)));
    }
    assert!(eng.wait_created(6, Duration::from_secs(5)), "all six must park");
    // Per shard: one more short-deadline request — the cap is reached,
    // so it waits in the queue and will expire there.
    let queued: Vec<Ticket> = kinds
        .iter()
        .map(|&kind| submit(kind, &format!("{}-queued", kind.name()), Duration::from_secs(1)))
        .collect();
    // One clock jump expires every short deadline in every shard. The
    // sweep fails the parked shorts (freeing capacity shard by shard);
    // the queued shorts are counted `expired_in_queue` whether the sweep
    // collects them or a newly freed worker admits them first — `admit`
    // checks the deadline before building the driver.
    vclock.advance(Duration::from_secs(2));
    for t in &shorts {
        match t.wait(Duration::from_secs(5)) {
            Err(Error::Deadline(_)) => {}
            other => panic!("parked short must expire, got {other:?}"),
        }
    }
    for t in &queued {
        match t.wait(Duration::from_secs(5)) {
            Err(Error::Deadline(_)) => {}
            other => panic!("queued short must expire, got {other:?}"),
        }
    }
    // Resolve all six scripted calls: the failed shorts' cells are
    // already failed (resolve is a lost race, a no-op), the longs wake,
    // finish, and complete.
    for i in 0..6 {
        eng.cell(i).resolve(json!(1), 0);
    }
    for t in &longs {
        t.wait(Duration::from_secs(5)).unwrap();
    }
    // Every lock domain drained, and the counters split per shard the
    // same way: 1 completed, 1 failed (parked expiry), 1 expired in queue.
    for kind in kinds {
        settle("per-shard counters settle", || {
            let m = ing.metrics(kind).unwrap();
            m.completed == 1 && m.failed == 1 && m.expired_in_queue == 1
        });
        assert_drained(&ing, kind);
        let m = ing.metrics(kind).unwrap();
        assert_eq!(m.accepted, 3, "{}", kind.name());
        assert_eq!(m.cancelled, 0, "{}", kind.name());
    }
    // The future index drained with the shards: terminal requests must
    // not leave per-request entries behind.
    settle("future index drains", || d.table().request_index_len() == 0);
    ing.stop();
    // After stop, GC leaves the future table itself empty — and the
    // atomic live-count agrees with a full shard walk.
    d.table().gc_terminal();
    assert_eq!(d.table().len(), 0, "no live futures survive the drain");
    d.table().debug_assert_len();
    d.shutdown();
}

/// Crash-replay race (ISSUE 9): the node dies in the window between an
/// engine-side future resolve and the requester's resume. The journal
/// records the resolve but no terminal; replay must re-issue the stage's
/// future afresh and produce exactly one terminal outcome — the
/// crash-window resolve must never double-resolve the request (the
/// resolve-after-fail drop semantics hold across a restart).
#[test]
fn crash_between_resolve_and_resume_replays_without_double_resolution() {
    let path = std::env::temp_dir()
        .join(format!("nalar-itest-crashrace-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);

    // Incarnation 1: park one scripted request, then die before it can
    // resume.
    let d = fast_router();
    let mut opts = SchedulerOpts::new(1, 4);
    opts.journal = JournalSink::open(&path, FsyncPolicy::Always).unwrap();
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let t = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("race", 1))
                .deadline(Duration::from_secs(600)),
        )
        .unwrap();
    assert!(eng.wait_created(1, Duration::from_secs(5)));
    // admitted + started + parked must all be durable before the crash
    settle("parked record journaled", || ing.journal().journal().unwrap().records() >= 3);
    ing.halt();
    // The engine resolves the future AFTER the node died — the exact
    // crash window. The subscribed waker still journals a `resolved`
    // record, but no scheduler is left to resume the request.
    eng.cell(0).resolve(json!("late"), 0);
    ing.journal().sync();
    assert!(t.try_take().is_none(), "a crashed node fulfils nothing");
    drop(ing);
    d.shutdown();

    // Replay: the resolve is advisory, not a terminal — the request is
    // still in flight in the journal and replays onto a fresh node.
    let plan = journal::load(&path).unwrap();
    assert_eq!(plan.completed, 0, "a resolve is not a terminal outcome");
    assert_eq!(plan.inflight.len(), 1);
    let d2 = fast_router();
    let mut opts2 = SchedulerOpts::new(1, 4);
    opts2.journal = JournalSink::open(&path, FsyncPolicy::Always).unwrap();
    let ing2 =
        Ingress::start_with_opts(&d2, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts2);
    let eng2 = ScriptedEngine::new();
    let outcome = ing2.recover_with(&plan, |_, _, _| eng2.driver("race", 1));
    assert_eq!((outcome.stats.recovered, outcome.stats.lost), (1, 0));
    let t2 = &outcome.tickets[0];
    // The replayed stage re-issues its future afresh; the dead
    // incarnation's cell was spent in the dead incarnation's table and
    // is never consumed twice.
    assert!(eng2.wait_created(1, Duration::from_secs(5)), "the stage's future is re-issued");
    eng2.cell(0).resolve(json!("fresh"), 0);
    let out = t2.wait(Duration::from_secs(10)).unwrap();
    assert_eq!(out.get("scripted").as_str(), Some("race"));
    assert_drained(&ing2, WorkflowKind::Router);
    settle("future index drains", || d2.table().request_index_len() == 0);
    ing2.stop();
    d2.shutdown();

    // Exactly one terminal record for the request across both
    // incarnations: the crash-window resolve did not double-complete it.
    let text = std::fs::read_to_string(&path).unwrap();
    let terminals = text.lines().filter(|l| l.contains("\"t\":\"terminal\"")).count();
    assert_eq!(terminals, 1, "exactly one terminal outcome across the crash");
    let resolves = text.lines().filter(|l| l.contains("\"t\":\"resolved\"")).count();
    assert!(resolves >= 2, "both the crash-window and the replayed resolve are journaled");
    let _ = std::fs::remove_file(&path);
}
