//! `nalar` CLI: launch deployments, run workloads, reproduce the paper.
//!
//! ```text
//! nalar run     --workflow financial|router|swe --system nalar|ayo|crew|autogen
//!               [--rps 8] [--secs 5] [--config path.json]
//! nalar info    [--config path.json]      # validate + describe a deployment
//! nalar bench   [--quick] [--only fig9,fig10,table4,sec62] [--out DIR]
//!               [--check-only]            # writes/validates BENCH_*.json
//! nalar bench contention [--quick] [--out DIR] [--check-only]
//!               # scheduler lock-scaling microbenchmark: sweeps worker
//!               # threads × workflow shards × tenants, reporting
//!               # submit/wake/poll/complete throughput and p99
//!               # shard-lock hold time -> BENCH_contention.json
//! nalar bench recovery [--quick] [--out DIR] [--check-only]
//!               # kill-and-recover scenario: a journal-enabled ingress is
//!               # halted mid-load, the journal replayed into a fresh node,
//!               # every survivor driven to completion (DESIGN.md §12)
//!               # -> BENCH_recovery.json
//! nalar bench routing [--quick] [--out DIR] [--check-only]
//!               # JIT model-routing comparison: the rps sweep run once
//!               # per routing mode (jit vs fixed-large) on a
//!               # variant-declaring config, gated on jit achieving
//!               # strictly higher goodput at an equal quality floor
//!               # (DESIGN.md §13) -> BENCH_routing.json
//! nalar serve   --workflow router|financial|swe [--system nalar|...] [--secs 30]
//!               [--rps N] [--config path.json] [--journal PATH]
//!               [--listen 127.0.0.1:8080] [--port-file P] [--stop-file P]
//!               [--time-scale F]
//!               # hold a deployment open behind the ingress front door;
//!               # --listen serves the HTTP/1.1 wire protocol (DESIGN.md §9)
//!               # instead of in-process self-traffic: --port-file writes
//!               # the bound port (for `--listen 127.0.0.1:0`), --stop-file
//!               # shuts down cleanly when the named file appears, and the
//!               # exit status asserts zero leaked connections;
//!               # --journal enables the durable request journal at PATH —
//!               # on startup an existing journal is replayed (crash
//!               # recovery, DESIGN.md §12) and the replay stats printed
//! nalar loadgen --workload router|financial|swe [--rps 20,40,80 | 20:160:20]
//!               [--systems nalar,ayo,crew,autogen] [--secs N] [--quick]
//!               [--hc-smoke] [--workers N] [--cancel-rate 0.1]
//!               [--schedule fifo,deadline_slack] [--route fixed,jit]
//!               [--tenants noisy | name:share[:weight],...] [--out DIR]
//!               [--config path.json] [--check-only] [--remote HOST:PORT]
//!               # open-loop saturation sweep -> BENCH_rps_sweep.json;
//!               # --hc-smoke gates on every admitted request completing
//!               # (and no scheduler-table leak) with a 4-thread
//!               # deadline_slack scheduler (in-flight >> threads);
//!               # --cancel-rate withdraws a seeded fraction of admitted
//!               # requests mid-flight; --schedule adds a front-door
//!               # scheduling axis (FIFO vs SRTF tail latency);
//!               # --route adds a model-routing axis (jit vs fixed pins,
//!               # needs a config declaring engine.variants);
//!               # --tenants splits the offered load across tenants
//!               # (DRR weights + per-tenant goodput rows — `noisy` is
//!               # the 10x noisy-neighbor profile at equal weights);
//!               # --remote drives a live `nalar serve --listen` socket
//!               # over HTTP instead of an in-process deployment
//! nalar trace   --workflow router|financial|swe [--system nalar|...]
//!               [--requests N] [--k N] [--config path.json] [--time-scale F]
//!               # run N requests through the ingress front door and print
//!               # span-timeline waterfalls for the k slowest (DESIGN.md
//!               # §10): every lifecycle event with its offset, plus the
//!               # per-stage latency decomposition
//! ```

use std::path::PathBuf;
use std::time::{Duration, Instant};

use nalar::baselines::SystemUnderTest;
use nalar::bench::{self, BenchOpts};
use nalar::config::DeploymentConfig;
use nalar::ingress::loadgen::{self, LoadgenOpts};
use nalar::ingress::{Ingress, SubmitRequest};
use nalar::server::http::HttpServer;
use nalar::server::Deployment;
use nalar::util::cli::Args;
use nalar::util::rng::Rng;
use nalar::workflow::harness::input_for;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};
use nalar::workload::{self, Arrivals};

/// Strict system-name parse: a typo must not silently change which system
/// a run or a benchmark point measures.
fn parse_system(s: &str) -> nalar::Result<SystemUnderTest> {
    Ok(match s {
        "nalar" => SystemUnderTest::Nalar,
        "ayo" => SystemUnderTest::AyoLike,
        "crew" => SystemUnderTest::CrewLike,
        "autogen" => SystemUnderTest::AutoGenLike,
        other => {
            return Err(nalar::Error::Config(format!(
                "unknown system `{other}` (known: nalar, ayo, crew, autogen)"
            )))
        }
    })
}

/// Strict workflow-name parse, same rationale.
fn parse_workflow(s: &str) -> nalar::Result<WorkflowKind> {
    WorkflowKind::parse(s).ok_or_else(|| {
        nalar::Error::Config(format!("unknown workflow `{s}` (known: financial, router, swe)"))
    })
}

fn main() -> nalar::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("info") => cmd_info(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("trace") => cmd_trace(&args),
        _ => {
            eprintln!(
                "usage: nalar <run|info|bench|serve|loadgen|trace> \
                 [--workflow financial|router|swe] \
                 [--system nalar|ayo|crew|autogen] [--rps N] [--secs N] [--config file.json] \
                 | bench [--quick] [--only fig9,fig10,table4,sec62] [--out DIR] [--check-only] \
                 | bench contention [--quick] [--out DIR] [--check-only] \
                 | bench recovery [--quick] [--out DIR] [--check-only] \
                 | serve [--workflow ...] [--secs N] [--rps N] [--listen ADDR] \
                 [--journal PATH] [--port-file P] [--stop-file P] [--time-scale F] \
                 | loadgen [--workload router|financial|swe] [--rps LIST|START:END:STEP] \
                 [--systems csv] [--secs N] [--quick] [--hc-smoke] [--workers N] \
                 [--cancel-rate F] [--schedule csv] [--tenants noisy|name:share[:weight],...] \
                 [--out DIR] [--check-only] [--remote HOST:PORT] \
                 | trace [--workflow ...] [--requests N] [--k N] [--time-scale F]"
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args, wf: WorkflowKind) -> nalar::Result<DeploymentConfig> {
    Ok(match args.get("config") {
        Some(path) => DeploymentConfig::from_json_file(path)?,
        None => wf.config(),
    })
}

fn cmd_run(args: &Args) -> nalar::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "financial"))?;
    let system = parse_system(&args.str_or("system", "nalar"))?;
    let cfg = load_config(args, wf)?;
    let scale = cfg.time_scale;
    let d = Deployment::launch_as(cfg, system)?;
    let rc = RunConfig {
        workflow: wf,
        rps: args.f64_or("rps", 8.0),
        duration: Duration::from_secs(args.u64_or("secs", 5)),
        session_pool: args.usize_or("sessions", 32),
        request_timeout: Duration::from_secs(args.u64_or("timeout", 60)),
        seed: args.u64_or("seed", 7),
    };
    println!(
        "running {} on {} at {} wall-RPS for {:?} (time_scale {})",
        wf.name(),
        system.name(),
        rc.rps,
        rc.duration,
        scale
    );
    let (stats, rec) = run_open_loop(&d, &rc);
    let paper = rec.summary_scaled(1.0 / stats.time_scale);
    println!(
        "completed {} failed {} | paper-s avg {:.1} p50 {:.1} p95 {:.1} p99 {:.1} | imbalance {:.2}x",
        stats.completed, stats.failed, paper.avg, paper.p50, paper.p95, paper.p99, stats.imbalance
    );
    d.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> nalar::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "financial"))?;
    let cfg = load_config(args, wf)?;
    println!("nodes: {}  time_scale: {}  policies: {:?}", cfg.nodes, cfg.time_scale, cfg.policies);
    for a in &cfg.agents {
        println!(
            "  {:<16} {:?} x{}  stateful={} batchable={} managed_state={} max={}",
            a.name,
            a.kind,
            a.instances,
            a.directives.stateful,
            a.directives.batchable,
            a.directives.managed_state,
            a.directives.max_instances
        );
    }
    Ok(())
}

/// `nalar bench`: the one-command reproduction of the paper's numbers
/// (Fig. 9, Fig. 10, Table 4, §6.2), emitting schema-validated
/// `BENCH_*.json` reports. `--quick` is the CI-smoke profile.
fn cmd_bench(args: &Args) -> nalar::Result<()> {
    let out_dir = PathBuf::from(args.str_or("out", "."));
    // `nalar bench contention`: the scheduler lock-scaling microbenchmark
    // (own subcommand, like `nalar loadgen` — not part of `bench::ALL`).
    if args.positional.get(1).map(|s| s.as_str()) == Some("contention") {
        if args.flag("check-only") {
            return bench::check_files(&out_dir, &[bench::CONTENTION]);
        }
        let quick = args.flag("quick") || std::env::var("NALAR_BENCH_QUICK").is_ok();
        let path = bench::run_contention(quick, &out_dir)?;
        println!("bench reports written:\n  {}", path.display());
        return Ok(());
    }
    // `nalar bench recovery`: the kill-and-recover scenario (also its own
    // subcommand — it needs a journal file and a deliberate halt, not the
    // steady-state harness the figure benches share).
    if args.positional.get(1).map(|s| s.as_str()) == Some("recovery") {
        if args.flag("check-only") {
            return bench::check_files(&out_dir, &[bench::RECOVERY]);
        }
        let quick = args.flag("quick") || std::env::var("NALAR_BENCH_QUICK").is_ok();
        let path = bench::run_recovery(quick, &out_dir)?;
        println!("bench reports written:\n  {}", path.display());
        return Ok(());
    }
    // `nalar bench routing`: the JIT-routing goodput comparison — the
    // same rps sweep run per routing mode (jit vs a fixed-large pin) on a
    // variant-declaring config, gated on jit winning goodput at an equal
    // quality floor (DESIGN.md §13).
    if args.positional.get(1).map(|s| s.as_str()) == Some("routing") {
        if args.flag("check-only") {
            return bench::check_files(&out_dir, &[bench::ROUTING]);
        }
        let quick = args.flag("quick") || std::env::var("NALAR_BENCH_QUICK").is_ok();
        let path = bench::run_routing(quick, &out_dir)?;
        println!("bench reports written:\n  {}", path.display());
        return Ok(());
    }
    let only: Option<Vec<String>> = args
        .get("only")
        .map(|s| s.split(',').map(|p| p.trim().to_string()).collect());
    if args.flag("check-only") {
        let names: Vec<&str> = match &only {
            Some(list) => list.iter().map(|s| s.as_str()).collect(),
            None => bench::ALL.to_vec(),
        };
        return bench::check_files(&out_dir, &names);
    }
    let opts = BenchOpts {
        quick: args.flag("quick") || std::env::var("NALAR_BENCH_QUICK").is_ok(),
        out_dir,
        only,
    };
    let written = bench::run(&opts)?;
    println!("bench reports written:");
    for p in written {
        println!("  {}", p.display());
    }
    Ok(())
}

/// `nalar serve`: hold a deployment open behind the ingress front door,
/// printing per-second front-door telemetry. Two traffic sources:
/// `--listen ADDR` starts the HTTP/1.1 serving plane (DESIGN.md §9) so
/// submissions arrive over a real socket; `--rps N` feeds an in-process
/// open-loop self-traffic stream (the pre-wire behaviour).
fn cmd_serve(args: &Args) -> nalar::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "router"))?;
    let system = parse_system(&args.str_or("system", "nalar"))?;
    let mut cfg = load_config(args, wf)?;
    if let Some(ts) = args.get("time-scale") {
        cfg.time_scale = ts
            .parse()
            .map_err(|_| nalar::Error::Config(format!("bad --time-scale `{ts}`")))?;
    }
    // --journal PATH: durable request journal + crash recovery. An
    // existing file at PATH is replayed by `Ingress::start` before the
    // front door opens (DESIGN.md §12).
    if let Some(journal) = args.get("journal") {
        cfg.ingress.journal.path = journal.to_string();
    }
    let time_scale = cfg.time_scale;
    let d = Deployment::launch_as(cfg, system)?;
    let ingress = std::sync::Arc::new(Ingress::start(&d, &[wf]));
    if let Some(r) = ingress.recovery() {
        println!(
            "[serve] journal replay: {} request(s) recovered, {} already terminal \
             (skipped), {} lost, {} corrupt line(s)",
            r.recovered, r.skipped_complete, r.lost, r.corrupt
        );
    }
    if let Some(listen) = args.get("listen") {
        let listen = listen.to_string();
        return serve_http(args, d, ingress, wf, &listen);
    }
    let secs = args.u64_or("secs", 30);
    let rps = args.f64_or("rps", 0.0);
    let timeout = Duration::from_secs_f64(
        (args.f64_or("timeout-paper-s", 30.0) * time_scale).max(0.001),
    );
    println!(
        "serving `{}` on {} behind the ingress front door for {secs}s \
         (admission {}, self-traffic {rps} rps)",
        wf.name(),
        system.name(),
        d.cfg().ingress.policy
    );
    let window = Duration::from_secs(secs.max(1));
    std::thread::scope(|scope| {
        if rps > 0.0 {
            let ingress = &ingress;
            scope.spawn(move || {
                let mut arrivals = Arrivals::new(rps, args.u64_or("seed", 7));
                let mut rng = Rng::new(0x5e44e);
                let start = Instant::now();
                for at in arrivals.schedule(window) {
                    let wait = at.saturating_sub(start.elapsed());
                    if !wait.is_zero() {
                        std::thread::sleep(wait);
                    }
                    let progress = (start.elapsed().as_secs_f64() / window.as_secs_f64()).min(1.0);
                    let input = input_for(wf, progress, 0, &mut rng);
                    // fire and forget
                    let _ = ingress
                        .submit(SubmitRequest::workflow(wf).input(input).deadline(timeout));
                }
            });
        }
        for _ in 0..secs.max(1) {
            std::thread::sleep(Duration::from_secs(1));
            if let Some(m) = ingress.metrics(wf) {
                println!(
                    "[serve] {} depth {} in-flight {}/{}t accepted {} shed {} completed {} \
                     failed {} expired {} cancelled {}",
                    m.schedule,
                    m.depth,
                    m.in_flight,
                    m.workers,
                    m.accepted,
                    m.shed,
                    m.completed,
                    m.failed,
                    m.expired_in_queue,
                    m.cancelled
                );
                // per-tenant split when the front door actually has
                // tenants (the implicit single `default` prints nothing)
                if m.tenants.len() > 1 {
                    for t in &m.tenants {
                        println!(
                            "[serve]   tenant {:<12} w {:<4} depth {} accepted {} shed {} \
                             completed {} cancelled {}",
                            t.tenant, t.weight, t.depth, t.accepted, t.shed, t.completed,
                            t.cancelled
                        );
                    }
                }
            }
        }
    });
    ingress.stop();
    d.shutdown();
    Ok(())
}

/// `nalar serve --listen`: the HTTP serving plane. Runs until `--secs`
/// elapses or the `--stop-file` path appears (the poll-based stand-in for
/// signal handling in a zero-dependency build), then asserts a clean
/// shutdown: a nonzero exit if any accepted connection leaked — the gate
/// the `serve-smoke` CI job relies on.
fn serve_http(
    args: &Args,
    d: Deployment,
    ingress: std::sync::Arc<Ingress>,
    wf: WorkflowKind,
    listen: &str,
) -> nalar::Result<()> {
    let server = HttpServer::start(&d, ingress.clone(), &[wf], listen)?;
    let addr = server.addr();
    println!(
        "[serve] listening on http://{addr} — POST /v1/workflows/{}/requests, \
         GET /metrics (time_scale {})",
        wf.name(),
        d.cfg().time_scale
    );
    if let Some(path) = args.get("port-file") {
        std::fs::write(path, format!("{}\n", addr.port()))?;
    }
    let secs = args.u64_or("secs", 0); // 0 = until the stop file appears
    let stop_file = args.get("stop-file").map(PathBuf::from);
    let started = Instant::now();
    let mut last_print = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(200));
        if let Some(f) = &stop_file {
            if f.exists() {
                println!("[serve] stop file present, shutting down");
                break;
            }
        }
        if secs > 0 && started.elapsed() >= Duration::from_secs(secs) {
            break;
        }
        // safety net when neither bound was given: don't serve forever
        if secs == 0 && stop_file.is_none() && started.elapsed() >= Duration::from_secs(3600) {
            break;
        }
        if last_print.elapsed() >= Duration::from_secs(1) {
            last_print = Instant::now();
            if let Some(m) = ingress.metrics(wf) {
                println!(
                    "[serve] conns {} depth {} in-flight {} accepted {} shed {} completed {} \
                     failed {} expired {} cancelled {}",
                    server.open_connections(),
                    m.depth,
                    m.in_flight,
                    m.accepted,
                    m.shed,
                    m.completed,
                    m.failed,
                    m.expired_in_queue,
                    m.cancelled
                );
            }
        }
    }
    let leaked = server.stop();
    ingress.stop();
    d.shutdown();
    if leaked != 0 {
        return Err(nalar::Error::State(format!(
            "{leaked} HTTP connection(s) leaked at shutdown"
        )));
    }
    println!("[serve] clean shutdown: 0 leaked connections");
    Ok(())
}

/// `nalar trace`: run a handful of requests through the ingress front
/// door and print the span-timeline waterfall of the k slowest — the CLI
/// view of the flight recorder behind `GET /v1/requests/{id}/trace`
/// (DESIGN.md §10).
fn cmd_trace(args: &Args) -> nalar::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "router"))?;
    let system = parse_system(&args.str_or("system", "nalar"))?;
    let mut cfg = load_config(args, wf)?;
    if let Some(ts) = args.get("time-scale") {
        cfg.time_scale = ts
            .parse()
            .map_err(|_| nalar::Error::Config(format!("bad --time-scale `{ts}`")))?;
    }
    let time_scale = cfg.time_scale;
    let d = Deployment::launch_as(cfg, system)?;
    let ingress = std::sync::Arc::new(Ingress::start(&d, &[wf]));
    let n = args.usize_or("requests", 12).max(1);
    let k = args.usize_or("k", 5).max(1);
    let timeout = Duration::from_secs_f64(
        (args.f64_or("timeout-paper-s", 30.0) * time_scale).max(0.001),
    );
    let mut rng = Rng::new(args.u64_or("seed", 7));
    println!(
        "tracing {n} `{}` request(s) on {} (time_scale {time_scale}, k = {k} slowest)",
        wf.name(),
        system.name()
    );
    let mut tickets = Vec::with_capacity(n);
    for i in 0..n {
        let input = input_for(wf, i as f64 / n as f64, 0, &mut rng);
        tickets.push(ingress.submit(SubmitRequest::workflow(wf).input(input).deadline(timeout))?);
    }
    // settle everything first so the waterfalls describe finished requests
    let mut rows: Vec<(usize, Duration, bool)> = Vec::with_capacity(n);
    for (i, t) in tickets.iter().enumerate() {
        let ok = t.wait(timeout + Duration::from_secs(5)).is_ok();
        rows.push((i, t.latency().unwrap_or_default(), ok));
    }
    rows.sort_by(|a, b| b.1.cmp(&a.1));
    let sink = ingress.trace();
    if !sink.enabled() {
        println!("tracing is disabled (ingress.trace.capacity = 0): no timelines to print");
    }
    for (rank, (i, latency, ok)) in rows.iter().take(k).enumerate() {
        let t = &tickets[*i];
        let events = sink.timeline(t.request);
        println!(
            "\n#{} request {}  latency {:.3}ms  {}",
            rank + 1,
            t.request.0,
            latency.as_secs_f64() * 1e3,
            if *ok { "ok" } else { "failed" }
        );
        if events.is_empty() {
            println!("   (no timeline — flight recorder overwrote it or tracing is off)");
            continue;
        }
        print_waterfall(&events);
    }
    let dropped = sink.dropped();
    if dropped > 0 {
        println!(
            "\n(flight recorder overwrote {dropped} event(s); raise ingress.trace.capacity \
             for complete timelines)"
        );
    }
    ingress.stop();
    d.shutdown();
    Ok(())
}

/// Render one request's span timeline as an ASCII waterfall: every event
/// with its offset from admission, a `#` bar spanning the gap to the next
/// event, and the folded per-stage decomposition underneath.
fn print_waterfall(events: &[nalar::trace::TraceEvent]) {
    const COLS: f64 = 40.0;
    let total_ns = events.last().map(|e| e.clock_ns).unwrap_or(0).max(1) as f64;
    for (i, e) in events.iter().enumerate() {
        let next_ns = events.get(i + 1).map(|n| n.clock_ns).unwrap_or(e.clock_ns);
        let lead = (((e.clock_ns as f64 / total_ns) * COLS).round() as usize).min(COLS as usize);
        // every non-final event gets at least one cell so zero-length
        // gaps (virtual clocks, sub-granularity stages) stay visible
        let span = ((((next_ns - e.clock_ns) as f64 / total_ns) * COLS).round() as usize)
            .max(usize::from(i + 1 < events.len()))
            .min(COLS as usize - lead);
        println!(
            "   {:>10.3}ms  {:<22} |{}{}{}|",
            e.clock_ns as f64 / 1e6,
            format!("{} ({})", e.kind.name(), e.detail),
            " ".repeat(lead),
            "#".repeat(span),
            " ".repeat((COLS as usize).saturating_sub(lead + span)),
        );
    }
    let s = nalar::trace::stage_durations(events);
    println!(
        "   stages: queue_wait {:.3}ms | sched_delay {:.3}ms | poll {:.3}ms | \
         future_wait {:.3}ms | engine_service {:.3}ms",
        s.queue_wait_ns as f64 / 1e6,
        s.sched_delay_ns as f64 / 1e6,
        s.poll_ns as f64 / 1e6,
        s.future_wait_ns as f64 / 1e6,
        s.engine_service_ns as f64 / 1e6
    );
}

/// `nalar loadgen`: the open-loop saturation sweep through the ingress
/// front door, emitting a schema-validated `BENCH_rps_sweep.json`.
/// `--quick` is the CI-smoke profile; `--check-only` re-validates the
/// report already on disk.
fn cmd_loadgen(args: &Args) -> nalar::Result<()> {
    let out_dir = PathBuf::from(args.str_or("out", "."));
    if args.flag("check-only") {
        return bench::check_files(&out_dir, &[bench::RPS_SWEEP]);
    }
    let wf = parse_workflow(&args.str_or("workload", "router"))?;
    let quick = args.flag("quick") || std::env::var("NALAR_LOADGEN_QUICK").is_ok();
    let mut opts = if args.flag("hc-smoke") {
        LoadgenOpts::hc_smoke(wf)
    } else if quick {
        LoadgenOpts::quick(wf)
    } else {
        LoadgenOpts::full(wf)
    };
    opts.out_dir = out_dir;
    if let Some(w) = args.get("workers") {
        let workers: usize =
            w.parse().map_err(|_| nalar::Error::Config(format!("bad --workers `{w}`")))?;
        opts.workers = Some(workers);
    }
    if let Some(r) = args.get("cancel-rate") {
        let rate: f64 =
            r.parse().map_err(|_| nalar::Error::Config(format!("bad --cancel-rate `{r}`")))?;
        if !(0.0..=1.0).contains(&rate) {
            return Err(nalar::Error::Config(format!(
                "--cancel-rate must be a probability in [0, 1], got `{r}`"
            )));
        }
        opts.cancel_rate = rate;
    }
    // Axis flags go through the subsystem name-authority parsers
    // (`SchedulePolicy::parse` / `RouteMode::parse`), so a typo dies here
    // — at flag-parse time — not minutes into a sweep.
    if let Some(csv) = args.get("schedule") {
        opts.schedules = Some(loadgen::parse_schedule_axis(csv).ok_or_else(|| {
            nalar::Error::Config(format!(
                "bad --schedule `{csv}` (known: fifo, deadline_slack, stage; no duplicates)"
            ))
        })?);
    }
    if let Some(csv) = args.get("route") {
        opts.routes = Some(loadgen::parse_route_axis(csv).ok_or_else(|| {
            nalar::Error::Config(format!(
                "bad --route `{csv}` (known: fixed, jit, fixed-<variant>; no duplicates)"
            ))
        })?);
    }
    if let Some(spec) = args.get("tenants") {
        opts.tenants = Some(loadgen::parse_tenant_mix(spec).ok_or_else(|| {
            nalar::Error::Config(format!(
                "bad --tenants `{spec}` (expected `noisy` or name:share[:weight],...)"
            ))
        })?);
    }
    if let Some(spec) = args.get("rps") {
        opts.rates = workload::parse_rps_sweep(spec)
            .ok_or_else(|| nalar::Error::Config(format!("bad --rps spec `{spec}`")))?;
    }
    if let Some(csv) = args.get("systems") {
        opts.systems = Vec::new();
        for name in csv.split(',') {
            let sys = parse_system(name.trim())?;
            if !opts.systems.contains(&sys) {
                opts.systems.push(sys);
            }
        }
    }
    if let Some(secs) = args.get("secs") {
        opts.secs = secs
            .parse()
            .map_err(|_| nalar::Error::Config(format!("bad --secs `{secs}`")))?;
    }
    if let Some(path) = args.get("config") {
        opts.config = Some(PathBuf::from(path));
    }
    opts.session_pool = args.usize_or("sessions", opts.session_pool);
    opts.timeout_paper_s = args.f64_or("timeout-paper-s", opts.timeout_paper_s);
    if let Some(ts) = args.get("time-scale") {
        let scale: f64 = ts
            .parse()
            .map_err(|_| nalar::Error::Config(format!("bad --time-scale `{ts}`")))?;
        opts.time_scale = Some(scale);
    }
    opts.seed = args.u64_or("seed", opts.seed);
    opts.remote = args.get("remote").map(String::from);
    let path = loadgen::run(&opts)?;
    println!("rps sweep written: {}", path.display());
    Ok(())
}
