//! Weighted-fair service across tenants: deficit round robin (DRR).
//!
//! One aggressive tenant must not starve the others sharing a workflow's
//! front-door queue ("Software-Defined Agentic Serving" makes the same
//! point: isolation policy belongs in the serving layer). The ingress
//! therefore keeps one sub-queue per tenant and asks [`Drr`] which tenant
//! to serve next; *inside* the chosen sub-queue the configured
//! [`crate::ingress::SchedulePolicy`] still orders requests, so fairness
//! composes with deadline-slack SRTF instead of replacing it.
//!
//! The discipline is classic DRR (Shreedhar & Varghese) specialised to
//! unit-cost work items (every pop serves exactly one request):
//!
//! * each tenant has a **quantum** proportional to its configured weight,
//!   normalised so the lightest tenant's quantum is exactly 1.0 — every
//!   backlogged tenant is served at least once per rotation, which keeps
//!   [`Drr::next`] O(tenants) per pop;
//! * a visit grants the tenant its quantum into a **deficit** counter;
//!   the tenant is served while the deficit covers the unit cost, and a
//!   fractional remainder carries to the next rotation;
//! * a tenant whose sub-queue empties forfeits its banked deficit
//!   (standard DRR: deficit measures *entitled service while backlogged*,
//!   not a savings account) — the ingress also resets it explicitly when
//!   a cancel or deadline expiry empties a sub-queue between pops.
//!
//! The fairness guarantee (property-tested in `tests/props.rs`): between
//! any two continuously-backlogged tenants, the weight-normalised service
//! gap never exceeds one maximum quantum.
//!
//! [`Drr`] is deliberately pure — a function of weights and the per-tenant
//! backlog lengths handed to each `next` call — so the deterministic
//! fairness suite exercises it without threads, clocks or a deployment.

/// Deficit-round-robin pop order over per-tenant sub-queues. See module
/// docs for the discipline and its fairness bound.
#[derive(Debug)]
pub struct Drr {
    /// Per-tenant service quantum, normalised so `min(quantum) == 1.0`.
    quantum: Vec<f64>,
    /// Entitled-but-unserved service per tenant (carries fractions of a
    /// quantum across rotations while the tenant stays backlogged).
    deficit: Vec<f64>,
    /// Tenant the rotation currently points at.
    cursor: usize,
    /// True when `cursor` just arrived at this tenant (its quantum for
    /// this rotation has not been granted yet). Distinguishes a fresh
    /// visit from re-serving the same tenant out of remaining deficit.
    fresh: bool,
}

impl Drr {
    /// Build from per-tenant DRR weights (config `ingress.tenants[].weight`,
    /// validated > 0).
    pub fn new(weights: &[f64]) -> Drr {
        assert!(!weights.is_empty(), "DRR needs at least one tenant");
        let min = weights.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min > 0.0 && min.is_finite(), "DRR weights must be finite and > 0");
        Drr {
            quantum: weights.iter().map(|w| w / min).collect(),
            deficit: vec![0.0; weights.len()],
            cursor: 0,
            fresh: true,
        }
    }

    pub fn tenants(&self) -> usize {
        self.quantum.len()
    }

    /// Which tenant the next pop serves, given each tenant's current
    /// sub-queue length. Returns `None` only when every sub-queue is
    /// empty. The caller MUST pop one request from the returned tenant's
    /// sub-queue — the unit cost is debited here.
    pub fn next(&mut self, backlog: &[usize]) -> Option<usize> {
        debug_assert_eq!(backlog.len(), self.quantum.len());
        if backlog.iter().all(|&b| b == 0) {
            return None;
        }
        // Bounded: quantum >= 1 for every tenant, so a fresh visit to a
        // backlogged tenant always serves — one full rotation suffices.
        for _ in 0..=self.quantum.len() {
            let t = self.cursor;
            if backlog[t] == 0 {
                // empty sub-queue forfeits its banked deficit (see module
                // docs) and the rotation moves on
                self.deficit[t] = 0.0;
                self.advance();
                continue;
            }
            if self.fresh {
                self.deficit[t] += self.quantum[t];
                self.fresh = false;
            }
            if self.deficit[t] >= 1.0 {
                self.deficit[t] -= 1.0;
                return Some(t);
            }
            self.advance();
        }
        unreachable!("a backlogged tenant must be served within one rotation");
    }

    /// Explicit deficit reset for a tenant whose sub-queue emptied
    /// *between* pops — a cancel or deadline expiry drained the last
    /// queued request, so the tenant must not bank entitlement it was
    /// granted while backlogged. (`next` also resets lazily on visiting
    /// an empty sub-queue; this closes the window where new arrivals land
    /// before the rotation comes around.)
    pub fn on_empty(&mut self, tenant: usize) {
        self.deficit[tenant] = 0.0;
    }

    fn advance(&mut self) {
        self.cursor = (self.cursor + 1) % self.quantum.len();
        self.fresh = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain `pops` picks against a fixed (never-emptying) backlog.
    fn service(drr: &mut Drr, backlog: &[usize], pops: usize) -> Vec<usize> {
        let mut served = vec![0usize; backlog.len()];
        for _ in 0..pops {
            served[drr.next(backlog).expect("backlogged")] += 1;
        }
        served
    }

    #[test]
    fn single_tenant_degenerates_to_the_plain_queue() {
        let mut drr = Drr::new(&[1.0]);
        assert_eq!(drr.tenants(), 1);
        for _ in 0..10 {
            assert_eq!(drr.next(&[5]), Some(0));
        }
        assert_eq!(drr.next(&[0]), None);
    }

    #[test]
    fn equal_weights_are_strict_round_robin() {
        let mut drr = Drr::new(&[1.0, 1.0, 1.0]);
        let order: Vec<usize> =
            (0..6).map(|_| drr.next(&[9, 9, 9]).unwrap()).collect();
        assert_eq!(order, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn weights_set_the_per_rotation_share() {
        // weight 2 vs 1: quanta 2.0/1.0 — two pops for A, one for B.
        let mut drr = Drr::new(&[2.0, 1.0]);
        let order: Vec<usize> = (0..6).map(|_| drr.next(&[9, 9]).unwrap()).collect();
        assert_eq!(order, vec![0, 0, 1, 0, 0, 1]);
    }

    #[test]
    fn fractional_quanta_carry_deficit_across_rotations() {
        // weights 2:3 normalise to quanta 1.0/1.5: B gets 1 then 2 pops
        // on alternating rotations — 3 per 2 rotations, exactly its share.
        let mut drr = Drr::new(&[2.0, 3.0]);
        let order: Vec<usize> = (0..10).map(|_| drr.next(&[9, 9]).unwrap()).collect();
        assert_eq!(order, vec![0, 1, 0, 1, 1, 0, 1, 0, 1, 1]);
        let served = service(&mut Drr::new(&[2.0, 3.0]), &[99, 99], 50);
        assert_eq!(served, vec![20, 30], "long-run service tracks the 2:3 weights");
    }

    #[test]
    fn empty_sub_queues_are_skipped_and_forfeit_deficit() {
        let mut drr = Drr::new(&[1.0, 1.0]);
        // B empty: A gets everything, work-conserving.
        assert_eq!(drr.next(&[3, 0]), Some(0));
        assert_eq!(drr.next(&[2, 0]), Some(0));
        // B filled up: strict alternation resumes, no banked B deficit
        // from the rotations it sat empty.
        let order: Vec<usize> = (0..4).map(|_| drr.next(&[9, 9]).unwrap()).collect();
        assert_eq!(order.iter().filter(|&&t| t == 1).count(), 2);
    }

    #[test]
    fn on_empty_resets_banked_entitlement() {
        // B (weight 3) banks deficit mid-service; its queue then empties
        // via cancel. After refill it must restart from a granted quantum,
        // not the banked remainder.
        let mut drr = Drr::new(&[1.0, 3.0]);
        assert_eq!(drr.next(&[5, 5]), Some(0));
        assert_eq!(drr.next(&[5, 5]), Some(1)); // deficit(B) now 2.0
        drr.on_empty(1); // cancel drained B's sub-queue
        // B refills; a fresh rotation grants quantum 3 — B serves 3, not
        // 3 + the 2 it banked before the cancel.
        let mut b_run = 0;
        assert_eq!(drr.next(&[5, 5]), Some(0));
        while drr.next(&[5, 5]) == Some(1) {
            b_run += 1;
        }
        assert_eq!(b_run, 3, "banked deficit must not survive an emptied sub-queue");
    }

    #[test]
    fn all_empty_returns_none_and_recovers() {
        let mut drr = Drr::new(&[1.0, 2.0]);
        assert_eq!(drr.next(&[0, 0]), None);
        assert!(drr.next(&[1, 1]).is_some(), "recovers once backlog returns");
    }
}
