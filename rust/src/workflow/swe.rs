//! Software-engineering workflow (paper Fig. 1 / Fig. 4, §6, Fig. 9c).
//!
//! The Fig. 4 driver, faithfully: a planner decomposes the request into
//! subtasks; each subtask goes to a developer agent (documentation lookup
//! feeding the implementation), whose output runs through the test
//! harness; failed subtasks are *relaunched by the driver* — the
//! fine-grained retry loop over `future.available()` / non-blocking value
//! probes that makes the workflow recursive and load non-deterministic.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::futures::{FutureHandle, Value};
use crate::ids::FutureId;
use crate::json;
use crate::workflow::Env;

const MAX_RETRIES: u32 = 3;

struct SubtaskRun {
    test: FutureHandle,
    code_future: FutureId,
    attempt: u32,
}

/// One coding request through plan -> implement -> test -> (retry).
pub fn run(env: &Env, input: &Value, timeout: Duration) -> Result<Value> {
    let task = input.get("task").as_str().unwrap_or("fix the bug");

    // #1 — planner decomposes the request (Fig. 4 lines 9-12: we block on
    // the plan because the subtask count is data-dependent).
    let plan = env
        .ctx
        .agent("planner")
        .call("plan", json!({"prompt": task, "max_new_tokens": 48}));
    let plan_out = plan.value(timeout)?;
    let plan_tokens = plan_out.get("generated_tokens").as_u64().unwrap_or(8);
    let n_subtasks = 2 + (plan_tokens % 3) as usize; // 2-4, model-driven

    // #2 — launch every subtask in parallel (non-blocking).
    let deeper = env.ctx.deeper();
    let launch = |attempt: u32| -> Vec<SubtaskRun> {
        (0..n_subtasks)
            .map(|i| {
                let docs = deeper.agent("documentation").call(
                    "get",
                    json!({"query": format!("{task} (part {i})"), "k": 2}),
                );
                let code = deeper.agent("developer").call_with(
                    "implement",
                    json!({
                        "prompt": format!("{task} — subtask {i}"),
                        "max_new_tokens": 160,
                    }),
                    &[plan.id(), docs.id()],
                    attempt,
                );
                let test = deeper.agent("test_harness").call_with(
                    "unit_test",
                    json!({"code": format!("subtask-{i}"), "attempt": attempt}),
                    &[code.id()],
                    attempt,
                );
                SubtaskRun { test, code_future: code.id(), attempt }
            })
            .collect()
    };

    let mut runs = launch(0);
    let mut done = vec![false; n_subtasks];
    let mut passed_codes: Vec<FutureId> = vec![FutureId(0); n_subtasks];
    let mut total_attempts = n_subtasks as u32;
    let deadline = std::time::Instant::now() + timeout;

    // #3 — the Fig. 4 retry loop: poll non-blocking, relaunch failures.
    while done.iter().any(|d| !d) {
        if std::time::Instant::now() >= deadline {
            return Err(Error::msg(format!("swe request timed out ({task})")));
        }
        let mut progressed = false;
        for i in 0..n_subtasks {
            if done[i] {
                continue;
            }
            let Some(result) = runs[i].test.try_value() else { continue };
            progressed = true;
            let passed = match result {
                Ok(v) => v.get("result").as_str() == Some("Pass"),
                Err(_) => false, // system error: driver retries (§5)
            };
            if passed {
                done[i] = true;
                passed_codes[i] = runs[i].code_future;
            } else {
                let attempt = runs[i].attempt + 1;
                if attempt > MAX_RETRIES {
                    return Err(Error::msg(format!(
                        "failed to implement `{task}` subtask {i} after {MAX_RETRIES} retries"
                    )));
                }
                // relaunch just this subtask (re-enters the graph: the LPT
                // policy's signal).
                let docs = deeper.agent("documentation").call(
                    "get",
                    json!({"query": format!("{task} (part {i}, retry)"), "k": 2}),
                );
                let code = deeper.agent("developer").call_with(
                    "implement",
                    json!({
                        "prompt": format!("{task} — subtask {i} retry {attempt}"),
                        "max_new_tokens": 160,
                    }),
                    &[docs.id()],
                    attempt,
                );
                let test = deeper.agent("test_harness").call_with(
                    "unit_test",
                    json!({"code": format!("subtask-{i}"), "attempt": attempt}),
                    &[code.id()],
                    attempt,
                );
                runs[i] = SubtaskRun { test, code_future: code.id(), attempt };
                total_attempts += 1;
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(300));
        }
    }

    // #4 — merge.
    Ok(json!({
        "task": task,
        "subtasks": n_subtasks,
        "attempts": total_attempts,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Deployment;
    use crate::workflow::WorkflowKind;

    #[test]
    fn completes_with_retries() {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let out = run(
            &env,
            &json!({"task": "Enable OAuth login for the website"}),
            Duration::from_secs(30),
        )
        .unwrap();
        let subtasks = out.get("subtasks").as_u64().unwrap();
        let attempts = out.get("attempts").as_u64().unwrap();
        assert!((2..=4).contains(&subtasks));
        assert!(attempts >= subtasks, "attempts {attempts} < subtasks {subtasks}");
        d.shutdown();
    }

    #[test]
    fn retries_recorded_in_graph_metadata() {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.time_scale = 0.0005;
        cfg.agents
            .iter_mut()
            .find(|a| a.name == "test_harness")
            .unwrap()
            .failure_rate = 0.9; // force retries
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        // may exhaust retries; both outcomes legal, but the future table
        // must contain retried futures either way
        let _ = run(&env, &json!({"task": "t"}), Duration::from_secs(30));
        let mut max_retry = 0;
        d.table().for_each(|c| {
            max_retry = max_retry.max(c.meta().retry_count);
        });
        assert!(max_retry >= 1, "no retried futures recorded");
        d.shutdown();
    }
}
