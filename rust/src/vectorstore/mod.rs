//! In-memory vector search — the ChromaDB substitute (DESIGN.md §3).
//!
//! The SWE workflow's documentation tool (paper Fig. 1 step 3) stores API
//! docs here and retrieves top-k by cosine similarity. Embeddings come
//! either from the real L2 `embed` entry (PJRT mode) or from the
//! deterministic [`HashEmbedder`] (sim mode) — both produce unit-norm
//! vectors, so the index code is identical.

use std::sync::RwLock;

/// Deterministic character-trigram hashing embedder (sim mode). Produces
/// unit-norm `dim`-vectors with the property that texts sharing trigrams
/// are closer — enough signal for retrieval-shaped workloads.
#[derive(Debug, Clone, Copy)]
pub struct HashEmbedder {
    pub dim: usize,
}

impl HashEmbedder {
    pub fn new(dim: usize) -> Self {
        HashEmbedder { dim }
    }

    pub fn embed(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0f32; self.dim];
        let bytes = text.as_bytes();
        if bytes.is_empty() {
            v[0] = 1.0;
            return v;
        }
        for w in bytes.windows(3.min(bytes.len())) {
            // FNV-1a over the trigram
            let mut h: u64 = 0xcbf29ce484222325;
            for &b in w {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            let idx = (h % self.dim as u64) as usize;
            let sign = if (h >> 63) & 1 == 1 { 1.0 } else { -1.0 };
            v[idx] += sign;
        }
        normalize(&mut v);
        v
    }
}

fn normalize(v: &mut [f32]) {
    let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 1e-9 {
        for x in v.iter_mut() {
            *x /= n;
        }
    } else {
        v[0] = 1.0;
    }
}

/// A stored document.
#[derive(Debug, Clone)]
pub struct Doc {
    pub id: u64,
    pub text: String,
    pub embedding: Vec<f32>,
}

/// A search hit.
#[derive(Debug, Clone)]
pub struct Hit {
    pub id: u64,
    pub score: f32,
    pub text: String,
}

/// Thread-safe cosine top-k index (exact, brute force — document counts in
/// the workflows are small; ANN would be over-engineering the substitute).
pub struct VectorStore {
    docs: RwLock<Vec<Doc>>,
    dim: usize,
}

impl VectorStore {
    pub fn new(dim: usize) -> Self {
        VectorStore { docs: RwLock::new(Vec::new()), dim }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Insert a document with a precomputed (unit-norm) embedding.
    pub fn add(&self, text: impl Into<String>, mut embedding: Vec<f32>) -> u64 {
        assert_eq!(embedding.len(), self.dim, "embedding dim mismatch");
        normalize(&mut embedding);
        let mut docs = self.docs.write().unwrap();
        let id = docs.len() as u64;
        docs.push(Doc { id, text: text.into(), embedding });
        id
    }

    /// Cosine top-k (dot product of unit vectors), highest first.
    pub fn query(&self, embedding: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(embedding.len(), self.dim, "query dim mismatch");
        let docs = self.docs.read().unwrap();
        let mut scored: Vec<(f32, usize)> = docs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let s: f32 = d
                    .embedding
                    .iter()
                    .zip(embedding.iter())
                    .map(|(a, b)| a * b)
                    .sum();
                (s, i)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        scored
            .into_iter()
            .take(k)
            .map(|(score, i)| Hit { id: docs[i].id, score, text: docs[i].text.clone() })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.docs.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_embedder_unit_norm_deterministic() {
        let e = HashEmbedder::new(64);
        let a = e.embed("oauth login flow");
        let b = e.embed("oauth login flow");
        assert_eq!(a, b);
        let n: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-5);
        assert!(!e.embed("").iter().any(|x| x.is_nan()));
    }

    #[test]
    fn similar_texts_score_higher() {
        let e = HashEmbedder::new(128);
        let store = VectorStore::new(128);
        for text in [
            "oauth2 token refresh documentation",
            "database connection pooling guide",
            "oauth login setup for web apps",
        ] {
            store.add(text, e.embed(text));
        }

        let hits = store.query(&e.embed("how to set up oauth login"), 2);
        assert_eq!(hits.len(), 2);
        assert!(
            hits[0].text.contains("oauth"),
            "top hit should be oauth-related, got `{}`",
            hits[0].text
        );
        assert!(hits[0].score >= hits[1].score);
    }

    #[test]
    fn topk_bounds() {
        let e = HashEmbedder::new(32);
        let store = VectorStore::new(32);
        store.add("a", e.embed("a"));
        assert_eq!(store.query(&e.embed("a"), 10).len(), 1);
        assert_eq!(store.len(), 1);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let store = VectorStore::new(8);
        store.add("x", vec![1.0; 16]);
    }

    #[test]
    fn concurrent_add_query() {
        let e = HashEmbedder::new(32);
        let store = std::sync::Arc::new(VectorStore::new(32));
        let mut handles = vec![];
        for t in 0..4 {
            let store = store.clone();
            handles.push(std::thread::spawn(move || {
                let e = HashEmbedder::new(32);
                for i in 0..50 {
                    store.add(format!("doc {t} {i}"), e.embed(&format!("doc {t} {i}")));
                    store.query(&e.embed("doc"), 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(store.len(), 200);
    }
}
