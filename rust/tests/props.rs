//! Property-based tests (testkit) on coordinator invariants:
//! routing, scheduling order, state management, JSON round-trips.

use std::sync::Arc;
use std::time::Duration;

use nalar::coordinator::{LoadMap, Router};
use nalar::futures::{FutureCell, FutureMeta};
use nalar::ids::*;
use nalar::nodestore::NodeStore;
use nalar::state::{migrate_session_state, ManagedList};
use nalar::testkit::{check, check_n};
use nalar::transport::Bus;
use nalar::util::json::{self, Value};
use nalar::util::rng::Rng;

fn rand_value(r: &mut Rng, depth: usize) -> Value {
    match r.below(if depth > 2 { 4 } else { 6 }) {
        0 => Value::Null,
        1 => Value::Bool(r.bool_with(0.5)),
        2 => Value::Num((r.next_u64() % 1_000_000) as f64 / 8.0),
        3 => Value::Str(
            (0..r.below(12)).map(|_| (b'a' + r.below(26) as u8) as char).collect(),
        ),
        4 => Value::Arr((0..r.below(4)).map(|_| rand_value(r, depth + 1)).collect()),
        _ => {
            let mut m = json::Map::new();
            for _ in 0..r.below(4) {
                let k: String =
                    (0..1 + r.below(6)).map(|_| (b'a' + r.below(26) as u8) as char).collect();
                m.insert(k, rand_value(r, depth + 1));
            }
            Value::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    check("json parse(to_string(v)) == v", |r, _s| rand_value(r, 0), |v| {
        json::parse(&v.to_string()).map(|w| w == *v).unwrap_or(false)
            && json::parse(&v.pretty()).map(|w| w == *v).unwrap_or(false)
    });
}

#[test]
fn prop_router_only_returns_live_instances() {
    check_n("router returns registered instance", 64, |r, s| {
        let n = 1 + (s.0 % 6) as u32;
        let kill = r.below(n as u64) as u32;
        let sessions: Vec<u64> = (0..8).map(|_| r.below(32)).collect();
        (n, kill, sessions)
    }, |(n, kill, sessions)| {
        let bus = Bus::new(Duration::ZERO);
        let loads = LoadMap::new();
        let mut rxs = Vec::new();
        for i in 0..*n {
            let id = InstanceId::new("a", i);
            rxs.push(bus.register(id.clone(), NodeId(i % 2)));
            loads.register(id);
        }
        let router = Router::new(bus.clone(), loads, 5);
        if *n > 1 {
            bus.deregister(&InstanceId::new("a", *kill));
        }
        sessions.iter().all(|s| match router.route(SessionId(*s), "a", s % 2 == 0) {
            Ok(inst) => bus.is_registered(&inst),
            Err(_) => *n == 1, // only legal if we killed the single instance
        })
    });
}

#[test]
fn prop_sticky_sessions_stable_under_load_changes() {
    check_n("sticky pin survives arbitrary load", 48, |r, _| {
        let loads: Vec<(u32, usize)> = (0..4).map(|i| (i, r.below(100) as usize)).collect();
        let session = r.below(1000);
        (loads, session)
    }, |(load_vec, session)| {
        let bus = Bus::new(Duration::ZERO);
        let loads = LoadMap::new();
        let mut rxs = Vec::new();
        for i in 0..4u32 {
            let id = InstanceId::new("a", i);
            rxs.push(bus.register(id.clone(), NodeId(0)));
            loads.register(id);
        }
        let router = Router::new(bus, loads.clone(), 5);
        let first = router.route(SessionId(*session), "a", true).unwrap();
        for (i, l) in load_vec {
            loads
                .get(&InstanceId::new("a", *i))
                .unwrap()
                .queued
                .store(*l, std::sync::atomic::Ordering::Relaxed);
        }
        router.route(SessionId(*session), "a", true).unwrap() == first
    });
}

#[test]
fn prop_future_value_immutable_after_first_resolution() {
    check_n("first resolve wins", 64, |r, _| {
        (r.below(1000), r.below(1000), r.bool_with(0.5))
    }, |(a, b, fail_second)| {
        let cell = FutureCell::new(FutureMeta::new(
            FutureId(1),
            SessionId(0),
            RequestId(0),
            AgentType::new("a"),
            "m",
            Location::Global,
        ));
        cell.resolve(Value::Num(*a as f64), 0);
        if *fail_second {
            cell.fail("late");
        } else {
            // second resolve is a programming error upstream; in release
            // builds it must be ignored (debug builds assert).
            if !cfg!(debug_assertions) {
                cell.resolve(Value::Num(*b as f64), 0);
            }
        }
        cell.try_value().unwrap().unwrap().as_u64() == Some(*a)
    });
}

#[test]
fn prop_managed_list_migration_preserves_content() {
    check_n("state migration is content-preserving", 48, |r, s| {
        let items: Vec<u64> = (0..s.0 % 20).map(|_| r.next_u64() % 1000).collect();
        let session = r.below(64);
        (items, session)
    }, |(items, session)| {
        let src = Arc::new(NodeStore::new());
        let dst = Arc::new(NodeStore::new());
        let l = ManagedList::bind(src.clone(), SessionId(*session), "xs");
        for x in items {
            l.push(Value::Num(*x as f64));
        }
        migrate_session_state(&src, &dst, SessionId(*session));
        let l2 = ManagedList::bind(dst, SessionId(*session), "xs");
        let got: Vec<u64> = l2.snapshot().iter().filter_map(|v| v.as_u64()).collect();
        got == *items
    });
}

#[test]
fn prop_rng_zipf_and_below_in_range() {
    check_n(
        "samplers stay in range",
        64,
        |r, _| (r.next_u64(), 1 + r.below(40) as usize),
        |(seed, n)| {
            let mut r = Rng::new(*seed);
            (0..50).all(|_| r.zipf(*n, 1.2) < *n && (r.below(*n as u64) as usize) < *n)
        },
    );
}

// ------------------------------------------------- admission controllers
//
// The front door's accept/shed decisions, property-checked against the
// deterministic `admit_at` entry point (a virtual "now" instead of the
// wall clock, so refill is a pure function of the generated timestamps).

use nalar::ingress::{AdmissionController, AdmissionPolicy};

#[test]
fn prop_token_bucket_never_admits_above_rate_times_window() {
    check_n(
        "token bucket: admitted <= burst + rate x window",
        64,
        |r, s| {
            let rate = 0.5 + (r.below(400) as f64) / 10.0; // 0.5..40.5 rps
            let burst = 1.0 + r.below(8) as f64;
            let window_ms = 20 + r.below(1500);
            // arrival offsets inside the window, sorted (time moves forward)
            let mut offsets: Vec<u64> =
                (0..(4 + s.0 * 4)).map(|_| r.below(window_ms)).collect();
            offsets.sort_unstable();
            (rate, burst, window_ms, offsets)
        },
        |(rate, burst, window_ms, offsets)| {
            let c = AdmissionController::new(AdmissionPolicy::TokenBucket {
                rate: *rate,
                burst: *burst,
            });
            let base = std::time::Instant::now();
            let admitted = offsets
                .iter()
                .filter(|ms| {
                    c.admit_at(0, base + Duration::from_millis(**ms)).is_ok()
                })
                .count() as f64;
            let window_s = *window_ms as f64 / 1000.0;
            admitted <= (*burst + *rate * window_s).floor() + 1.0
        },
    );
}

#[test]
fn prop_bounded_queue_never_exceeds_cap_under_interleaved_submit_drain() {
    check_n(
        "bounded queue: depth <= cap under any submit/drain interleaving",
        64,
        |r, s| {
            let cap = 1 + r.below(16) as usize;
            let ops: Vec<bool> = (0..(8 + s.0 * 8)).map(|_| r.bool_with(0.6)).collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let c = AdmissionController::new(AdmissionPolicy::Bounded { cap: *cap });
            let mut depth = 0usize;
            for submit in ops {
                if *submit {
                    // the scheduler admits against the live depth; an Ok
                    // verdict enqueues
                    if c.admit(depth).is_ok() {
                        depth += 1;
                    }
                } else {
                    depth = depth.saturating_sub(1); // a worker drained one
                }
                if depth > *cap {
                    return false;
                }
            }
            true
        },
    );
}

// ------------------------------------------------- tenant fairness (DRR)
//
// The front door's weighted-fair discipline, property-checked on the
// pure `Drr` core (no threads, no clocks): bounded unfairness between
// continuously-backlogged tenants, and per-tenant token-bucket isolation
// through the same deterministic `admit_at` entry point as above.

use nalar::config::TenantSettings;
use nalar::ingress::{AdmissionPolicy as AP, Drr};

#[test]
fn prop_drr_unfairness_is_bounded_by_one_max_quantum() {
    check_n(
        "drr: weight-normalised service gap <= one max quantum",
        64,
        |r, s| {
            let n = 2 + r.below(3) as usize; // 2..4 tenants
            let weights: Vec<f64> = (0..n).map(|_| 1.0 + r.below(4) as f64).collect();
            let pops = 8 + s.0 * 6;
            (weights, pops)
        },
        |(weights, pops)| {
            let mut drr = Drr::new(weights);
            let n = weights.len();
            // continuously backlogged: every sub-queue always has work
            let backlog = vec![1_000_000usize; n];
            let mut served = vec![0u64; n];
            for _ in 0..*pops {
                let t = match drr.next(&backlog) {
                    Some(t) if t < n => t,
                    _ => return false, // must serve, and in range
                };
                served[t] += 1;
            }
            if served.iter().sum::<u64>() != *pops as u64 {
                return false; // work-conserving: every pop served someone
            }
            let wmin = weights.iter().cloned().fold(f64::INFINITY, f64::min);
            let wmax = weights.iter().cloned().fold(0.0f64, f64::max);
            let max_quantum = wmax / wmin;
            // bounded unfairness: between any two continuously-backlogged
            // tenants, normalised service never diverges by more than one
            // max quantum
            for i in 0..n {
                for j in 0..n {
                    let gap = (served[i] as f64 / weights[i]
                        - served[j] as f64 / weights[j])
                        .abs();
                    if gap > max_quantum + 1e-9 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_drr_never_serves_an_empty_sub_queue() {
    check_n(
        "drr: picks are backlogged, None only when all empty",
        64,
        |r, s| {
            let n = 1 + r.below(4) as usize;
            let weights: Vec<f64> = (0..n).map(|_| 1.0 + r.below(3) as f64).collect();
            // a schedule of backlog snapshots, some entirely empty
            let snapshots: Vec<Vec<usize>> = (0..(4 + s.0))
                .map(|_| (0..n).map(|_| r.below(3) as usize).collect())
                .collect();
            (weights, snapshots)
        },
        |(weights, snapshots)| {
            let mut drr = Drr::new(weights);
            snapshots.iter().all(|backlog| match drr.next(backlog) {
                Some(t) => backlog[t] > 0,
                None => backlog.iter().all(|&b| b == 0),
            })
        },
    );
}

#[test]
fn prop_per_tenant_buckets_bound_and_isolate_admission() {
    check_n(
        "tenant buckets: admitted <= burst + rate x window, hog cannot drain meek",
        48,
        |r, s| {
            let rate = 0.5 + (r.below(300) as f64) / 10.0; // 0.5..30.5 rps
            let burst = 1.0 + r.below(6) as f64;
            let window_ms = 20 + r.below(1200);
            // the hog offers ~10x the meek tenant's arrivals, interleaved
            let mut hog: Vec<u64> = (0..(10 + s.0 * 10)).map(|_| r.below(window_ms)).collect();
            let mut meek: Vec<u64> = (0..(1 + s.0)).map(|_| r.below(window_ms)).collect();
            hog.sort_unstable();
            meek.sort_unstable();
            (rate, burst, window_ms, hog, meek)
        },
        |(rate, burst, window_ms, hog, meek)| {
            let bucket = |tenant_rate: f64| {
                AdmissionController::new(AP::for_tenant(&TenantSettings {
                    name: "t".into(),
                    weight: 1.0,
                    token_rate: tenant_rate,
                    token_burst: *burst,
                }))
            };
            // `base` sits far past every bucket's creation instant, so
            // the first refill saturates at `burst` for every bucket and
            // later refills are pure functions of the generated offsets —
            // the interleaved and solo runs see byte-identical bucket
            // state, with no creation-time jitter.
            let base = std::time::Instant::now() + Duration::from_secs(3600);
            let run = |c: &AdmissionController, offsets: &[u64]| {
                offsets
                    .iter()
                    .filter(|ms| c.admit_at(0, base + Duration::from_millis(**ms)).is_ok())
                    .count() as f64
            };
            // interleaved: each tenant against its own bucket
            let hog_bucket = bucket(*rate);
            let meek_bucket = bucket(*rate);
            let mut merged: Vec<(u64, bool)> = hog
                .iter()
                .map(|ms| (*ms, true))
                .chain(meek.iter().map(|ms| (*ms, false)))
                .collect();
            merged.sort_unstable();
            let (mut hog_ok, mut meek_ok) = (0f64, 0f64);
            for (ms, is_hog) in merged {
                let c = if is_hog { &hog_bucket } else { &meek_bucket };
                if c.admit_at(0, base + Duration::from_millis(ms)).is_ok() {
                    if is_hog {
                        hog_ok += 1.0;
                    } else {
                        meek_ok += 1.0;
                    }
                }
            }
            // per-tenant rate bound, hog flood or not
            let window_s = *window_ms as f64 / 1000.0;
            let cap = (*burst + *rate * window_s).floor() + 1.0;
            if hog_ok > cap || meek_ok > cap {
                return false;
            }
            // isolation: the meek tenant admits exactly what it would
            // admit with the hog absent (separate buckets share nothing)
            let solo = run(&bucket(*rate), meek);
            if meek_ok != solo {
                return false;
            }
            // a rate-less tenant is never shed by the tenant layer
            let open = bucket(0.0);
            run(&open, hog) as usize == hog.len()
        },
    );
}

#[test]
fn prop_shed_decisions_are_monotone_in_queue_depth() {
    check_n(
        "bounded shed: shedding at depth d implies shedding at every d' >= d",
        64,
        |r, _| (1 + r.below(32) as usize, 2 + r.below(48) as usize),
        |(cap, probe_max)| {
            let c = AdmissionController::new(AdmissionPolicy::Bounded { cap: *cap });
            let verdicts: Vec<bool> =
                (0..*probe_max).map(|d| c.admit(d).is_ok()).collect();
            // monotone: once a depth sheds, every deeper depth sheds too
            // (an accept-prefix followed by a shed-suffix, split at cap)
            let first_shed = verdicts.iter().position(|ok| !ok);
            match first_shed {
                None => *probe_max <= *cap,
                Some(at) => at == (*cap).min(*probe_max) && !verdicts[at..].iter().any(|ok| *ok),
            }
        },
    );
}
