//! Baseline serving modes (paper §2.3, §6.1).
//!
//! The paper compares NALAR against three systems. We reproduce their
//! *serving-relevant* behaviours as deployment configurations of the same
//! runtime — the standard emulation approach for closed systems, and the
//! only fair one here since all systems share the substrate:
//!
//! * **Ayo-like** (static-graph end-to-end framework): parallel execution
//!   and pipelining work (the runtime gives those for free), but the graph
//!   is fixed at submission — no migration, no priority changes, no
//!   reallocation; sessions stay where first placed (sticky KV).
//! * **CrewAI-like** (specification-only library): whole-workflow
//!   replication — a session hashes to one replica for *all* its agents;
//!   no resource management at all.
//! * **AutoGen-like** (event-driven messaging): best-effort FCFS dispatch
//!   round-robin across instances, no global coordination, sticky sessions
//!   (its async messaging engine exposes no policy control, §6.2).
//!
//! None of the baselines isolates tenants at its front door either, so
//! `apply` also clears `ingress.tenants` — every baseline runs the
//! implicit single-tenant queue (submitted tenant names collapse onto
//! it), keeping the §6 fairness comparison honest: NALAR-with-DRR is
//! measured against single-queue systems, not against a tenancy feature
//! quietly granted to everyone.
//!
//! NALAR mode = the paper's three default policies + migration enabled.

use crate::config::DeploymentConfig;
use crate::coordinator::router::FallbackMode;

/// Which system a deployment emulates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemUnderTest {
    Nalar,
    AyoLike,
    CrewLike,
    AutoGenLike,
}

impl SystemUnderTest {
    pub fn all() -> [SystemUnderTest; 4] {
        [
            SystemUnderTest::Nalar,
            SystemUnderTest::AyoLike,
            SystemUnderTest::CrewLike,
            SystemUnderTest::AutoGenLike,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            SystemUnderTest::Nalar => "NALAR",
            SystemUnderTest::AyoLike => "Ayo-like",
            SystemUnderTest::CrewLike => "CrewAI-like",
            SystemUnderTest::AutoGenLike => "AutoGen-like",
        }
    }

    /// Mutate a deployment config to emulate this system.
    pub fn apply(&self, cfg: &mut DeploymentConfig) {
        match self {
            SystemUnderTest::Nalar => {
                if cfg.policies.is_empty() {
                    cfg.policies = vec![
                        "load_balance".into(),
                        "hol_migration".into(),
                        "resource_realloc".into(),
                    ];
                }
                cfg.control.enable_migration = true;
                cfg.engine.kv_policy = "hint".into();
            }
            SystemUnderTest::AyoLike => {
                cfg.policies.clear();
                cfg.control.enable_migration = false;
                cfg.engine.kv_policy = "lru".into();
                cfg.ingress.policy = "unbounded".into();
                cfg.ingress.schedule = "fifo".into();
                cfg.ingress.tenants.clear();
            }
            SystemUnderTest::CrewLike => {
                cfg.policies.clear();
                cfg.control.enable_migration = false;
                cfg.engine.kv_policy = "lru".into();
                cfg.ingress.policy = "unbounded".into();
                cfg.ingress.schedule = "fifo".into();
                cfg.ingress.tenants.clear();
            }
            SystemUnderTest::AutoGenLike => {
                cfg.policies.clear();
                cfg.control.enable_migration = false;
                cfg.engine.kv_policy = "lru".into();
                cfg.ingress.policy = "unbounded".into();
                cfg.ingress.schedule = "fifo".into();
                cfg.ingress.tenants.clear();
            }
        }
    }

    /// Router behaviour for this system (applied by the deployment).
    pub fn router_mode(&self) -> (bool, FallbackMode) {
        match self {
            SystemUnderTest::Nalar => (false, FallbackMode::LeastLoaded),
            // Ayo binds placement when the (static) graph is instantiated.
            SystemUnderTest::AyoLike => (true, FallbackMode::LeastLoaded),
            // CrewAI replicates the whole workflow; a session lives on one
            // replica for everything.
            SystemUnderTest::CrewLike => (true, FallbackMode::HashSession),
            // AutoGen dispatches as messages arrive, no load awareness.
            SystemUnderTest::AutoGenLike => (true, FallbackMode::RoundRobin),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> DeploymentConfig {
        DeploymentConfig::from_json(r#"{"agents": [{"name": "a", "kind": "llm"}]}"#).unwrap()
    }

    #[test]
    fn nalar_gets_default_policies() {
        let mut cfg = base_cfg();
        SystemUnderTest::Nalar.apply(&mut cfg);
        assert_eq!(cfg.policies.len(), 3);
        assert!(cfg.control.enable_migration);
        assert_eq!(cfg.engine.kv_policy, "hint");
        assert_eq!(cfg.ingress.policy, "bounded", "NALAR keeps admission control");
    }

    #[test]
    fn baselines_lose_control() {
        let baselines =
            [SystemUnderTest::AyoLike, SystemUnderTest::CrewLike, SystemUnderTest::AutoGenLike];
        for s in baselines {
            let mut cfg = base_cfg();
            cfg.policies = vec!["load_balance".into()];
            cfg.ingress.tenants = vec![crate::config::TenantSettings::default()];
            s.apply(&mut cfg);
            assert!(cfg.policies.is_empty(), "{}", s.name());
            assert!(!cfg.control.enable_migration);
            assert_eq!(cfg.ingress.policy, "unbounded", "{} has no admission control", s.name());
            assert_eq!(cfg.ingress.schedule, "fifo", "{} has no front-door SRTF", s.name());
            assert!(
                cfg.ingress.tenants.is_empty(),
                "{} must run the single-tenant front door",
                s.name()
            );
            let (sticky, _) = s.router_mode();
            assert!(sticky, "{} must be session-sticky", s.name());
        }
    }

    #[test]
    fn nalar_keeps_its_tenants() {
        let mut cfg = base_cfg();
        cfg.ingress.tenants = vec![crate::config::TenantSettings::default()];
        SystemUnderTest::Nalar.apply(&mut cfg);
        assert_eq!(cfg.ingress.tenants.len(), 1, "tenancy is a NALAR capability");
    }

    #[test]
    fn names_distinct() {
        let names: std::collections::HashSet<_> =
            SystemUnderTest::all().iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), 4);
    }
}
