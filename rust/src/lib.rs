//! # NALAR — a serving framework for agent workflows
//!
//! Reproduction of "NALAR: A Serving Framework for Agent Workflows"
//! (Laju et al., CS.DC 2026) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a futures-centric
//!   coordinator with a two-level control plane (periodic global controller
//!   + event-driven component controllers), a managed state layer, and a
//!   policy interface (`route` / `set_priority` / `migrate` / `kill` /
//!   `provision`).
//! * **Layer 2** — a JAX transformer LM (`python/compile/model.py`) lowered
//!   AOT to HLO text in `artifacts/`, loaded and executed from Rust through
//!   PJRT ([`runtime`]).
//! * **Layer 1** — Pallas attention kernels (`python/compile/kernels/`),
//!   validated against a pure-jnp oracle and lowered (interpret mode) into
//!   the same HLO.
//!
//! Python never runs on the request path: `make artifacts` is build-time
//! only, and the `nalar` binary is self-contained afterwards.
//!
//! The build environment is fully offline (zero external dependencies),
//! so the ecosystem crates a serving stack normally leans on are
//! implemented from scratch in [`util`], [`testkit`], [`nodestore`],
//! [`transport`] and [`runtime::xla`] — see DESIGN.md §3 for the
//! substitution table. `nalar bench` ([`bench`]) reproduces the paper's
//! Fig-9 / Fig-10 / Table-4 / §6.2 numbers headlessly and writes
//! `BENCH_*.json` reports at the repo root.
//!
//! ## Crate map
//!
//! | module | paper section | role |
//! |---|---|---|
//! | [`agents`] | §3.1 | agent specs, stub registry, instance event loops |
//! | [`futures`] | §3.2, §4.3.1 | futures with mutable metadata, dep graph |
//! | [`state`] | §3.3, §4.3.2 | managed lists/dicts, tiered KV cache |
//! | [`coordinator`] | §4 | two-level control plane + policy interface |
//! | [`nodestore`] | §4.1 | telemetry/decision broker (Redis substitute) |
//! | [`transport`] | impl | in-proc bus (gRPC substitute) |
//! | [`engine`] | impl | continuous-batching LLM engine (vLLM substitute) |
//! | [`runtime`] | impl | PJRT loader/executor for the AOT artifacts |
//! | [`vectorstore`] | impl | cosine top-k index (ChromaDB substitute) |
//! | [`ingress`] | §6 | open-loop front door: admission + event-driven scheduler |
//! | [`journal`] | §5 | durable request journal + crash recovery replay |
//! | [`trace`] | §5 | per-request span timelines + the bounded flight recorder |
//! | [`workflow`] | §6 | the three evaluation workflows as resumable drivers |
//! | [`workload`] | §6 | arrival processes + synthetic corpora |
//! | [`baselines`] | §6 | Ayo/CrewAI/AutoGen-like serving modes |

pub mod agents;
pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod futures;
pub mod ids;
pub mod ingress;
pub mod journal;
pub mod metrics;
pub mod nodestore;
pub mod runtime;
pub mod server;
pub mod state;
pub mod testkit;
pub mod trace;
pub mod transport;
pub mod util;
pub mod vectorstore;
pub mod workflow;
pub mod workload;

pub use error::{Error, Result};
