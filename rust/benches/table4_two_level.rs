//! Table 4 reproduction: one-level vs two-level control.
//!
//! Measures the time to schedule one token/future when (a) a single
//! centralized controller routes *every* future through one decision
//! queue versus (b) NALAR's two-level design, where component-level
//! controllers route independently and a new future's scheduling latency
//! is one local decision. Paper: one-level 1.2ms@1K -> 72.3ms@131K;
//! two-level flat 0.1-0.4ms.
//!
//! Thin wrapper over [`nalar::bench::table4`] — the same code path as
//! `nalar bench --only table4`; writes `BENCH_table4.json`.

use std::path::Path;

fn main() {
    let quick = std::env::var("NALAR_BENCH_QUICK").is_ok();
    let report = nalar::bench::table4(quick).expect("table4 reproduction failed");
    nalar::bench::validate(&report).expect("table4 report schema");
    let path = nalar::bench::write_report(Path::new("."), "table4", &report).expect("write report");
    println!("wrote {}", path.display());
}
