//! Sharded registry of live futures (per node).

use std::collections::HashMap;
use std::sync::Arc;

use std::sync::RwLock;

use crate::futures::{FutureCell, FutureState};
use crate::ids::{FutureId, RequestId};

const SHARDS: usize = 32;

/// Sharded `FutureId -> Arc<FutureCell>` map. The global controller scans
/// it (via telemetry snapshots, not directly) while component controllers
/// insert/resolve at event rate — sharding keeps those paths from
/// contending (§Perf: the Fig-10 loop reads while 128 agents write).
pub struct FutureTable {
    shards: Vec<RwLock<HashMap<FutureId, Arc<FutureCell>>>>,
}

impl Default for FutureTable {
    fn default() -> Self {
        Self::new()
    }
}

impl FutureTable {
    pub fn new() -> Self {
        FutureTable {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    fn shard(&self, id: FutureId) -> &RwLock<HashMap<FutureId, Arc<FutureCell>>> {
        &self.shards[(id.0 as usize) % SHARDS]
    }

    pub fn insert(&self, cell: Arc<FutureCell>) {
        self.shard(cell.id).write().unwrap().insert(cell.id, cell);
    }

    pub fn get(&self, id: FutureId) -> Option<Arc<FutureCell>> {
        self.shard(id).read().unwrap().get(&id).cloned()
    }

    pub fn remove(&self, id: FutureId) -> Option<Arc<FutureCell>> {
        self.shard(id).write().unwrap().remove(&id)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count by state (telemetry snapshot for the global controller).
    pub fn state_counts(&self) -> HashMap<FutureState, usize> {
        let mut out = HashMap::new();
        for shard in &self.shards {
            for cell in shard.read().unwrap().values() {
                *out.entry(cell.state()).or_insert(0) += 1;
            }
        }
        out
    }

    /// Visit all live futures (used by policy loops and GC).
    pub fn for_each(&self, mut f: impl FnMut(&Arc<FutureCell>)) {
        for shard in &self.shards {
            for cell in shard.read().unwrap().values() {
                f(cell);
            }
        }
    }

    /// Fail every non-terminal future belonging to `request` (request
    /// cancellation via `Ticket::cancel`, or deadline expiry of a started
    /// request): consumers observe the failure immediately instead of
    /// waiting out an answer nobody wants. Returns how many futures were
    /// failed. The cells are collected under the shard locks but failed
    /// outside them — `fail` fires wakers, and a waker is free to take
    /// unrelated locks (the ingress scheduler's, for one).
    ///
    /// Deliberately a full-table scan: cancels/expiries are orders of
    /// magnitude rarer than resolves, `gc_terminal` bounds the live set,
    /// and a by-request index would need an eviction hook the table does
    /// not have (requests finish without telling it) — see the ROADMAP
    /// item before reaching for one.
    pub fn fail_request(&self, request: RequestId, reason: &str) -> usize {
        let mut doomed: Vec<Arc<FutureCell>> = Vec::new();
        for shard in &self.shards {
            for cell in shard.read().unwrap().values() {
                if !matches!(cell.state(), FutureState::Ready | FutureState::Failed)
                    && cell.with_meta(|m| m.request) == request
                {
                    doomed.push(cell.clone());
                }
            }
        }
        for cell in &doomed {
            cell.fail(reason);
        }
        doomed.len()
    }

    /// Drop terminal futures older than keeping is useful; returns count
    /// removed. (The paper scales to 131K live futures; GC keeps bench
    /// memory bounded.)
    pub fn gc_terminal(&self) -> usize {
        let mut removed = 0;
        for shard in &self.shards {
            let mut m = shard.write().unwrap();
            let before = m.len();
            m.retain(|_, c| !matches!(c.state(), FutureState::Ready | FutureState::Failed));
            removed += before - m.len();
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::futures::FutureMeta;
    use crate::ids::*;

    fn cell(id: u64) -> Arc<FutureCell> {
        cell_for(id, 0)
    }

    fn cell_for(id: u64, request: u64) -> Arc<FutureCell> {
        FutureCell::new(FutureMeta::new(
            FutureId(id),
            SessionId(0),
            RequestId(request),
            AgentType::new("a"),
            "m",
            Location::Global,
        ))
    }

    #[test]
    fn insert_get_remove() {
        let t = FutureTable::new();
        t.insert(cell(1));
        t.insert(cell(2));
        assert_eq!(t.len(), 2);
        assert!(t.get(FutureId(1)).is_some());
        assert!(t.remove(FutureId(1)).is_some());
        assert!(t.get(FutureId(1)).is_none());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn state_counts_and_gc() {
        let t = FutureTable::new();
        for i in 0..10 {
            let c = cell(i);
            if i < 4 {
                c.resolve(crate::json!(i), 0);
            }
            t.insert(c);
        }
        let counts = t.state_counts();
        assert_eq!(counts[&FutureState::Ready], 4);
        assert_eq!(counts[&FutureState::Created], 6);
        assert_eq!(t.gc_terminal(), 4);
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn fail_request_only_touches_the_request_and_spares_terminals() {
        let t = FutureTable::new();
        t.insert(cell_for(1, 7)); // doomed
        t.insert(cell_for(2, 7)); // doomed
        let done = cell_for(3, 7); // already terminal: untouched
        done.resolve(crate::json!("ok"), 0);
        t.insert(done.clone());
        t.insert(cell_for(4, 8)); // other request: untouched
        assert_eq!(t.fail_request(RequestId(7), "request cancelled"), 2);
        assert!(t.get(FutureId(1)).unwrap().try_value().unwrap().is_err());
        assert!(t.get(FutureId(2)).unwrap().try_value().unwrap().is_err());
        assert!(done.try_value().unwrap().is_ok(), "resolved value is immutable");
        assert_eq!(t.get(FutureId(4)).unwrap().state(), FutureState::Created);
        assert_eq!(t.fail_request(RequestId(7), "again"), 0, "idempotent");
    }

    #[test]
    fn for_each_visits_all() {
        let t = FutureTable::new();
        for i in 0..100 {
            t.insert(cell(i));
        }
        let mut n = 0;
        t.for_each(|_| n += 1);
        assert_eq!(n, 100);
    }
}
