//! The component-level controller (paper §4.1).
//!
//! One per agent instance, running the instance's thread. Three roles
//! (paper): (1) local scheduling under installed policy, plus future
//! metadata upkeep and readiness propagation; (2) the interface between
//! stubs and the runtime — every stub call lands in this inbox; (3)
//! serving-time telemetry into the node store.
//!
//! The controller is *event-driven*: it reacts to arriving calls,
//! engine-step completions and migration commands immediately; periodic
//! decision-making lives in the global controller.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::agents::Backend;
use crate::config::Directives;
use crate::coordinator::router::{InstanceLoad, LoadMap, Router};
use crate::coordinator::InstanceMetrics;
use crate::engine::EngineReq;
use crate::futures::{DepGraph, FutureState};
use crate::ids::{InstanceId, NodeId, SessionId};
use crate::ingress::routing::SharedRoute;
use crate::json;
use crate::nodestore::{keys, NodeStore, StoreDirectory, Subscription};
use crate::state::kvcache::KvCacheManager;
use crate::trace::{SharedSink, TraceKind};
use crate::transport::{Bus, CallMsg, Message, MigratePayload};

/// Queue ordering installed by the global controller (`policy/{instance}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LocalOrder {
    /// First come, first served (baseline; LangGraph-style).
    #[default]
    Fcfs,
    /// Highest priority first, FIFO within a priority (enables
    /// `set_priority`-based policies: SRTF, LPT, per-session boosts).
    Priority,
}

/// Handle returned by `ComponentController::spawn`.
pub struct InstanceHandle {
    pub id: InstanceId,
    pub node: NodeId,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl InstanceHandle {
    /// Request stop and wait for the thread (used by `kill` / shutdown).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for InstanceHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// See module docs.
pub struct ComponentController {
    pub id: InstanceId,
    pub node: NodeId,
    backend: Backend,
    directives: Directives,
    inbox: mpsc::Receiver<Message>,
    bus: Bus,
    store: Arc<NodeStore>,
    stores: StoreDirectory,
    router: Arc<Router>,
    load: Arc<InstanceLoad>,
    graph: Arc<DepGraph>,
    queue: VecDeque<CallMsg>,
    /// tag -> in-flight call (engine backends).
    inflight: std::collections::HashMap<u64, CallMsg>,
    next_tag: u64,
    order: LocalOrder,
    policy_sub: Subscription,
    stop: Arc<AtomicBool>,
    /// Flight-recorder handle (late-bound: the ingress scheduler installs
    /// the recorder after instances spawn; see `server::Deployment`).
    /// Engine dispatch/complete events overlay executor service onto the
    /// per-request timelines the scheduler writes.
    trace: SharedSink,
    /// Routing slot (late-bound like `trace`): when the front door installs
    /// a router, engine admits re-check the stamped variant against the
    /// *current* quality floor — the local-enforcement half of the
    /// two-level routing policy (DESIGN.md §13).
    route: SharedRoute,
    // telemetry
    completed: u64,
    failed: u64,
    migrated_in: u64,
    migrated_out: u64,
    busy_ewma: f64,
    last_telemetry: Instant,
}

impl ComponentController {
    /// Launch the instance: registers on the bus and load map, subscribes
    /// to its policy key, and spawns the event loop thread.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        id: InstanceId,
        node: NodeId,
        backend: Backend,
        directives: Directives,
        bus: Bus,
        stores: StoreDirectory,
        router: Arc<Router>,
        loads: &LoadMap,
        graph: Arc<DepGraph>,
        trace: SharedSink,
        route: SharedRoute,
    ) -> InstanceHandle {
        let inbox = bus.register(id.clone(), node);
        let load = loads.register(id.clone());
        let store = stores.node(node);
        let policy_sub = store.subscribe(&keys::policy(&id));
        let stop = Arc::new(AtomicBool::new(false));
        let ctl = ComponentController {
            id: id.clone(),
            node,
            backend,
            directives,
            inbox,
            bus,
            store,
            stores,
            router,
            load,
            graph,
            queue: VecDeque::new(),
            inflight: std::collections::HashMap::new(),
            next_tag: 1,
            order: LocalOrder::Fcfs,
            policy_sub,
            stop: stop.clone(),
            trace,
            route,
            completed: 0,
            failed: 0,
            migrated_in: 0,
            migrated_out: 0,
            busy_ewma: 0.0,
            last_telemetry: Instant::now(),
        };
        let join = std::thread::Builder::new()
            .name(format!("nalar-{id}"))
            .spawn(move || ctl.run())
            .expect("spawn component controller");
        InstanceHandle { id, node, stop, join: Some(join) }
    }

    fn run(mut self) {
        while !self.stop.load(Ordering::Relaxed) {
            let worked = self.drain_inbox();
            self.apply_policy_updates();

            let stepped = match &mut self.backend {
                Backend::Engine(_) => self.engine_turn(),
                Backend::Tool(_) => self.tool_turn(),
            };

            self.maybe_push_telemetry();

            if !worked && !stepped {
                // idle: block briefly on the inbox
                match self.inbox.recv_timeout(Duration::from_millis(2)) {
                    Ok(msg) => self.handle(msg),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        // Deregister, then fail whatever is left (paper §5: report, don't mask).
        self.bus.deregister(&self.id);
        for msg in self.queue.drain(..) {
            msg.cell.fail(format!("instance {} stopped", self.id));
        }
        for (_, msg) in self.inflight.drain() {
            msg.cell.fail(format!("instance {} stopped", self.id));
        }
        self.push_telemetry();
    }

    // ------------------------------------------------------------ inbox
    fn drain_inbox(&mut self) -> bool {
        let mut any = false;
        while let Ok(msg) = self.inbox.try_recv() {
            any = true;
            self.handle(msg);
        }
        any
    }

    fn handle(&mut self, msg: Message) {
        match msg {
            Message::Call(call) => {
                call.cell.mark_queued(self.id.clone());
                self.load.queued.fetch_add(1, Ordering::Relaxed);
                self.queue.push_back(call);
            }
            Message::MigrateOut { session, to } => self.migrate_out(session, to),
            Message::MigrateIn(payload) => self.migrate_in(payload),
            Message::Shutdown => {
                self.stop.store(true, Ordering::Relaxed);
            }
        }
    }

    fn apply_policy_updates(&mut self) {
        for (_k, v) in self.policy_sub.drain() {
            if let Ok(order) = v.downcast::<LocalOrder>() {
                self.order = *order;
            }
        }
    }

    // ------------------------------------------------------- scheduling
    /// Pop the next runnable call per the installed order. Preserves
    /// per-session arrival order (stateful guarantee, §3.4): a session's
    /// call is only eligible if it is that session's oldest queued call.
    fn pop_next(&mut self) -> Option<CallMsg> {
        if self.queue.is_empty() {
            return None;
        }
        let idx = match self.order {
            LocalOrder::Fcfs => 0,
            LocalOrder::Priority => {
                let mut best = 0usize;
                let mut best_prio = i32::MIN;
                let mut seen_sessions = std::collections::HashSet::new();
                for (i, m) in self.queue.iter().enumerate() {
                    let session = m.cell.session();
                    if !seen_sessions.insert(session) {
                        continue; // an earlier call of this session exists
                    }
                    let p = m.cell.priority();
                    if p > best_prio {
                        best_prio = p;
                        best = i;
                    }
                }
                best
            }
        };
        let msg = self.queue.remove(idx)?;
        self.load.queued.fetch_sub(1, Ordering::Relaxed);
        Some(msg)
    }

    // ---------------------------------------------------------- engine
    fn engine_turn(&mut self) -> bool {
        let Backend::Engine(core) = &mut self.backend else { return false };
        // admit up to batch capacity (batchable) or one at a time
        let cap = if self.directives.batchable { core.max_batch() } else { 1 };
        while core.active() < cap {
            let Some(msg) = ({
                // inline pop_next to appease the borrow checker
                if self.queue.is_empty() {
                    None
                } else {
                    let idx = match self.order {
                        LocalOrder::Fcfs => 0,
                        LocalOrder::Priority => {
                            let mut best = 0usize;
                            let mut best_prio = i32::MIN;
                            let mut seen = std::collections::HashSet::new();
                            for (i, m) in self.queue.iter().enumerate() {
                                if !seen.insert(m.cell.session()) {
                                    continue;
                                }
                                let p = m.cell.priority();
                                if p > best_prio {
                                    best_prio = p;
                                    best = i;
                                }
                            }
                            best
                        }
                    };
                    let m = self.queue.remove(idx);
                    if m.is_some() {
                        self.load.queued.fetch_sub(1, Ordering::Relaxed);
                    }
                    m
                }
            }) else {
                break;
            };
            msg.cell.mark_running();
            self.load.active.fetch_add(1, Ordering::Relaxed);
            let tag = self.next_tag;
            self.next_tag += 1;
            let meta = msg.cell.meta();
            // Dispatch/complete pairs carry the *future id* as detail —
            // globally unique, so concurrent calls of one request on
            // different instances still pair up in `stage_durations`.
            self.trace.record(meta.request, TraceKind::EngineDispatch, msg.cell.id.0);
            // Local routing enforcement: the front door stamped its variant
            // choice into the call args; re-check it against the current
            // quality floor (the global controller may have moved it since)
            // and resolve the variant's service-time multiplier.
            let (variant, latency_mult) = match self.route.get() {
                Some(rs) => match msg.args.get("variant").as_str() {
                    Some(name) if !name.is_empty() => {
                        let urgent = msg.args.get("urgent").as_bool().unwrap_or(false);
                        let idx = rs.enforce(name, urgent);
                        (
                            Some(rs.variant_name(idx).to_string()),
                            rs.variants()[idx].latency_mult,
                        )
                    }
                    _ => (None, 1.0),
                },
                None => (None, 1.0),
            };
            core.admit(EngineReq {
                tag,
                session: meta.session,
                prompt: msg.args.get("prompt").as_str().unwrap_or_default().to_string(),
                history_tokens: msg.args.get("history_tokens").as_usize().unwrap_or(0),
                max_new_tokens: msg.args.get("max_new_tokens").as_usize().unwrap_or(64),
                variant,
                latency_mult,
            });
            self.inflight.insert(tag, msg);
        }

        if core.active() == 0 {
            return false;
        }
        let t0 = Instant::now();
        let done = core.step();
        let busy = t0.elapsed().as_secs_f64();
        self.busy_ewma = 0.95 * self.busy_ewma + 0.05 * busy.min(1.0) * 20.0; // ~per-50ms window
        self.busy_ewma = self.busy_ewma.min(1.0);

        for d in done {
            let Some(msg) = self.inflight.remove(&d.tag) else { continue };
            self.load.active.fetch_sub(1, Ordering::Relaxed);
            // Recorded before resolve: resolution fires the ingress waker
            // inline on this thread, and the completion must precede the
            // Resumed event it causes on the request's timeline.
            self.trace.record(msg.cell.meta().request, TraceKind::EngineComplete, msg.cell.id.0);
            match d.result {
                Ok(out) => {
                    self.completed += 1;
                    self.graph.on_resolve(msg.cell.id);
                    msg.cell.resolve(
                        json!({
                            "text": out.text,
                            "prompt_tokens": out.prompt_tokens,
                            "generated_tokens": out.generated_tokens,
                            "kv": out.kv_outcome,
                        }),
                        (busy * 1e6) as u64,
                    );
                }
                Err(e) => {
                    self.failed += 1;
                    msg.cell.fail(e.to_string());
                }
            }
        }
        true
    }

    // ------------------------------------------------------------ tools
    fn tool_turn(&mut self) -> bool {
        let Some(msg) = self.pop_next() else { return false };
        msg.cell.mark_running();
        self.load.active.fetch_add(1, Ordering::Relaxed);
        let meta = msg.cell.meta();
        self.trace.record(meta.request, TraceKind::EngineDispatch, msg.cell.id.0);
        let t0 = Instant::now();
        let Backend::Tool(tool) = &mut self.backend else { unreachable!() };
        let result = tool.execute(&meta.method, &msg.args);
        let service = t0.elapsed();
        self.busy_ewma = 0.9 * self.busy_ewma + 0.1 * (service.as_secs_f64() * 20.0).min(1.0);
        self.load.active.fetch_sub(1, Ordering::Relaxed);
        self.trace.record(meta.request, TraceKind::EngineComplete, msg.cell.id.0);
        match result {
            Ok(v) => {
                self.completed += 1;
                self.graph.on_resolve(msg.cell.id);
                msg.cell.resolve(v, service.as_micros() as u64);
            }
            Err(e) => {
                self.failed += 1;
                msg.cell.fail(e.to_string());
            }
        }
        true
    }

    // -------------------------------------------------------- migration
    /// Fig. 8 source side: extract queued (never running) work + state for
    /// `session`, repoint metadata, transfer to `to`.
    fn migrate_out(&mut self, session: SessionId, to: InstanceId) {
        if self.directives.stateful {
            return; // strict stateful agents never migrate (§5)
        }
        if to == self.id || !self.bus.is_registered(&to) {
            return;
        }
        // steps 2-3: collect queued calls of the session; running work stays.
        let mut calls = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].cell.session() == session {
                let msg = self.queue.remove(i).unwrap();
                self.load.queued.fetch_sub(1, Ordering::Relaxed);
                msg.cell.set_executor(to.clone()); // mutable metadata (Property 1)
                calls.push(msg);
            } else {
                i += 1;
            }
        }
        // engine-side KV moves with the session
        let kv_bytes = match &mut self.backend {
            Backend::Engine(core) => {
                let moved = core.kv_manager().migrate_out(session);
                core.evict_session(session);
                moved.map(|(b, _, _)| b).unwrap_or(0)
            }
            Backend::Tool(_) => 0,
        };
        // step 5: managed state moves between node stores. The session's
        // state is not necessarily on *this* instance's node (its home is
        // `session % nodes`, and prior migrations may have moved it), so
        // the directory resolves the current source and records the new
        // location for future binds.
        let state = {
            let target_node = self.bus.node_of(&to).unwrap_or(self.node);
            self.stores.migrate_session(session, target_node);
            Vec::new() // state moved store-to-store; payload carries size only
        };
        // step 4: creator learns the executor changed -> future routes repin
        self.router.repin_session(session, self.id.agent.as_str(), to.clone());
        self.migrated_out += 1;
        let n = calls.len();
        let payload = MigratePayload { session, calls, state, kv_bytes };
        if !self.bus.send_from(Some(self.node), &to, Message::MigrateIn(payload)) && n > 0 {
            // target vanished between check and send: the futures fail (§5)
        }
    }

    /// Fig. 8 destination side (step 6): activate the migrated work.
    fn migrate_in(&mut self, payload: MigratePayload) {
        if let Backend::Engine(core) = &mut self.backend {
            if payload.kv_bytes > 0 {
                core.kv_manager().migrate_in(payload.session, payload.kv_bytes, 0);
            }
        }
        for (k, v) in payload.state {
            self.store.put(&k, v);
        }
        for msg in payload.calls {
            msg.cell.mark_queued(self.id.clone());
            self.load.queued.fetch_add(1, Ordering::Relaxed);
            self.queue.push_back(msg);
        }
        self.migrated_in += 1;
    }

    // -------------------------------------------------------- telemetry
    fn maybe_push_telemetry(&mut self) {
        if self.last_telemetry.elapsed() >= Duration::from_millis(20) {
            self.push_telemetry();
        }
    }

    fn push_telemetry(&mut self) {
        self.last_telemetry = Instant::now();
        let mut waiting: Vec<(SessionId, u64)> = self
            .queue
            .iter()
            .map(|m| (m.cell.session(), m.cell.queue_wait().as_millis() as u64))
            .collect();
        waiting.sort_by_key(|(_, w)| std::cmp::Reverse(*w));
        waiting.truncate(16);
        let oldest = waiting.first().map(|(_, w)| *w).unwrap_or(0);
        let m = InstanceMetrics {
            agent: self.id.agent.as_str().to_string(),
            node: self.node.0,
            queue_len: self.queue.len(),
            // Tool backends execute synchronously inside the turn, so at
            // telemetry time their in-flight count is always zero; engine
            // backends report admitted-but-unfinished sequences.
            active: self.inflight.len(),
            completed: self.completed,
            failed: self.failed,
            migrated_in: self.migrated_in,
            migrated_out: self.migrated_out,
            busy_ewma: self.busy_ewma,
            oldest_wait_ms: oldest,
            waiting_sessions: waiting,
        };
        self.store.put(&keys::instance_metrics(&self.id), m);
    }

    /// KV manager access for tests / policy assertions.
    pub fn kv_manager(&self) -> Option<&Arc<KvCacheManager>> {
        match &self.backend {
            Backend::Engine(core) => Some(core.kv_manager()),
            Backend::Tool(_) => None,
        }
    }

    /// The future-state snapshot used by telemetry tests.
    pub fn queue_states(&self) -> Vec<FutureState> {
        self.queue.iter().map(|m| m.cell.state()).collect()
    }
}
