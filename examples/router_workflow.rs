//! Router workflow under shifting load (Fig. 9b scenario).
//!
//! The Azure-like trace flips from chat-heavy to coder-heavy mid-run;
//! NALAR's resource_realloc policy kills idle chat instances and
//! provisions coder instances, while baselines ride out the imbalance.
//!
//! Run: `cargo run --release --example router_workflow -- --rps 40`

use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::server::Deployment;
use nalar::util::cli::Args;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};

fn main() -> nalar::Result<()> {
    let args = Args::from_env();
    let rps = args.f64_or("rps", 40.0);
    let secs = args.u64_or("secs", 6);

    for system in [SystemUnderTest::Nalar, SystemUnderTest::AutoGenLike] {
        let cfg = WorkflowKind::Router.config();
        let d = Deployment::launch_as(cfg, system)?;
        let rc = RunConfig {
            workflow: WorkflowKind::Router,
            rps,
            duration: Duration::from_secs(secs),
            session_pool: 64,
            request_timeout: Duration::from_secs(30),
            seed: 22,
        };
        let (stats, rec) = run_open_loop(&d, &rc);
        let paper = rec.summary_scaled(1.0 / stats.time_scale);
        let view = d.global().collect();
        let chat = view.instances_of("chat").count();
        let coder = view.instances_of("coder").count();
        println!(
            "{:<13} avg {:>6.1} p99 {:>7.1} (paper-s) | ok {:>4} fail {:>3} | imbalance {:.2}x | final chat={} coder={}",
            system.name(),
            paper.avg,
            paper.p99,
            stats.completed,
            stats.failed,
            stats.imbalance,
            chat,
            coder,
        );
        d.shutdown();
    }
    Ok(())
}
