#!/usr/bin/env bash
# serve-smoke: end-to-end gate for the HTTP serving plane (DESIGN.md §9).
#
# Boots `nalar serve --listen 127.0.0.1:0` as a real process, drives it
# with `nalar loadgen --remote` (async-park submits over the wire, DELETE
# cancels via --cancel-rate), validates the resulting BENCH_rps_sweep.json
# against the nalar-bench/v1 schema (transport must be "http"), checks the
# observability surfaces (`GET /metrics?format=prom`, a request's
# `/trace` timeline — DESIGN.md §10), then stops the server via its stop
# file and asserts the process exits 0 — which the server only does when
# zero accepted connections leaked at shutdown.
#
# Zero-dependency by design: bash + coreutils + the nalar binary.
set -euo pipefail

cd "$(dirname "$0")/.."

BIN=${NALAR_BIN:-target/release/nalar}
OUT=${SERVE_SMOKE_OUT:-serve-smoke}
TMP=$(mktemp -d)
SERVE_PID=

cleanup() {
    if [[ -n "$SERVE_PID" ]] && kill -0 "$SERVE_PID" 2>/dev/null; then
        kill "$SERVE_PID" 2>/dev/null || true
        wait "$SERVE_PID" 2>/dev/null || true
    fi
    rm -rf "$TMP"
}
trap cleanup EXIT

fail() {
    echo "serve-smoke: FAIL — $*" >&2
    echo "--- serve log ---" >&2
    cat "$TMP/serve.log" >&2 || true
    exit 1
}

if [[ ! -x "$BIN" ]]; then
    echo "serve-smoke: building $BIN"
    cargo build --release --bin nalar
fi
mkdir -p "$OUT"

# 1. Serve on an ephemeral port; the bound port lands in the port file.
#    time_scale matches the loadgen --quick profile (the client reads the
#    authoritative value back from GET /metrics before pacing).
echo "serve-smoke: starting $BIN serve --listen 127.0.0.1:0"
"$BIN" serve --workflow router --listen 127.0.0.1:0 \
    --port-file "$TMP/port" --stop-file "$TMP/stop" \
    --time-scale 0.002 >"$TMP/serve.log" 2>&1 &
SERVE_PID=$!

for _ in $(seq 1 300); do
    [[ -s "$TMP/port" ]] && break
    kill -0 "$SERVE_PID" 2>/dev/null || fail "server died before binding"
    sleep 0.1
done
[[ -s "$TMP/port" ]] || fail "server never wrote its port file"
PORT=$(tr -d '[:space:]' <"$TMP/port")
echo "serve-smoke: server up on 127.0.0.1:$PORT (pid $SERVE_PID)"

# 2. Quick open-loop sweep over the wire: async-park POSTs, GET polls,
#    seeded DELETE cancels. A nonzero exit here means a wire-protocol or
#    drain violation (lost request, missing Retry-After, leaked slot).
"$BIN" loadgen --quick --remote "127.0.0.1:$PORT" --cancel-rate 0.05 \
    --out "$OUT" || fail "remote loadgen sweep failed"

# 3. Schema gate: the report must validate as nalar-bench/v1 rps_sweep,
#    and every point must record the http transport.
"$BIN" loadgen --check-only --out "$OUT" || fail "report schema validation failed"
grep -q '"transport": *"http"' "$OUT/BENCH_rps_sweep.json" \
    || fail "report does not record transport=http"

# 4. Observability surfaces (DESIGN.md §10), via /dev/tcp so the gate
#    stays zero-dependency: the Prometheus exposition must render, and a
#    fresh request must yield a retrievable span timeline.
http_get() {
    # one HTTP/1.1 GET over /dev/tcp; prints status line + headers + body
    exec 3<>"/dev/tcp/127.0.0.1/$PORT" \
        || fail "cannot open /dev/tcp to 127.0.0.1:$PORT"
    printf 'GET %s HTTP/1.1\r\nHost: 127.0.0.1\r\nConnection: close\r\n\r\n' "$1" >&3
    cat <&3
    exec 3<&- 3>&-
}
http_get "/metrics?format=prom" >"$TMP/prom" 2>/dev/null
grep -q '^nalar_ingress_completed_total' "$TMP/prom" \
    || fail "prom exposition missing nalar_ counters"
grep -q '^nalar_stage_latency_seconds' "$TMP/prom" \
    || fail "prom exposition missing the stage-latency breakdown"

# Async-park one request, pull its id out of the 202, fetch its trace.
exec 3<>"/dev/tcp/127.0.0.1/$PORT" || fail "cannot open submit connection"
BODY='{"prompt": "trace me", "class": "chat"}'
printf 'POST /v1/workflows/router/requests HTTP/1.1\r\nHost: 127.0.0.1\r\nX-Nalar-Wait: 0\r\nX-Nalar-Deadline-Ms: 60000\r\nContent-Length: %s\r\nConnection: close\r\n\r\n%s' \
    "${#BODY}" "$BODY" >&3
cat <&3 >"$TMP/submit"
exec 3<&- 3>&-
grep -q '202' "$TMP/submit" || fail "async-park submit did not answer 202"
REQ_ID=$(grep -o '"request": *[0-9]*' "$TMP/submit" | grep -o '[0-9]*' | head -1)
[[ -n "$REQ_ID" ]] || fail "202 body carried no request id"
http_get "/v1/requests/$REQ_ID/trace" >"$TMP/trace" 2>/dev/null
grep -q '"events"' "$TMP/trace" || fail "request $REQ_ID has no span timeline"
grep -q '"queue_wait_ns"' "$TMP/trace" \
    || fail "trace response missing the stage decomposition"
echo "serve-smoke: prom exposition + request $REQ_ID trace OK"

# 5. Clean shutdown: touch the stop file, require exit code 0. The server
#    exits nonzero iff HttpServer::stop() found leaked connections.
touch "$TMP/stop"
if ! wait "$SERVE_PID"; then
    SERVE_PID=
    fail "server exited nonzero (leaked connections?)"
fi
SERVE_PID=
grep -q "clean shutdown: 0 leaked connections" "$TMP/serve.log" \
    || fail "server log missing the clean-shutdown line"

echo "serve-smoke: PASS — wire sweep valid, prom + trace served, clean shutdown, 0 leaked connections"
