//! Writing a new scheduling policy in a dozen lines (paper §6.2 / Fig. 6).
//!
//! Implements the paper's Figure-6 policy — boost one high-priority
//! session and migrate it away from busy instances — and shows it acting
//! on a live deployment. The `tick` body is 12 lines, mirroring the
//! paper's claim that operators explore policies in ~12 LoC.
//!
//! Run: `cargo run --release --example custom_policy`

use std::time::Duration;

use nalar::coordinator::{ClusterView, Policy, PolicyApi};
use nalar::ids::SessionId;
use nalar::json;
use nalar::server::Deployment;
use nalar::workflow::{Env, WorkflowKind};

/// Figure 6: request prioritization for one VIP session.
struct VipSession {
    session: SessionId,
}

impl Policy for VipSession {
    fn name(&self) -> &'static str {
        "vip_session"
    }

    // -- the 12 lines ----------------------------------------------------
    fn tick(&mut self, view: &ClusterView, api: &mut PolicyApi) {
        api.set_priority(self.session, 10);
        for agent in view.instances.iter() {
            if agent.m.waiting_sessions.iter().any(|(s, _)| *s == self.session) {
                if let Some(idle) = view
                    .instances_of(&agent.m.agent)
                    .find(|o| o.id != agent.id && o.m.queue_len == 0)
                {
                    api.migrate(self.session, agent.id.clone(), idle.id.clone());
                }
            }
        }
    }
    // ---------------------------------------------------------------------
}

fn main() -> nalar::Result<()> {
    let mut cfg = WorkflowKind::Financial.config();
    cfg.time_scale = 0.002;
    cfg.policies.clear(); // only the custom policy acts
    let d = Deployment::launch(cfg)?;

    let vip = d.new_session();
    println!("installing VipSession policy for {vip}");
    // Install by driving the global controller manually each period
    // (operators normally list the policy in the config; this shows the
    // same objects wired by hand).
    let global = d.global();
    let mut policy = VipSession { session: vip };

    // Background load from other sessions.
    let mut handles = Vec::new();
    for _ in 0..6 {
        let session = d.new_session();
        let env = Env::new(&d, session);
        handles.push(std::thread::spawn(move || {
            let f = env.ctx.agent("analyst").call(
                "summarize",
                json!({"prompt": "background load", "max_new_tokens": 200}),
            );
            let _ = f.value(Duration::from_secs(30));
        }));
    }
    std::thread::sleep(Duration::from_millis(30));

    // The VIP request arrives while instances are busy.
    let env = Env::new(&d, vip);
    let f = env.ctx.agent("analyst").call(
        "summarize",
        json!({"prompt": "urgent: board meeting", "max_new_tokens": 60}),
    );
    // Run a few policy ticks while the request is in flight.
    for _ in 0..10 {
        let view = global.collect();
        let mut api = PolicyApi::new();
        policy.tick(&view, &mut api);
        let n = api.commands().len();
        global.apply(api.take_commands());
        if n > 0 {
            println!("tick issued {n} command(s)");
        }
        std::thread::sleep(Duration::from_millis(20));
        if f.available() {
            break;
        }
    }
    let out = f.value(Duration::from_secs(30))?;
    println!(
        "VIP request served: {} tokens (priority path)",
        out.get("generated_tokens").as_i64().unwrap_or(0)
    );
    for h in handles {
        let _ = h.join();
    }
    d.shutdown();
    println!("OK");
    Ok(())
}
