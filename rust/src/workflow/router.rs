//! Router-based workflow (paper §6, Fig. 9b).
//!
//! A lightweight router agent classifies each query, then the request
//! branches: chat queries go to the chat agent; coding queries go to a
//! coding agent whose output is checked by the test harness. Branch
//! popularity shifts over the trace (Azure-like, >90% imbalance), which is
//! what NALAR's resource reallocation exploits and static baselines
//! cannot (§6.1: AutoGen/Ayo fail at 70-80 RPS).
//!
//! Written as a resumable [`Driver`]: each state holds the futures in
//! flight and `poll` advances one stage per readiness push, so the
//! request occupies no thread between stages.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::futures::{FutureHandle, Value};
use crate::json;
use crate::workflow::driver::{drive_blocking, Driver, Step};
use crate::workflow::Env;

/// One request: classify, then branch. Blocking compat shim over
/// [`RouterDriver`] (the closed-loop harness and examples call this).
pub fn run(env: &Env, input: &Value, timeout: Duration) -> Result<Value> {
    drive_blocking(&mut RouterDriver::new(input), env, timeout)
}

enum State {
    Start,
    /// Classification in flight (its latency is on the path).
    Classify { classify: FutureHandle },
    /// Chat branch: the reply is in flight.
    Chat { reply: FutureHandle },
    /// Coder branch: the implementation is in flight.
    Implement { code: FutureHandle },
    /// Coder branch: the test run over the implementation is in flight.
    /// The implementation text rides along so a journaled snapshot can
    /// re-issue the test run without re-implementing.
    Test { test: FutureHandle, code: String },
    /// Journal-replay re-entry point ([`RouterDriver::restore`]): the
    /// first poll re-issues the interrupted stage's call afresh.
    Resume { stage: String, code: String },
    Finished,
}

/// See [`run`]; resumable form.
pub struct RouterDriver {
    prompt: String,
    /// Ground-truth class rides along from the trace; the router agent's
    /// (tiny) LLM call still happens — it is the classification cost.
    class: String,
    state: State,
}

impl RouterDriver {
    pub fn new(input: &Value) -> RouterDriver {
        RouterDriver {
            prompt: input.get("prompt").as_str().unwrap_or("hello").to_string(),
            class: input.get("class").as_str().unwrap_or("chat").to_string(),
            state: State::Start,
        }
    }

    /// Rebuild a driver from a [`Driver::serialize_state`] snapshot.
    /// Classification (or an unrecognized snapshot) restarts from
    /// `Start` — re-issuing the classify call *is* the resume; later
    /// stages re-enter directly, skipping the work already banked.
    pub fn restore(input: &Value, state: &Value) -> RouterDriver {
        let mut d = RouterDriver::new(input);
        let stage = state.str_or("stage", "");
        if matches!(stage, "chat" | "implement" | "test") {
            d.state = State::Resume {
                stage: stage.to_string(),
                code: state.str_or("code", "").to_string(),
            };
        }
        d
    }
}

impl Driver for RouterDriver {
    fn poll(&mut self, env: &Env) -> Step {
        loop {
            // Take the state out; every arm either installs the next state
            // and loops, restores the current one and suspends, or finishes.
            match std::mem::replace(&mut self.state, State::Finished) {
                State::Start => {
                    let classify = env.ctx.agent("router").call(
                        "classify",
                        json!({"prompt": self.prompt.as_str(), "max_new_tokens": 4}),
                    );
                    self.state = State::Classify { classify };
                }
                State::Classify { classify } => match classify.try_value() {
                    None => {
                        let id = classify.id();
                        self.state = State::Classify { classify };
                        return Step::Pending { waiting_on: vec![id] };
                    }
                    Some(Err(e)) => return Step::Done(Err(e)),
                    Some(Ok(_)) => {
                        let deeper = env.ctx.deeper();
                        if self.class == "coder" {
                            let code = deeper.agent("coder").call(
                                "implement",
                                json!({"prompt": self.prompt.as_str(), "max_new_tokens": 192}),
                            );
                            self.state = State::Implement { code };
                        } else {
                            let reply = deeper.agent("chat").call(
                                "reply",
                                json!({"prompt": self.prompt.as_str(), "max_new_tokens": 96}),
                            );
                            self.state = State::Chat { reply };
                        }
                    }
                },
                State::Chat { reply } => match reply.try_value() {
                    None => {
                        let id = reply.id();
                        self.state = State::Chat { reply };
                        return Step::Pending { waiting_on: vec![id] };
                    }
                    Some(Err(e)) => return Step::Done(Err(e)),
                    Some(Ok(out)) => {
                        return Step::Done(Ok(json!({
                            "branch": "chat",
                            "tokens": out.get("generated_tokens").as_i64().unwrap_or(0),
                        })))
                    }
                },
                State::Implement { code } => match code.try_value() {
                    None => {
                        let id = code.id();
                        self.state = State::Implement { code };
                        return Step::Pending { waiting_on: vec![id] };
                    }
                    Some(Err(e)) => return Step::Done(Err(e)),
                    Some(Ok(code_out)) => {
                        let text = code_out.get("text").as_str().unwrap_or("").to_string();
                        let test = env.ctx.deeper().agent("test_harness").call_with(
                            "unit_test",
                            json!({"code": text.as_str(), "attempt": 0}),
                            &[code.id()],
                            0,
                        );
                        self.state = State::Test { test, code: text };
                    }
                },
                State::Test { test, code } => match test.try_value() {
                    None => {
                        let id = test.id();
                        self.state = State::Test { test, code };
                        return Step::Pending { waiting_on: vec![id] };
                    }
                    Some(Err(e)) => return Step::Done(Err(e)),
                    Some(Ok(test_out)) => {
                        return Step::Done(Ok(json!({
                            "branch": "coder",
                            "test": test_out.get("result").as_str().unwrap_or("?"),
                        })))
                    }
                },
                State::Resume { stage, code } => {
                    // Replay re-issues the interrupted stage's call afresh:
                    // the pre-crash future died with the node, and retrying
                    // an agent call is exactly what the driver would have
                    // done on failure anyway (§5 "driver decides").
                    let deeper = env.ctx.deeper();
                    match stage.as_str() {
                        "chat" => {
                            let reply = deeper.agent("chat").call(
                                "reply",
                                json!({"prompt": self.prompt.as_str(), "max_new_tokens": 96}),
                            );
                            self.state = State::Chat { reply };
                        }
                        "implement" => {
                            let code = deeper.agent("coder").call(
                                "implement",
                                json!({"prompt": self.prompt.as_str(), "max_new_tokens": 192}),
                            );
                            self.state = State::Implement { code };
                        }
                        "test" => {
                            // The implementation survived in the snapshot;
                            // only the test run is re-issued (no dep: the
                            // producing future did not survive the crash).
                            let test = deeper.agent("test_harness").call_with(
                                "unit_test",
                                json!({"code": code.as_str(), "attempt": 0}),
                                &[],
                                0,
                            );
                            self.state = State::Test { test, code };
                        }
                        _ => self.state = State::Start,
                    }
                }
                State::Finished => {
                    return Step::Done(Err(Error::msg("router driver polled after completion")))
                }
            }
        }
    }

    /// Classification is stage 1; the branch body 2; the coder branch's
    /// test run 3 — later stages have less remaining work (front-door
    /// SRTF).
    fn stage(&self) -> u32 {
        match &self.state {
            State::Start => 0,
            State::Classify { .. } => 1,
            State::Chat { .. } | State::Implement { .. } => 2,
            State::Test { .. } => 3,
            State::Resume { stage, .. } => match stage.as_str() {
                "chat" | "implement" => 2,
                "test" => 3,
                _ => 0,
            },
            State::Finished => 4,
        }
    }

    fn serialize_state(&self) -> Value {
        match &self.state {
            // Classification in flight resumes by re-classifying — which
            // is the same as starting over, so both snapshot alike.
            State::Start | State::Classify { .. } => json!({"stage": "classify"}),
            State::Chat { .. } => json!({"stage": "chat"}),
            State::Implement { .. } => json!({"stage": "implement"}),
            State::Test { code, .. } => json!({"stage": "test", "code": code.as_str()}),
            State::Resume { stage, code } => {
                json!({"stage": stage.as_str(), "code": code.as_str()})
            }
            State::Finished => Value::Null,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Deployment;
    use crate::workflow::WorkflowKind;

    #[test]
    fn both_branches_work() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let timeout = Duration::from_secs(20);

        let env = Env::new(&d, d.new_session());
        let chat = run(&env, &json!({"prompt": "hi", "class": "chat"}), timeout).unwrap();
        assert_eq!(chat.get("branch").as_str(), Some("chat"));

        let env2 = Env::new(&d, d.new_session());
        let code = run(&env2, &json!({"prompt": "fix bug", "class": "coder"}), timeout).unwrap();
        assert_eq!(code.get("branch").as_str(), Some("coder"));
        let t = code.get("test").as_str().unwrap();
        assert!(t == "Pass" || t == "Fail");
        d.shutdown();
    }

    #[test]
    fn poll_suspends_between_stages_and_names_what_it_waits_on() {
        // The router agent is made slow enough (100 paper-s at 0.001 =
        // 100ms wall) that two polls land while classification is in
        // flight — the suspend point is deterministic.
        let cfg = crate::config::DeploymentConfig::from_json(
            r#"{"time_scale": 0.001, "agents": [
                {"name": "router", "kind": "llm", "instances": 1,
                 "profile": {"base_s": 100.0}, "methods": ["classify"]},
                {"name": "chat", "kind": "llm", "instances": 1,
                 "profile": {"base_s": 0.1}, "methods": ["reply"]}]}"#,
        )
        .unwrap();
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let mut drv = RouterDriver::new(&json!({"prompt": "hi", "class": "chat"}));
        // First poll issues the classify call and suspends on it.
        let Step::Pending { waiting_on } = drv.poll(&env) else {
            panic!("fresh driver cannot be done");
        };
        assert_eq!(waiting_on.len(), 1);
        let classify_id = waiting_on[0];
        // Polling again while nothing resolved must stay pending on the
        // same future (no duplicate agent calls).
        let Step::Pending { waiting_on } = drv.poll(&env) else {
            panic!("still pending");
        };
        assert_eq!(waiting_on, vec![classify_id]);
        d.shutdown();
    }

    #[test]
    fn restore_reenters_the_snapshotted_stage() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let input = json!({"prompt": "fix bug", "class": "coder"});

        // A fresh driver snapshots as "classify" (nothing banked yet); a
        // null snapshot restores to exactly that.
        let fresh = RouterDriver::new(&input);
        assert_eq!(fresh.serialize_state().get("stage").as_str(), Some("classify"));
        assert_eq!(RouterDriver::restore(&input, &Value::Null).stage(), 0);

        // A test-stage snapshot carries the implementation text: the
        // restored driver skips classify + implement and re-issues only
        // the test run — then completes end to end.
        let snap = json!({"stage": "test", "code": "fn main() {}"});
        let mut restored = RouterDriver::restore(&input, &snap);
        assert_eq!(restored.stage(), 3, "snapshot re-enters the test stage");
        let out = drive_blocking(&mut restored, &env, Duration::from_secs(20)).unwrap();
        assert_eq!(out.get("branch").as_str(), Some("coder"));
        d.shutdown();
    }
}
