//! Just-in-time model routing on deadline slack (DESIGN.md §13).
//!
//! The two-level control story applied to *which model* serves a call:
//! agent calls no longer bind to a fixed engine class. When the engine
//! declares named variants (`engine.variants[]` — distinct latency/quality
//! curves behind one batch former), the front door picks a variant per
//! call at dispatch time from the request's current deadline slack
//! (`deadline − now − StageStats::estimate(stage)`) and the tenant's
//! budget state:
//!
//! * slack below the fast threshold, or the tenant's token bucket dry →
//!   the *fastest* variant that still meets the quality floor (a request
//!   already past its deadline waives the floor — any answer beats none);
//! * slack of several multiples of the remaining-work estimate → the
//!   *highest-quality* variant (headroom is free quality);
//! * otherwise → the *base* variant (the profile as calibrated).
//!
//! The thresholds and the quality floor are global policy: the
//! `jit_route` policy ([`crate::coordinator::policies`]) adjusts them
//! from cluster telemetry each tick, and the component controller
//! enforces the floor locally on every engine admit ([`RouteState::enforce`]).
//! With no variants declared (every pre-existing config) the router is
//! never installed and dispatch is byte-for-byte the old fixed path.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::{DeploymentConfig, ModelVariant};

/// Which routing behaviour the front door runs (`ingress.route`). This is
/// the single name authority shared by config validation, the loadgen
/// `--route` axis and the CLI — a typo fails at parse time everywhere.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteMode {
    /// No per-call decision. `Fixed(None)` is the pre-variant behaviour
    /// (no router installed at all); `Fixed(Some(name))` pins every call
    /// to one named variant — the bench's comparison arms.
    Fixed(Option<String>),
    /// Pick a variant per call from deadline slack at dispatch time.
    Jit,
}

impl RouteMode {
    /// Parse a config/CLI name: "fixed" | "jit" | "fixed-<variant>".
    /// Whether a pinned variant actually exists is checked where the
    /// variant table is in scope (config validation / [`RouteState::new`]).
    pub fn parse(s: &str) -> Option<RouteMode> {
        match s {
            "fixed" => Some(RouteMode::Fixed(None)),
            "jit" => Some(RouteMode::Jit),
            other => other
                .strip_prefix("fixed-")
                .filter(|name| !name.is_empty())
                .map(|name| RouteMode::Fixed(Some(name.to_string()))),
        }
    }

    pub fn name(&self) -> String {
        match self {
            RouteMode::Fixed(None) => "fixed".into(),
            RouteMode::Fixed(Some(v)) => format!("fixed-{v}"),
            RouteMode::Jit => "jit".into(),
        }
    }
}

/// One routing decision: the chosen variant plus whether the request was
/// urgent (negative slack / tenant over budget) when it was made — urgency
/// waives the quality floor at local enforcement too.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub variant: usize,
    pub urgent: bool,
}

/// An f64 stored as atomic bits so policy updates never take a lock on
/// the dispatch hot path.
struct AtomicF64(AtomicU64);

impl AtomicF64 {
    fn new(x: f64) -> AtomicF64 {
        AtomicF64(AtomicU64::new(x.to_bits()))
    }
    fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
    fn set(&self, x: f64) {
        self.0.store(x.to_bits(), Ordering::Relaxed);
    }
}

/// Default fast threshold: route to the fast variant once slack dips
/// below zero — the request will miss its deadline on the current curve.
pub const DEFAULT_SLACK_FAST_S: f64 = 0.0;
/// Default headroom multiple: slack above 4x the remaining-work estimate
/// upgrades to the highest-quality variant.
pub const DEFAULT_HEADROOM_LARGE: f64 = 4.0;
/// Default quality floor: none (any declared variant is acceptable).
pub const DEFAULT_QUALITY_FLOOR: f64 = 0.0;

/// Shared router state: the variant table, the policy-tunable thresholds,
/// and the global per-variant dispatch counters. One per deployment,
/// installed into the [`SharedRoute`] slot by `Ingress::start` when the
/// config declares variants and a non-`fixed` route.
pub struct RouteState {
    mode: RouteMode,
    variants: Vec<ModelVariant>,
    /// Precomputed indices: min latency_mult / max quality / closest to
    /// the profile curve (latency_mult nearest 1.0).
    fastest: usize,
    largest: usize,
    base: usize,
    /// `Fixed(Some(_))` resolved to its index.
    pinned: Option<usize>,
    slack_fast_s: AtomicF64,
    headroom_large: AtomicF64,
    quality_floor: AtomicF64,
    /// Per-variant dispatch decisions, cluster-wide (the per-workflow /
    /// per-tenant split lives on the ingress shard counters).
    dispatches: Vec<AtomicU64>,
}

impl RouteState {
    /// Build from a validated mode + variant table. Returns `None` for
    /// `Fixed(None)` or an empty table: routing stays uninstalled and the
    /// dispatch path is exactly the pre-variant one.
    pub fn new(mode: RouteMode, variants: &[ModelVariant]) -> Option<Arc<RouteState>> {
        if variants.is_empty() || mode == RouteMode::Fixed(None) {
            return None;
        }
        let arg = |f: &dyn Fn(&ModelVariant) -> f64, max: bool| -> usize {
            let mut best = 0usize;
            for (i, v) in variants.iter().enumerate() {
                let cur = f(&variants[best]);
                let better = if max { f(v) > cur } else { f(v) < cur };
                if better {
                    best = i;
                }
            }
            best
        };
        let pinned = match &mode {
            RouteMode::Fixed(Some(name)) => {
                Some(variants.iter().position(|v| &v.name == name)?)
            }
            _ => None,
        };
        Some(Arc::new(RouteState {
            mode,
            fastest: arg(&|v| v.latency_mult, false),
            largest: arg(&|v| v.quality, true),
            base: arg(&|v| (v.latency_mult.ln()).abs(), false),
            pinned,
            slack_fast_s: AtomicF64::new(DEFAULT_SLACK_FAST_S),
            headroom_large: AtomicF64::new(DEFAULT_HEADROOM_LARGE),
            quality_floor: AtomicF64::new(DEFAULT_QUALITY_FLOOR),
            dispatches: variants.iter().map(|_| AtomicU64::new(0)).collect(),
            variants: variants.to_vec(),
        }))
    }

    /// Resolve the deployment's configured route. The config was
    /// validated, so a pinned name always resolves.
    pub fn from_config(cfg: &DeploymentConfig) -> Option<Arc<RouteState>> {
        let mode = RouteMode::parse(&cfg.ingress.route)?;
        Self::new(mode, &cfg.engine.variants)
    }

    pub fn mode(&self) -> &RouteMode {
        &self.mode
    }

    pub fn variants(&self) -> &[ModelVariant] {
        &self.variants
    }

    pub fn variant_name(&self, idx: usize) -> &str {
        &self.variants[idx].name
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.variants.iter().position(|v| v.name == name)
    }

    /// Pick a variant for one dispatch. `slack_s` is the request's signed
    /// deadline slack (`None` before the deadline is known — treated as
    /// ample); `est_s` the `StageStats` remaining-work estimate.
    pub fn decide(&self, slack_s: Option<f64>, est_s: Option<f64>, over_budget: bool) -> Decision {
        if let Some(idx) = self.pinned {
            return Decision { variant: idx, urgent: false };
        }
        let floor = self.quality_floor.get();
        let slack = slack_s.unwrap_or(f64::INFINITY);
        let urgent = over_budget || slack < self.slack_fast_s.get();
        let variant = if urgent {
            // fastest variant meeting the floor; a request already past
            // its deadline (or with no floor-meeting variant) takes the
            // absolute fastest — any answer beats a miss.
            if slack < 0.0 {
                self.fastest
            } else {
                self.fastest_meeting(floor).unwrap_or(self.fastest)
            }
        } else {
            let headroom = match est_s {
                Some(est) if est > 0.0 => slack / est,
                // no estimate yet: only clearly-idle requests upgrade
                _ => 0.0,
            };
            let pick = if headroom > self.headroom_large.get() { self.largest } else { self.base };
            // the floor binds every non-urgent dispatch
            if self.variants[pick].quality < floor {
                self.fastest_meeting(floor).unwrap_or(self.largest)
            } else {
                pick
            }
        };
        Decision { variant, urgent }
    }

    /// Lowest-latency variant whose quality is >= `floor`.
    fn fastest_meeting(&self, floor: f64) -> Option<usize> {
        self.variants
            .iter()
            .enumerate()
            .filter(|(_, v)| v.quality >= floor)
            .min_by(|a, b| a.1.latency_mult.total_cmp(&b.1.latency_mult))
            .map(|(i, _)| i)
    }

    /// Local enforcement at the engine admit path: the component
    /// controller re-checks the stamped variant against the *current*
    /// quality floor (the global controller may have raised it since the
    /// front door decided) and substitutes the cheapest floor-meeting
    /// variant. Urgent dispatches keep their fast pick.
    pub fn enforce(&self, name: &str, urgent: bool) -> usize {
        let idx = self.index_of(name).unwrap_or(self.base);
        if urgent || self.pinned.is_some() {
            return idx;
        }
        let floor = self.quality_floor.get();
        if self.variants[idx].quality < floor {
            self.fastest_meeting(floor).unwrap_or(idx)
        } else {
            idx
        }
    }

    /// Count one dispatch decision (cluster-wide; the ingress keeps the
    /// per-workflow/per-tenant split).
    pub fn note(&self, idx: usize) {
        self.dispatches[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Per-variant dispatch counts, in variant declaration order.
    pub fn counts(&self) -> Vec<(String, u64)> {
        self.variants
            .iter()
            .zip(&self.dispatches)
            .map(|(v, c)| (v.name.clone(), c.load(Ordering::Relaxed)))
            .collect()
    }

    /// Dispatch-weighted mean quality (the bench's quality accounting),
    /// `None` before any dispatch was routed.
    pub fn quality_mean(&self) -> Option<f64> {
        let mut n = 0u64;
        let mut sum = 0.0;
        for (v, c) in self.variants.iter().zip(&self.dispatches) {
            let c = c.load(Ordering::Relaxed);
            n += c;
            sum += c as f64 * v.quality;
        }
        (n > 0).then(|| sum / n as f64)
    }

    pub fn set_thresholds(&self, slack_fast_s: f64, headroom_large: f64, quality_floor: f64) {
        self.slack_fast_s.set(slack_fast_s);
        self.headroom_large.set(headroom_large.max(1.0));
        self.quality_floor.set(quality_floor.clamp(0.0, 1.0));
    }

    pub fn thresholds(&self) -> (f64, f64, f64) {
        (self.slack_fast_s.get(), self.headroom_large.get(), self.quality_floor.get())
    }

    pub fn quality_floor(&self) -> f64 {
        self.quality_floor.get()
    }
}

/// Per-request routing hint: the front door writes the decision here at
/// each dispatch and the agent stub reads it when issuing the call, so a
/// driver that fans out several calls from one poll stamps each of them
/// with the same (freshest) decision. Index 0 means "no decision yet" —
/// the stub then leaves the call unrouted (profile curve).
pub struct RouteHint {
    state: Arc<RouteState>,
    /// Chosen variant index + 1; 0 = unset.
    sel: AtomicUsize,
    urgent: AtomicBool,
    /// Per-variant dispatch counters of the owning (workflow, tenant) —
    /// shared with the ingress metrics snapshot, bumped by [`Self::consume`]
    /// once per stamped call. `None` outside an ingress (unit tests).
    counters: Option<Arc<Vec<AtomicU64>>>,
}

impl RouteHint {
    pub fn new(state: Arc<RouteState>) -> Arc<RouteHint> {
        Self::with_counters(state, None)
    }

    /// A hint whose consumptions also land on the given per-variant
    /// counter slice (the ingress passes its per-(workflow, tenant) row).
    pub fn with_counters(
        state: Arc<RouteState>,
        counters: Option<Arc<Vec<AtomicU64>>>,
    ) -> Arc<RouteHint> {
        Arc::new(RouteHint {
            state,
            sel: AtomicUsize::new(0),
            urgent: AtomicBool::new(false),
            counters,
        })
    }

    pub fn state(&self) -> &Arc<RouteState> {
        &self.state
    }

    pub fn set(&self, d: Decision) {
        self.urgent.store(d.urgent, Ordering::Relaxed);
        self.sel.store(d.variant + 1, Ordering::Release);
    }

    pub fn get(&self) -> Option<Decision> {
        match self.sel.load(Ordering::Acquire) {
            0 => None,
            n => Some(Decision { variant: n - 1, urgent: self.urgent.load(Ordering::Relaxed) }),
        }
    }

    /// The stamped variant's name + urgency — a pure read (assertions,
    /// display). Dispatch accounting goes through [`Self::consume`].
    pub fn variant(&self) -> Option<(&str, bool)> {
        self.get().map(|d| (self.state.variant_name(d.variant), d.urgent))
    }

    /// Read the stamped decision *and count it as one dispatch*: the
    /// agent stub (and the scripted testkit engine) call this exactly
    /// once per issued call, so the per-variant counters sum to the total
    /// number of routed dispatches — the satellite-4 invariant.
    pub fn consume(&self) -> Option<(&str, bool)> {
        let d = self.get()?;
        self.state.note(d.variant);
        if let Some(c) = &self.counters {
            c[d.variant].fetch_add(1, Ordering::Relaxed);
        }
        Some((self.state.variant_name(d.variant), d.urgent))
    }
}

/// Late-install slot for the deployment's router (mirrors the trace
/// sink's `SharedSink`): the deployment is built before the ingress
/// decides whether routing is on, and the global/component controllers
/// hold clones of this slot from spawn time.
#[derive(Clone, Default)]
pub struct SharedRoute {
    slot: Arc<Mutex<Option<Arc<RouteState>>>>,
}

impl SharedRoute {
    pub fn install(&self, state: Arc<RouteState>) {
        *self.slot.lock().unwrap() = Some(state);
    }

    pub fn get(&self) -> Option<Arc<RouteState>> {
        self.slot.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn variants() -> Vec<ModelVariant> {
        vec![
            ModelVariant { name: "fast".into(), latency_mult: 0.35, quality: 0.82 },
            ModelVariant { name: "base".into(), latency_mult: 1.0, quality: 0.92 },
            ModelVariant { name: "large".into(), latency_mult: 2.2, quality: 0.99 },
        ]
    }

    #[test]
    fn parse_is_the_name_authority() {
        assert_eq!(RouteMode::parse("fixed"), Some(RouteMode::Fixed(None)));
        assert_eq!(RouteMode::parse("jit"), Some(RouteMode::Jit));
        assert_eq!(
            RouteMode::parse("fixed-large"),
            Some(RouteMode::Fixed(Some("large".into())))
        );
        for typo in ["jitt", "Fixed", "fixed-", "adaptive", ""] {
            assert!(RouteMode::parse(typo).is_none(), "{typo} must not parse");
        }
        // names round-trip
        for name in ["fixed", "jit", "fixed-large"] {
            assert_eq!(RouteMode::parse(name).unwrap().name(), name);
        }
    }

    #[test]
    fn no_variants_or_fixed_mode_means_no_router() {
        assert!(RouteState::new(RouteMode::Jit, &[]).is_none());
        assert!(RouteState::new(RouteMode::Fixed(None), &variants()).is_none());
        assert!(RouteState::new(RouteMode::Fixed(Some("nope".into())), &variants()).is_none());
        assert!(RouteState::new(RouteMode::Jit, &variants()).is_some());
    }

    #[test]
    fn jit_routes_by_slack() {
        let r = RouteState::new(RouteMode::Jit, &variants()).unwrap();
        // negative slack -> fastest, flagged urgent
        let d = r.decide(Some(-0.5), Some(2.0), false);
        assert_eq!((d.variant, d.urgent), (0, true));
        // ample headroom (slack >> estimate) -> highest quality
        let d = r.decide(Some(20.0), Some(2.0), false);
        assert_eq!((d.variant, d.urgent), (2, false));
        // moderate slack -> base curve
        let d = r.decide(Some(5.0), Some(2.0), false);
        assert_eq!((d.variant, d.urgent), (1, false));
        // no estimate yet: never upgrades, base curve
        let d = r.decide(Some(100.0), None, false);
        assert_eq!(d.variant, 1);
        // tenant over budget -> fast even with slack
        let d = r.decide(Some(5.0), Some(2.0), true);
        assert_eq!((d.variant, d.urgent), (0, true));
    }

    #[test]
    fn quality_floor_binds_except_when_urgent() {
        let r = RouteState::new(RouteMode::Jit, &variants()).unwrap();
        r.set_thresholds(0.0, 4.0, 0.9);
        // urgent-but-not-expired: fastest variant meeting the floor
        let d = r.decide(Some(0.5), Some(2.0), true);
        assert_eq!(r.variant_name(d.variant), "base");
        // past the deadline the floor is waived: absolute fastest
        let d = r.decide(Some(-1.0), Some(2.0), false);
        assert_eq!(r.variant_name(d.variant), "fast");
        // local enforcement mirrors the same rule
        assert_eq!(r.variant_name(r.enforce("fast", false)), "base");
        assert_eq!(r.variant_name(r.enforce("fast", true)), "fast");
        assert_eq!(r.variant_name(r.enforce("large", false)), "large");
    }

    #[test]
    fn pinned_mode_always_picks_its_variant() {
        let r = RouteState::new(RouteMode::Fixed(Some("large".into())), &variants()).unwrap();
        for slack in [Some(-5.0), Some(0.5), Some(50.0), None] {
            let d = r.decide(slack, Some(2.0), false);
            assert_eq!(r.variant_name(d.variant), "large");
            assert!(!d.urgent);
        }
    }

    #[test]
    fn counters_and_quality_mean_accumulate() {
        let r = RouteState::new(RouteMode::Jit, &variants()).unwrap();
        assert_eq!(r.quality_mean(), None);
        r.note(0);
        r.note(0);
        r.note(2);
        let counts = r.counts();
        assert_eq!(counts[0], ("fast".into(), 2));
        assert_eq!(counts[1], ("base".into(), 0));
        assert_eq!(counts[2], ("large".into(), 1));
        let q = r.quality_mean().unwrap();
        let want = (2.0 * 0.82 + 0.99) / 3.0;
        assert!((q - want).abs() < 1e-9, "{q} vs {want}");
    }

    #[test]
    fn hint_stamps_and_reads_back() {
        let r = RouteState::new(RouteMode::Jit, &variants()).unwrap();
        let h = RouteHint::new(r);
        assert_eq!(h.get(), None);
        assert_eq!(h.variant(), None);
        h.set(Decision { variant: 2, urgent: false });
        assert_eq!(h.variant(), Some(("large", false)));
        h.set(Decision { variant: 0, urgent: true });
        assert_eq!(h.variant(), Some(("fast", true)));
    }

    #[test]
    fn consume_counts_dispatches_but_variant_reads_are_pure() {
        let r = RouteState::new(RouteMode::Jit, &variants()).unwrap();
        let counters: Arc<Vec<AtomicU64>> =
            Arc::new((0..3).map(|_| AtomicU64::new(0)).collect());
        let h = RouteHint::with_counters(r.clone(), Some(counters.clone()));
        assert_eq!(h.consume(), None, "unset hint never counts");
        h.set(Decision { variant: 1, urgent: false });
        h.variant();
        h.variant();
        assert_eq!(r.counts()[1].1, 0, "pure reads must not count");
        assert_eq!(h.consume(), Some(("base", false)));
        assert_eq!(h.consume(), Some(("base", false)));
        assert_eq!(r.counts()[1].1, 2, "one count per consumed dispatch");
        assert_eq!(counters[1].load(Ordering::Relaxed), 2, "tenant row tracks the global");
        assert_eq!(counters[0].load(Ordering::Relaxed), 0);
    }

    #[test]
    fn shared_slot_installs_late() {
        let slot = SharedRoute::default();
        assert!(slot.get().is_none());
        let r = RouteState::new(RouteMode::Jit, &variants()).unwrap();
        slot.install(r);
        assert!(slot.get().is_some());
        assert!(slot.clone().get().is_some(), "clones share the slot");
    }
}
