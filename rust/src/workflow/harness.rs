//! Open-loop serving harness: Poisson arrivals -> driver threads -> stats.
//!
//! This regenerates the Fig-9 cells: for a (workflow, system, rate) tuple
//! it drives the deployment at `rps` for `duration`, then reports
//! avg/P50/P95/P99 latency (scaled back to paper-equivalent seconds),
//! completion/failure counts and the load-imbalance factor.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::ids::SessionId;
use crate::json;
use crate::metrics::{LatencyRecorder, LatencySummary};
use crate::server::Deployment;
use crate::util::rng::Rng;
use crate::workflow::{run_request, WorkflowKind};
use crate::workload;

/// One Fig-9 cell's run parameters.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub workflow: WorkflowKind,
    /// Wall-clock requests/second (scale by `time_scale` to compare with
    /// the paper's paper-seconds axis).
    pub rps: f64,
    /// Wall-clock measurement window.
    pub duration: Duration,
    /// Session pool size (stateful workflows draw sessions Zipf-skewed).
    pub session_pool: usize,
    /// Per-request timeout (requests past it count as failures — the
    /// "fails under load" signal of §6.1).
    pub request_timeout: Duration,
    pub seed: u64,
}

impl RunConfig {
    pub fn quick(workflow: WorkflowKind, rps: f64) -> Self {
        RunConfig {
            workflow,
            rps,
            duration: Duration::from_secs(3),
            session_pool: 24,
            request_timeout: Duration::from_secs(30),
            seed: 7,
        }
    }
}

/// Results of one harness run.
#[derive(Debug, Clone)]
pub struct RunStats {
    /// Latency summary in wall-clock seconds (use `scaled_summary` for
    /// paper-equivalent units).
    pub latency: LatencySummary,
    pub completed: u64,
    pub failed: u64,
    /// max/mean busy across the workflow's LLM instances (>=1).
    pub imbalance: f64,
    /// Completed requests per wall-clock second.
    pub throughput: f64,
    pub time_scale: f64,
}

impl RunStats {
    /// Latency in paper-equivalent seconds (divide by `time_scale`).
    pub fn paper_latency(&self, recorder: &LatencyRecorder) -> LatencySummary {
        recorder.summary_scaled(1.0 / self.time_scale)
    }

    pub fn failure_rate(&self) -> f64 {
        let total = self.completed + self.failed;
        if total == 0 {
            0.0
        } else {
            self.failed as f64 / total as f64
        }
    }
}

/// Synthesize one request input for `kind` from the §6 corpora. `progress`
/// (0..1) drives the Azure-trace phase flip; `turn` > 0 draws a follow-up
/// for stateful sessions. Shared by this closed-pool harness and the
/// ingress load generator ([`crate::ingress::loadgen`]).
pub fn input_for(
    kind: WorkflowKind,
    progress: f64,
    turn: u64,
    rng: &mut Rng,
) -> crate::futures::Value {
    match kind {
        WorkflowKind::Financial => {
            let q = if turn == 0 {
                workload::finqa_question(rng)
            } else {
                workload::finqa_followup(rng)
            };
            json!({"question": q})
        }
        WorkflowKind::Router => {
            let class = workload::azure_like_class(progress, rng);
            let prompt = if class == "coder" {
                workload::swe_task(rng)
            } else {
                workload::chat_prompt(rng)
            };
            json!({"prompt": prompt, "class": class})
        }
        WorkflowKind::Swe => json!({"task": workload::swe_task(rng)}),
    }
}

/// LLM agent types whose instances define the imbalance metric.
fn imbalance_agents(kind: WorkflowKind) -> &'static [&'static str] {
    match kind {
        WorkflowKind::Financial => &["analyst"],
        WorkflowKind::Router => &["chat", "coder"],
        WorkflowKind::Swe => &["developer"],
    }
}

/// Run the open-loop experiment. Returns stats plus the raw recorder (for
/// paper-scaled reporting).
pub fn run_open_loop(d: &Deployment, rc: &RunConfig) -> (RunStats, Arc<LatencyRecorder>) {
    let mut arrivals = workload::Arrivals::new(rc.rps, rc.seed);
    let schedule = arrivals.schedule(rc.duration);
    let recorder = Arc::new(LatencyRecorder::new());
    let completed = Arc::new(AtomicU64::new(0));
    let failed = Arc::new(AtomicU64::new(0));
    let mut rng = Rng::new(rc.seed ^ 0xFEED);

    // Pre-create the session pool; per-session turn counters drive
    // follow-up questions (human-in-the-loop).
    let sessions: Vec<SessionId> = (0..rc.session_pool.max(1)).map(|_| d.new_session()).collect();
    let turns: Arc<Vec<AtomicU64>> =
        Arc::new((0..sessions.len()).map(|_| AtomicU64::new(0)).collect());

    let start = Instant::now();
    // The deployment is shared by reference across driver threads via a
    // scope; drivers block on futures, threads are cheap here. The scope
    // joins every driver before returning.
    std::thread::scope(|scope| {
        for at in &schedule {
            let wait = at.saturating_sub(start.elapsed());
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
            let progress = start.elapsed().as_secs_f64() / rc.duration.as_secs_f64();
            let sidx = rng.zipf(sessions.len(), 1.1);
            let session = sessions[sidx];
            let turn = turns[sidx].fetch_add(1, Ordering::Relaxed);
            let input = input_for(rc.workflow, progress.min(1.0), turn, &mut rng);

            let recorder = recorder.clone();
            let completed = completed.clone();
            let failed = failed.clone();
            let kind = rc.workflow;
            let timeout = rc.request_timeout;
            scope.spawn(move || {
                let t0 = Instant::now();
                let outcome = run_request(d, kind, session, &input, timeout);
                let elapsed = t0.elapsed();
                // per-run recorder (this experiment's cell) plus the
                // deployment-lifetime recorder exposed by the server.
                recorder.record(elapsed);
                d.latency().record(elapsed);
                match outcome {
                    Ok(_) => {
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        // timeouts/failures also contribute tail latency
                        failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let elapsed = start.elapsed().as_secs_f64();

    // Load imbalance over completed work per instance.
    let view = d.global().collect();
    let mut per_instance: Vec<f64> = Vec::new();
    for agent in imbalance_agents(rc.workflow) {
        for i in view.instances_of(agent) {
            per_instance.push(i.m.completed as f64);
        }
    }
    let imbalance = crate::metrics::load_imbalance(&per_instance);

    let stats = RunStats {
        latency: recorder.summary(),
        completed: completed.load(Ordering::Relaxed),
        failed: failed.load(Ordering::Relaxed),
        imbalance,
        throughput: completed.load(Ordering::Relaxed) as f64 / elapsed.max(1e-9),
        time_scale: d.cfg().time_scale,
    };
    (stats, recorder)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_runs_router_workflow() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let rc = RunConfig {
            workflow: WorkflowKind::Router,
            rps: 30.0,
            duration: Duration::from_secs(2),
            session_pool: 8,
            request_timeout: Duration::from_secs(20),
            seed: 3,
        };
        let (stats, _rec) = run_open_loop(&d, &rc);
        assert!(stats.completed >= 20, "completed only {}", stats.completed);
        assert_eq!(stats.failed, 0, "unexpected failures");
        assert!(stats.latency.p99 >= stats.latency.p50);
        assert!(stats.imbalance >= 1.0);
        // the deployment-lifetime recorder saw every request too
        assert_eq!(d.latency().len() as u64, stats.completed + stats.failed);
        assert!(d.latency_paper_summary().p99 > 0.0);
        d.shutdown();
    }

    #[test]
    fn harness_deterministic_arrivals() {
        let a = workload::Arrivals::new(50.0, 9).schedule(Duration::from_secs(1));
        let b = workload::Arrivals::new(50.0, 9).schedule(Duration::from_secs(1));
        assert_eq!(a, b);
    }
}
