//! Serving metrics: latency quantiles, throughput, load imbalance.
//!
//! The evaluation (paper §6.1, Figure 9) reports average / P50 / P95 / P99
//! end-to-end latency per request rate, plus a load-imbalance factor for
//! the router and SWE workflows. `LatencyRecorder` backs those tables;
//! `summary_scaled` converts the testbed's scaled milliseconds back into
//! "paper-equivalent" seconds (see DESIGN.md §3 substitution table).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Value;

/// Collects latency samples and computes the Fig-9 summary row.
#[derive(Default, Debug)]
pub struct LatencyRecorder {
    samples: Mutex<Vec<f64>>, // seconds
}

/// One Fig-9 row: the summary statistics for a (workflow, rate, system) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub count: usize,
    pub avg: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencySummary {
    /// Stable JSON form used by the `nalar bench` reports (DESIGN.md §4):
    /// every report point carries exactly these quantile fields.
    pub fn to_json(&self) -> Value {
        crate::json!({
            "count": self.count,
            "avg": self.avg,
            "p50": self.p50,
            "p95": self.p95,
            "p99": self.p99,
            "max": self.max
        })
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, latency: Duration) {
        self.samples.lock().unwrap().push(latency.as_secs_f64());
    }

    pub fn record_secs(&self, secs: f64) {
        self.samples.lock().unwrap().push(secs);
    }

    pub fn len(&self) -> usize {
        self.samples.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Summary with all values multiplied by `scale` (use `1.0 /
    /// time_scale` to report paper-equivalent seconds).
    pub fn summary_scaled(&self, scale: f64) -> LatencySummary {
        let mut s = self.samples.lock().unwrap().clone();
        if s.is_empty() {
            return LatencySummary { count: 0, avg: 0.0, p50: 0.0, p95: 0.0, p99: 0.0, max: 0.0 };
        }
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| -> f64 {
            let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
            s[idx] * scale
        };
        LatencySummary {
            count: s.len(),
            avg: s.iter().sum::<f64>() / s.len() as f64 * scale,
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            max: s[s.len() - 1] * scale,
        }
    }

    pub fn summary(&self) -> LatencySummary {
        self.summary_scaled(1.0)
    }
}

/// Load imbalance across instances: `max(busy) / mean(busy)` (>= 1.0).
///
/// The paper reports baselines showing ">2.1x higher load-imbalance" on the
/// SWE workflow and >90% branch imbalance in the Azure traces (§6.1).
pub fn load_imbalance(busy_fractions: &[f64]) -> f64 {
    if busy_fractions.is_empty() {
        return 1.0;
    }
    let mean = busy_fractions.iter().sum::<f64>() / busy_fractions.len() as f64;
    if mean <= f64::EPSILON {
        return 1.0;
    }
    let max = busy_fractions.iter().cloned().fold(f64::MIN, f64::max);
    max / mean
}

/// Goodput: requests completed *within their deadline* per wall-clock
/// second of the measurement window (the saturation-sweep y-axis — under
/// overload, completions past the deadline no longer count).
pub fn goodput(completed_in_deadline: u64, window: Duration) -> f64 {
    if window.is_zero() {
        return 0.0;
    }
    completed_in_deadline as f64 / window.as_secs_f64()
}

/// Fraction of offered requests rejected by admission control.
pub fn shed_rate(shed: u64, offered: u64) -> f64 {
    if offered == 0 {
        0.0
    } else {
        shed as f64 / offered as f64
    }
}

/// Number of log-spaced buckets in a [`Histogram`].
pub const HIST_BUCKETS: usize = 80;
/// Lowest bucket upper bound, in seconds (1µs).
pub const HIST_BASE: f64 = 1e-6;
/// Bucket-to-bucket growth factor. `HIST_BASE * HIST_GROWTH^79 ≈ 1123 s`,
/// so 80 buckets span 1µs .. ~19 minutes with ~30% relative resolution.
pub const HIST_GROWTH: f64 = 1.3;

/// A fixed-layout, lock-free latency histogram: [`HIST_BUCKETS`]
/// log-spaced buckets (upper bound of bucket *i* = `HIST_BASE *
/// HIST_GROWTH^i`; the last bucket also absorbs everything above it).
/// `record` is one float log + one relaxed atomic increment — safe on the
/// request completion path. Quantiles are read from a [`HistogramSnapshot`].
#[derive(Debug)]
pub struct Histogram {
    counts: Vec<AtomicU64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Index of the first bucket whose upper bound is >= `secs`.
    fn bucket(secs: f64) -> usize {
        if !(secs > HIST_BASE) {
            return 0;
        }
        let idx = ((secs / HIST_BASE).ln() / HIST_GROWTH.ln()).ceil();
        (idx as usize).min(HIST_BUCKETS - 1)
    }

    /// Upper bound of bucket `i`, in seconds (the quantile estimate a
    /// sample in that bucket reports — a conservative over-estimate).
    pub fn bound(i: usize) -> f64 {
        HIST_BASE * HIST_GROWTH.powi(i as i32)
    }

    pub fn record(&self, secs: f64) {
        let secs = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        self.counts[Self::bucket(secs)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64());
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> = self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let count = counts.iter().sum();
        HistogramSnapshot { counts, count }
    }
}

/// A point-in-time copy of a [`Histogram`]'s buckets. Snapshots from
/// different tenants/nodes merge by bucket-wise addition — the layout is
/// fixed, so merging is exact (no re-bucketing error).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub counts: Vec<u64>,
    pub count: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { counts: vec![0; HIST_BUCKETS], count: 0 }
    }
}

impl HistogramSnapshot {
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
    }

    /// Quantile estimate: upper bound of the bucket holding the q-th
    /// sample (0.0 when empty).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((self.count as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Histogram::bound(i);
            }
        }
        Histogram::bound(HIST_BUCKETS - 1)
    }

    /// Reduce to the fixed p50/p95/p99 stat the telemetry plane carries.
    pub fn stat(&self) -> StageStat {
        StageStat {
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            count: self.count,
        }
    }
}

/// p50/p95/p99 + sample count for one latency component, in seconds.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StageStat {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub count: u64,
}

impl StageStat {
    pub fn scaled(&self, scale: f64) -> StageStat {
        StageStat {
            p50: self.p50 * scale,
            p95: self.p95 * scale,
            p99: self.p99 * scale,
            count: self.count,
        }
    }

    pub fn to_json(&self) -> Value {
        crate::json!({
            "p50": self.p50,
            "p95": self.p95,
            "p99": self.p99,
            "count": self.count
        })
    }
}

/// The five-component request-latency decomposition (DESIGN.md §10):
/// queue-wait, sched-delay, poll-time and future-wait partition the
/// end-to-end latency; engine-service overlaps future-wait (the request
/// is parked while an engine serves its calls) and rides alongside.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct StageBreakdown {
    pub queue_wait: StageStat,
    pub sched_delay: StageStat,
    pub poll_time: StageStat,
    pub future_wait: StageStat,
    pub engine_service: StageStat,
}

/// The stable component order/naming used in reports and exposition.
pub const STAGE_NAMES: [&str; 5] =
    ["queue_wait", "sched_delay", "poll_time", "future_wait", "engine_service"];

impl StageBreakdown {
    pub fn components(&self) -> [(&'static str, &StageStat); 5] {
        [
            (STAGE_NAMES[0], &self.queue_wait),
            (STAGE_NAMES[1], &self.sched_delay),
            (STAGE_NAMES[2], &self.poll_time),
            (STAGE_NAMES[3], &self.future_wait),
            (STAGE_NAMES[4], &self.engine_service),
        ]
    }

    pub fn scaled(&self, scale: f64) -> StageBreakdown {
        StageBreakdown {
            queue_wait: self.queue_wait.scaled(scale),
            sched_delay: self.sched_delay.scaled(scale),
            poll_time: self.poll_time.scaled(scale),
            future_wait: self.future_wait.scaled(scale),
            engine_service: self.engine_service.scaled(scale),
        }
    }

    pub fn to_json(&self) -> Value {
        let mut v = crate::json!({});
        for (name, stat) in self.components() {
            v.insert(name, stat.to_json());
        }
        v
    }
}

/// One (workflow, tenant) cell's live histograms — what completed
/// requests fold their [`crate::trace::StageDurations`] into.
#[derive(Debug, Default)]
pub struct StageHistograms {
    pub queue_wait: Histogram,
    pub sched_delay: Histogram,
    pub poll_time: Histogram,
    pub future_wait: Histogram,
    pub engine_service: Histogram,
}

impl StageHistograms {
    pub fn new() -> StageHistograms {
        StageHistograms::default()
    }

    /// Record one completed request's decomposition (durations in ns).
    pub fn record_ns(&self, queue: u64, sched: u64, poll: u64, wait: u64, engine: u64) {
        self.queue_wait.record(queue as f64 / 1e9);
        self.sched_delay.record(sched as f64 / 1e9);
        self.poll_time.record(poll as f64 / 1e9);
        self.future_wait.record(wait as f64 / 1e9);
        self.engine_service.record(engine as f64 / 1e9);
    }

    pub fn snapshots(&self) -> [HistogramSnapshot; 5] {
        [
            self.queue_wait.snapshot(),
            self.sched_delay.snapshot(),
            self.poll_time.snapshot(),
            self.future_wait.snapshot(),
            self.engine_service.snapshot(),
        ]
    }

    pub fn breakdown(&self) -> StageBreakdown {
        let [q, s, p, w, e] = self.snapshots();
        StageBreakdown {
            queue_wait: q.stat(),
            sched_delay: s.stat(),
            poll_time: p.stat(),
            future_wait: w.stat(),
            engine_service: e.stat(),
        }
    }
}

/// Merge per-tenant snapshot arrays into one aggregate breakdown.
pub fn merge_breakdowns(parts: &[[HistogramSnapshot; 5]]) -> StageBreakdown {
    let mut merged: [HistogramSnapshot; 5] = Default::default();
    for part in parts {
        for (m, p) in merged.iter_mut().zip(part.iter()) {
            m.merge(p);
        }
    }
    let [q, s, p, w, e] = merged;
    StageBreakdown {
        queue_wait: q.stat(),
        sched_delay: s.stat(),
        poll_time: p.stat(),
        future_wait: w.stat(),
        engine_service: e.stat(),
    }
}

/// Per-instance serving counters pushed into the node store as telemetry.
#[derive(Debug, Default, Clone, Copy)]
pub struct Counters {
    pub enqueued: u64,
    pub started: u64,
    pub completed: u64,
    pub failed: u64,
    pub migrated_in: u64,
    pub migrated_out: u64,
    pub busy_time_us: u64,
}

impl Counters {
    pub fn busy_fraction(&self, window: Duration) -> f64 {
        if window.is_zero() {
            return 0.0;
        }
        (self.busy_time_us as f64 / window.as_micros() as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_ordered() {
        let r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record_secs(i as f64);
        }
        let s = r.summary();
        assert_eq!(s.count, 100);
        assert!((s.avg - 50.5).abs() < 1e-9);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn scaled_summary() {
        let r = LatencyRecorder::new();
        r.record_secs(2.0);
        let s = r.summary_scaled(100.0);
        assert_eq!(s.avg, 200.0);
    }

    #[test]
    fn empty_summary_zeroes() {
        let r = LatencyRecorder::new();
        let s = r.summary();
        assert_eq!(s.count, 0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn imbalance() {
        assert_eq!(load_imbalance(&[]), 1.0);
        assert_eq!(load_imbalance(&[0.5, 0.5]), 1.0);
        assert!((load_imbalance(&[0.9, 0.1]) - 1.8).abs() < 1e-9);
        assert_eq!(load_imbalance(&[0.0, 0.0]), 1.0);
    }

    #[test]
    fn goodput_and_shed_rate() {
        assert_eq!(goodput(80, Duration::from_secs(4)), 20.0);
        assert_eq!(goodput(5, Duration::ZERO), 0.0);
        assert_eq!(shed_rate(25, 100), 0.25);
        assert_eq!(shed_rate(0, 0), 0.0);
    }

    #[test]
    fn busy_fraction_capped() {
        let c = Counters { busy_time_us: 2_000_000, ..Default::default() };
        assert_eq!(c.busy_fraction(Duration::from_secs(1)), 1.0);
    }

    #[test]
    fn histogram_buckets_are_monotonic_and_bounded() {
        // every sample lands in a bucket whose bound is >= the sample
        // and < GROWTH * sample (log-bucket relative-error contract)
        for secs in [1e-7, 1e-6, 3.1e-5, 0.004, 0.25, 7.0, 900.0] {
            let h = Histogram::new();
            h.record(secs);
            let s = h.snapshot();
            assert_eq!(s.count, 1);
            let est = s.quantile(0.5);
            assert!(est >= secs * 0.999 || est >= HIST_BASE, "{secs} -> {est}");
            if secs > HIST_BASE && secs < Histogram::bound(HIST_BUCKETS - 2) {
                assert!(est <= secs * HIST_GROWTH * 1.001, "{secs} -> {est}");
            }
        }
        // above-range samples clamp into the last bucket, never panic
        let h = Histogram::new();
        h.record(1e9);
        assert_eq!(h.snapshot().quantile(0.99), Histogram::bound(HIST_BUCKETS - 1));
    }

    #[test]
    fn histogram_quantiles_order_and_merge_exactly() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 1..=50 {
            a.record(i as f64 * 1e-3); // 1..50 ms
            b.record(i as f64 * 1e-2); // 10..500 ms
        }
        let (sa, sb) = (a.snapshot(), b.snapshot());
        assert!(sa.quantile(0.5) <= sa.quantile(0.95));
        assert!(sa.quantile(0.95) <= sa.quantile(0.99));
        let mut merged = sa.clone();
        merged.merge(&sb);
        assert_eq!(merged.count, 100);
        // the merged median sits between the two sources' medians
        assert!(merged.quantile(0.5) >= sa.quantile(0.5));
        assert!(merged.quantile(0.5) <= sb.quantile(0.5));
        let stat = merged.stat();
        assert_eq!(stat.count, 100);
        assert!(stat.p50 <= stat.p95 && stat.p95 <= stat.p99);
    }

    #[test]
    fn stage_histograms_fold_and_expose_breakdown_json() {
        let sh = StageHistograms::new();
        sh.record_ns(2_000_000, 0, 1_000_000, 7_000_000, 6_500_000);
        sh.record_ns(4_000_000, 0, 1_000_000, 9_000_000, 8_500_000);
        let bd = sh.breakdown();
        assert_eq!(bd.queue_wait.count, 2);
        assert!(bd.queue_wait.p50 >= 0.002 && bd.queue_wait.p50 <= 0.002 * HIST_GROWTH);
        assert!(bd.future_wait.p99 >= 0.009);
        let v = bd.scaled(10.0).to_json();
        for name in STAGE_NAMES {
            let stat = v.get(name);
            assert!(!stat.is_null(), "missing `{name}`");
            for q in ["p50", "p95", "p99", "count"] {
                assert!(!stat.get(q).is_null(), "missing `{name}.{q}`");
            }
        }
        assert_eq!(v.get("queue_wait").get("count").as_u64(), Some(2), "scale keeps counts");
        let agg = merge_breakdowns(&[sh.snapshots(), sh.snapshots()]);
        assert_eq!(agg.poll_time.count, 4, "aggregate = bucket-wise sum");
    }

    #[test]
    fn summary_to_json_has_quantile_fields() {
        let r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record_secs(i as f64);
        }
        let v = r.summary().to_json();
        for key in ["count", "avg", "p50", "p95", "p99", "max"] {
            assert!(!v.get(key).is_null(), "missing `{key}`");
        }
        assert_eq!(v.get("count").as_usize(), Some(10));
        assert_eq!(v.get("max").as_f64(), Some(10.0));
    }
}
