//! `nalar` CLI: launch deployments, run workloads, inspect the system.
//!
//! ```text
//! nalar run   --workflow financial|router|swe --system nalar|ayo|crew|autogen
//!             [--rps 8] [--secs 5] [--config path.json]
//! nalar info  [--config path.json]      # validate + describe a deployment
//! ```

use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::config::DeploymentConfig;
use nalar::server::Deployment;
use nalar::util::cli::Args;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};

fn parse_system(s: &str) -> SystemUnderTest {
    match s {
        "ayo" => SystemUnderTest::AyoLike,
        "crew" => SystemUnderTest::CrewLike,
        "autogen" => SystemUnderTest::AutoGenLike,
        _ => SystemUnderTest::Nalar,
    }
}

fn parse_workflow(s: &str) -> WorkflowKind {
    match s {
        "router" => WorkflowKind::Router,
        "swe" => WorkflowKind::Swe,
        _ => WorkflowKind::Financial,
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!("usage: nalar <run|info> [--workflow financial|router|swe] [--system nalar|ayo|crew|autogen] [--rps N] [--secs N] [--config file.json]");
            Ok(())
        }
    }
}

fn load_config(args: &Args, wf: WorkflowKind) -> anyhow::Result<DeploymentConfig> {
    Ok(match args.get("config") {
        Some(path) => DeploymentConfig::from_json_file(path)?,
        None => wf.config(),
    })
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "financial"));
    let system = parse_system(&args.str_or("system", "nalar"));
    let cfg = load_config(args, wf)?;
    let scale = cfg.time_scale;
    let d = Deployment::launch_as(cfg, system)?;
    let rc = RunConfig {
        workflow: wf,
        rps: args.f64_or("rps", 8.0),
        duration: Duration::from_secs(args.u64_or("secs", 5)),
        session_pool: args.usize_or("sessions", 32),
        request_timeout: Duration::from_secs(args.u64_or("timeout", 60)),
        seed: args.u64_or("seed", 7),
    };
    println!(
        "running {} on {} at {} wall-RPS for {:?} (time_scale {})",
        wf.name(),
        system.name(),
        rc.rps,
        rc.duration,
        scale
    );
    let (stats, rec) = run_open_loop(&d, &rc);
    let paper = rec.summary_scaled(1.0 / stats.time_scale);
    println!(
        "completed {} failed {} | paper-s avg {:.1} p50 {:.1} p95 {:.1} p99 {:.1} | imbalance {:.2}x",
        stats.completed, stats.failed, paper.avg, paper.p50, paper.p95, paper.p99, stats.imbalance
    );
    d.shutdown();
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let wf = parse_workflow(&args.str_or("workflow", "financial"));
    let cfg = load_config(args, wf)?;
    println!("nodes: {}  time_scale: {}  policies: {:?}", cfg.nodes, cfg.time_scale, cfg.policies);
    for a in &cfg.agents {
        println!(
            "  {:<16} {:?} x{}  stateful={} batchable={} managed_state={} max={}",
            a.name,
            a.kind,
            a.instances,
            a.directives.stateful,
            a.directives.batchable,
            a.directives.managed_state,
            a.directives.max_instances
        );
    }
    Ok(())
}
