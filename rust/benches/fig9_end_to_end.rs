//! Figure 9 reproduction: end-to-end latency vs request rate, for all
//! three workflows × {NALAR, Ayo-like, CrewAI-like, AutoGen-like}.
//!
//! Thin wrapper over [`nalar::bench::fig9`] — the same code path as
//! `nalar bench --only fig9`. Prints the per-cell table and writes a
//! schema-validated `BENCH_fig9.json` in the working directory.
//!
//! `NALAR_BENCH_QUICK=1` runs the CI-smoke profile; `NALAR_BENCH_FULL=1`
//! extends the measurement windows.

use std::path::Path;

fn main() {
    let quick = std::env::var("NALAR_BENCH_QUICK").is_ok();
    let report = nalar::bench::fig9(quick).expect("fig9 reproduction failed");
    nalar::bench::validate(&report).expect("fig9 report schema");
    let path = nalar::bench::write_report(Path::new("."), "fig9", &report).expect("write report");
    println!("wrote {}", path.display());
}
