//! Deterministic multi-tenant fairness suite for the ingress front door:
//! weighted-fair (DRR) sub-queues + per-tenant token buckets, proven on
//! the PR-4 testkit (virtual clock + scripted engine) rather than hoped
//! for under timing. Companion property tests (bounded DRR unfairness,
//! per-tenant bucket isolation) live in `tests/props.rs`.
//!
//! The headline A/B test replays one seeded noisy-neighbor trace twice —
//! identical arrivals, identical service costs, identical deadlines —
//! differing ONLY in whether the front door has the two-tenant DRR table
//! or the single shared queue, and shows the single queue starving the
//! meek tenant past its deadlines while DRR holds the meek tenant's
//! completions at exactly its weight share of capacity.

use std::time::{Duration, Instant};

use nalar::config::TenantSettings;
use nalar::error::Error;
use nalar::ids::TenantId;
use nalar::ingress::{
    AdmissionPolicy, Ingress, SchedulePolicy, SchedulerOpts, SubmitRequest, Ticket,
};
use nalar::server::Deployment;
use nalar::testkit::{Clock, Gate, ScriptedEngine};
use nalar::workflow::WorkflowKind;

const HOG: usize = 0;
const MEEK: usize = 1;

/// Router deployment with an explicit tenant table (empty = the
/// pre-tenancy single shared queue). Capacity policies stay out — a
/// reallocation kill would fail futures retryably, which is orthogonal
/// to queue fairness.
fn fairness_deployment(tenants: &[(&str, f64)]) -> Deployment {
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = 0.0005;
    cfg.control.global_period_ms = 10;
    cfg.policies = vec!["load_balance".into()];
    cfg.ingress.tenants = tenants
        .iter()
        .map(|(name, weight)| TenantSettings {
            name: name.to_string(),
            weight: *weight,
            ..TenantSettings::default()
        })
        .collect();
    Deployment::launch(cfg).unwrap()
}

/// Block (wall clock, bounded) until `cond` holds — scheduler bookkeeping
/// runs on worker threads, so gauges settle an instant after fulfilment.
fn settle(what: &str, cond: impl Fn() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < Duration::from_secs(5), "timed out settling: {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// The no-leak invariant every fairness path must restore: scheduler
/// tables (including every per-tenant sub-queue) empty, and the future
/// table's per-request index fully evicted, once all tickets are
/// terminal.
fn assert_drained(d: &Deployment, ing: &Ingress, wf: WorkflowKind) {
    settle("scheduler tables drain", || {
        let m = ing.metrics(wf).unwrap();
        m.in_flight == 0 && m.depth == 0 && m.tenants.iter().all(|t| t.depth == 0)
    });
    settle("per-request future index evicts", || d.table().request_index_len() == 0);
}

/// Per-logical-tenant outcome of one trace run (client-side attribution,
/// so the single-queue arm — whose server collapses tenant names — is
/// counted on the same axis as the DRR arm).
#[derive(Debug, Default, PartialEq, Eq)]
struct TraceOutcome {
    completed: [u64; 2],
    missed: [u64; 2],
}

/// One seeded noisy-neighbor trace (virtual time; submitted as one burst
/// at t=0 behind a gate, so both arms pop from an identical 44-deep
/// backlog; one scripted call per request priced at exactly 2 virtual
/// seconds by the pump; workers=1 and max_in_flight=1 make the queue
/// discipline the only variable):
///
/// * arrivals: 4 blocks of [10 hog requests, then 1 meek request] — the
///   hog offers 10x the meek tenant's rate at equal weights;
/// * every request: deadline 31 virtual seconds. With 2s service, the
///   deadline window holds exactly 15 completions (t = 2, 4, …, 30);
///   the 16th to start expires mid-flight and everything still queued is
///   swept as expired-in-queue.
///
/// **Single queue (tenancy=false)** serves arrival order: the meek
/// requests sit at positions 10, 21, 32, 43, so only the first (t=22)
/// beats the deadline — the hog's backlog starves meek 3-of-4:
///
/// | tenant | offered | completed | missed |
/// |--------|---------|-----------|--------|
/// | hog    | 40      | 14        | 26     |
/// | meek   | 4       | 1         | 3      |
///
/// **DRR (tenancy=true, equal weights)** alternates sub-queues while
/// both are backlogged, so every meek request is served by t=14 — within
/// ±1 of its weight share (min(4 offered, 15/2) = 4) — and the hog
/// absorbs the entire overload it created:
///
/// | tenant | offered | completed | missed |
/// |--------|---------|-----------|--------|
/// | hog    | 40      | 11        | 29     |
/// | meek   | 4       | 4         | 0      |
fn run_noisy_neighbor_trace(tenancy: bool) -> TraceOutcome {
    let tenants: &[(&str, f64)] = if tenancy { &[("hog", 1.0), ("meek", 1.0)] } else { &[] };
    let d = fairness_deployment(tenants);
    let (clock, vclock) = Clock::manual();
    let mut opts = SchedulerOpts::new(1, 1);
    opts.schedule = Some(SchedulePolicy::Fifo); // within-tenant order
    opts.clock = clock;
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let gate = Gate::new();
    let blocker = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.gated_driver("blocker", 0, gate.clone()))
                .deadline(Duration::from_secs(100_000)),
        )
        .unwrap();
    settle("blocker holds the worker", || ing.in_flight(WorkflowKind::Router) == 1);
    let deadline = Duration::from_secs(31); // virtual seconds
    let mut tickets: Vec<(Ticket, usize)> = Vec::new();
    for block in 0..4 {
        for i in 0..10 {
            let t = ing
                .submit(
                    SubmitRequest::workflow(WorkflowKind::Router)
                        .driver(eng.driver(&format!("hog-{block}-{i}"), 1))
                        .deadline(deadline)
                        .tenant("hog"),
                )
                .unwrap();
            tickets.push((t, HOG));
        }
        let t = ing
            .submit(
                SubmitRequest::workflow(WorkflowKind::Router)
                    .driver(eng.driver(&format!("meek-{block}"), 1))
                    .deadline(deadline)
                    .tenant("meek"),
            )
            .unwrap();
        tickets.push((t, MEEK));
    }
    assert_eq!(ing.depth(WorkflowKind::Router), 44, "whole trace queued before service starts");
    if tenancy {
        assert_eq!(tickets[0].0.tenant, TenantId(HOG as u64));
        assert_eq!(tickets[10].0.tenant, TenantId(MEEK as u64));
    } else {
        // single-queue arm: the names collapse onto the implicit tenant
        assert_eq!(tickets[10].0.tenant, TenantId(0));
    }
    gate.open();
    // The pump: every started request's single call costs exactly 2
    // virtual seconds; whatever the clock leaves behind in the queues,
    // the sweep expires.
    let mut n = 0;
    while eng.wait_created(n + 1, Duration::from_secs(3)) {
        vclock.advance(Duration::from_secs(2));
        eng.cell(n).resolve(nalar::json!(n as i64), 0);
        n += 1;
    }
    blocker.wait(Duration::from_secs(5)).unwrap();
    let mut out = TraceOutcome::default();
    for (i, (t, tenant)) in tickets.iter().enumerate() {
        match t.wait(Duration::from_secs(5)) {
            Ok(_) => out.completed[*tenant] += 1,
            Err(Error::Deadline(_)) => out.missed[*tenant] += 1,
            Err(e) => panic!("request {i}: unexpected terminal outcome {e}"),
        }
    }
    if tenancy {
        // the server-side per-tenant telemetry must agree with the
        // client-side attribution
        let m = ing.metrics(WorkflowKind::Router).unwrap();
        let hog = m.tenants.iter().find(|t| t.tenant == "hog").unwrap();
        let meek = m.tenants.iter().find(|t| t.tenant == "meek").unwrap();
        assert_eq!(hog.accepted, 41, "40 hog requests + the blocker");
        assert_eq!(meek.accepted, 4);
        assert_eq!(hog.completed, out.completed[HOG] + 1, "+1: the blocker");
        assert_eq!(meek.completed, out.completed[MEEK]);
        assert_eq!(meek.expired_in_queue, 0, "DRR never lets meek expire in queue");
        assert_eq!(
            hog.expired_in_queue + hog.failed,
            out.missed[HOG],
            "hog misses split between swept-in-queue and started-then-expired"
        );
        assert_eq!(meek.cancelled + hog.cancelled, 0);
    }
    assert_drained(&d, &ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
    out
}

/// The headline A/B: same trace, single queue vs DRR — FIFO starves the
/// meek tenant past its deadlines, DRR holds it within ±1 request of its
/// weight share, and fairness costs no capacity (15 completions in both
/// arms).
#[test]
fn seeded_ab_trace_drr_unstarves_the_meek_tenant() {
    let fifo = run_noisy_neighbor_trace(false);
    let drr = run_noisy_neighbor_trace(true);
    // single shared queue: the hog's backlog pushes meek past its
    // deadlines (the documented 14/1 vs 26/3 table)
    assert_eq!(fifo.completed[HOG], 14);
    assert_eq!(fifo.completed[MEEK], 1, "single queue: meek starves");
    assert_eq!(fifo.missed[MEEK], 3, "3 of 4 meek requests miss their deadlines");
    assert_eq!(fifo.missed[HOG], 26);
    // DRR at equal weights: meek's fair share of the 15 servable slots
    // is min(4 offered, 7.5) = 4 — within ±1 of which it must land
    // (exactly 4 on this deterministic trace), with zero misses.
    assert_eq!(drr.missed[MEEK], 0, "DRR: no meek request misses its deadline");
    let share = 4i64;
    let got = drr.completed[MEEK] as i64;
    assert!((got - share).abs() <= 1, "meek completions {got} not within ±1 of share {share}");
    assert_eq!(drr.completed[MEEK], 4);
    assert_eq!(drr.completed[HOG], 11, "the hog absorbs the overload it created");
    // fairness is not free capacity: both disciplines fill all 15 slots
    assert_eq!(
        fifo.completed[HOG] + fifo.completed[MEEK],
        drr.completed[HOG] + drr.completed[MEEK],
        "DRR must be work-conserving"
    );
}

/// Weighted DRR at 3:1, both tenants fully backlogged with equal offered
/// load: the exact deterministic service order follows the quanta —
/// tenant `a` takes 3 slots per rotation, `b` takes 1 — and total
/// completions track the weights.
#[test]
fn weighted_drr_follows_the_three_to_one_quanta() {
    let d = fairness_deployment(&[("a", 3.0), ("b", 1.0)]);
    let (clock, vclock) = Clock::manual();
    let mut opts = SchedulerOpts::new(1, 1);
    opts.schedule = Some(SchedulePolicy::Fifo);
    opts.clock = clock;
    let ing =
        Ingress::start_with_opts(&d, &[WorkflowKind::Router], AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let gate = Gate::new();
    let long = Duration::from_secs(100_000);
    // The blocker rides tenant `a`'s sub-queue (tenant None = index 0);
    // its pop empties that sub-queue, so `a` forfeits the rest of its
    // first granted quantum (the DRR empty-queue rule).
    let blocker = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.gated_driver("blocker", 0, gate.clone()))
                .deadline(long),
        )
        .unwrap();
    settle("blocker holds the worker", || ing.in_flight(WorkflowKind::Router) == 1);
    let mut tickets = Vec::new();
    for i in 0..8 {
        for name in ["a", "b"] {
            let t = ing
                .submit(
                    SubmitRequest::workflow(WorkflowKind::Router)
                        .driver(eng.driver(&format!("{name}{i}"), 1))
                        .deadline(long)
                        .tenant(name),
                )
                .unwrap();
            tickets.push(t);
        }
    }
    assert_eq!(ing.depth(WorkflowKind::Router), 16);
    gate.open();
    let mut n = 0;
    while eng.wait_created(n + 1, Duration::from_secs(3)) {
        vclock.advance(Duration::from_secs(2));
        eng.cell(n).resolve(nalar::json!(n as i64), 0);
        n += 1;
    }
    for t in &tickets {
        t.wait(Duration::from_secs(5)).unwrap();
    }
    // Quanta 3:1. The blocker's pop emptied `a`'s sub-queue, forfeiting
    // the rest of `a`'s first grant — so the rotation moves to `b` first
    // (b0); from there full rotations serve [a a a b] until `a` drains
    // (forfeiting again at a7), after which `b` gets every slot — the
    // DRR service order, end to end, exactly.
    assert_eq!(
        eng.completions(),
        vec![
            "blocker", "b0", "a0", "a1", "a2", "b1", "a3", "a4", "a5", "b2", "a6", "a7", "b3",
            "b4", "b5", "b6", "b7"
        ],
        "service must follow the 3:1 quanta with empty-queue forfeits"
    );
    assert_drained(&d, &ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}

/// Lifecycle x tenancy: a cancel drains the right sub-queue, charges the
/// right tenant's `cancelled` counter, and leaves neither a scheduler
/// table entry nor a per-request future index entry behind.
#[test]
fn cancel_debits_the_cancelling_tenants_sub_queue_only() {
    let d = fairness_deployment(&[("hog", 1.0), ("meek", 1.0)]);
    let ing = Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router],
        AdmissionPolicy::Unbounded,
        SchedulerOpts::new(1, 1),
    );
    let eng = ScriptedEngine::new();
    let gate = Gate::new();
    let long = Duration::from_secs(1000);
    let blocker = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.gated_driver("blocker", 0, gate.clone()))
                .deadline(long),
        )
        .unwrap();
    settle("blocker occupies the slot", || ing.in_flight(WorkflowKind::Router) == 1);
    let hog_keep = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("hog-keep", 1))
                .deadline(long)
                .tenant("hog"),
        )
        .unwrap();
    let hog_doomed = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("hog-doomed", 1))
                .deadline(long)
                .tenant("hog"),
        )
        .unwrap();
    let meek = ing
        .submit(
            SubmitRequest::workflow(WorkflowKind::Router)
                .driver(eng.driver("meek-0", 1))
                .deadline(long)
                .tenant("meek"),
        )
        .unwrap();
    assert_eq!(ing.depth(WorkflowKind::Router), 3);
    assert!(hog_doomed.cancel(), "queued request must be cancellable");
    assert_eq!(ing.depth(WorkflowKind::Router), 2, "cancel drains its sub-queue entry at once");
    assert!(matches!(hog_doomed.wait(Duration::from_secs(5)), Err(Error::Cancelled)));
    gate.open();
    // the two survivors complete (the cancelled driver never issues its
    // call, so cells are created in service order)
    let mut n = 0;
    while eng.wait_created(n + 1, Duration::from_secs(3)) {
        eng.cell(n).resolve(nalar::json!(n as i64), 0);
        n += 1;
    }
    blocker.wait(Duration::from_secs(5)).unwrap();
    hog_keep.wait(Duration::from_secs(5)).unwrap();
    meek.wait(Duration::from_secs(5)).unwrap();
    let m = ing.metrics(WorkflowKind::Router).unwrap();
    let hog = m.tenants.iter().find(|t| t.tenant == "hog").unwrap();
    let meek_m = m.tenants.iter().find(|t| t.tenant == "meek").unwrap();
    assert_eq!(hog.cancelled, 1, "the cancel lands on the cancelling tenant");
    assert_eq!(meek_m.cancelled, 0, "the innocent tenant is untouched");
    assert_eq!(hog.completed, 2, "hog-keep + the blocker");
    assert_eq!(meek_m.completed, 1);
    assert_eq!(hog.failed + meek_m.failed, 0);
    assert_drained(&d, &ing, WorkflowKind::Router);
    ing.stop();
    d.shutdown();
}
