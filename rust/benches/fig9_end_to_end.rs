//! Figure 9 reproduction: end-to-end latency vs request rate, for all
//! three workflows × {NALAR, Ayo-like, CrewAI-like, AutoGen-like}.
//!
//! Prints one row per (workflow, system, rate) with avg/P50/P95/P99 in
//! paper-equivalent seconds plus failures and load imbalance — the same
//! cells the paper's bars+whiskers encode.
//!
//! Rates are paper-RPS *for this testbed's capacity*; the paper's absolute
//! axis (2-8 / 20-80 RPS on 8xA100) maps to our emulated capacity as
//! documented in EXPERIMENTS.md. `NALAR_BENCH_FULL=1` runs longer windows.

use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::server::Deployment;
use nalar::util::bench::Table;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};

fn full() -> bool {
    std::env::var("NALAR_BENCH_FULL").is_ok()
}

fn main() {
    let secs = if full() { 10 } else { 4 };
    // (workflow, wall-RPS grid). time_scale = 0.01 => paper-RPS = wall/100.
    let plan: [(WorkflowKind, &[f64]); 3] = [
        (WorkflowKind::Financial, &[40.0, 80.0, 120.0, 160.0]),
        (WorkflowKind::Router, &[120.0, 240.0, 360.0, 480.0]),
        (WorkflowKind::Swe, &[20.0, 40.0, 60.0, 80.0]),
    ];

    for (wf, rates) in plan {
        println!("\n=== Fig 9{} — {} workflow ===", match wf {
            WorkflowKind::Financial => 'a',
            WorkflowKind::Router => 'b',
            WorkflowKind::Swe => 'c',
        }, wf.name());
        let mut table = Table::new(&[
            "system", "rate", "avg(s)", "p50(s)", "p95(s)", "p99(s)", "ok", "fail", "imbalance",
        ]);
        for &rps in rates {
            for system in SystemUnderTest::all() {
                let cfg = wf.config();
                let d = Deployment::launch_as(cfg, system).expect("launch");
                let rc = RunConfig {
                    workflow: wf,
                    rps,
                    duration: Duration::from_secs(secs),
                    session_pool: 48,
                    request_timeout: Duration::from_secs(6),
                    seed: 0xF19,
                };
                let (stats, rec) = run_open_loop(&d, &rc);
                let paper = rec.summary_scaled(1.0 / stats.time_scale);
                table.row(&[
                    system.name().to_string(),
                    format!("{:.1}", rps * stats.time_scale),
                    format!("{:.0}", paper.avg),
                    format!("{:.0}", paper.p50),
                    format!("{:.0}", paper.p95),
                    format!("{:.0}", paper.p99),
                    stats.completed.to_string(),
                    stats.failed.to_string(),
                    format!("{:.2}", stats.imbalance),
                ]);
                d.shutdown();
            }
        }
        table.print();
    }
}
