//! `managedList` / `managedDict` — session-scoped state in the node store.

use std::sync::Arc;

use crate::futures::Value;
use crate::ids::SessionId;
use crate::nodestore::{keys, NodeStore};
use crate::util::json::Map;

/// A session-bound list stored in the node store. Used like an ordinary
/// list; the framework owns placement, consistency and lifetime.
#[derive(Clone)]
pub struct ManagedList {
    store: Arc<NodeStore>,
    key: String,
}

impl ManagedList {
    /// Bind (creating if absent) the list `name` for `session` on the local
    /// node store. Component controllers call this when materializing state
    /// for a request (paper: "reconstructs the appropriate managed lists").
    pub fn bind(store: Arc<NodeStore>, session: SessionId, name: &str) -> Self {
        let key = keys::session_state(session, name);
        ManagedList { store, key }
    }

    pub fn push(&self, v: Value) {
        self.store.update(&self.key, Vec::<Value>::new(), |l| l.push(v));
    }

    pub fn get(&self, idx: usize) -> Option<Value> {
        self.snapshot().get(idx).cloned()
    }

    pub fn set(&self, idx: usize, v: Value) -> bool {
        let mut ok = false;
        self.store.update(&self.key, Vec::<Value>::new(), |l| {
            if idx < l.len() {
                l[idx] = v;
                ok = true;
            }
        });
        ok
    }

    pub fn len(&self) -> usize {
        self.snapshot().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> Vec<Value> {
        self.store
            .get::<Vec<Value>>(&self.key)
            .map(|a| (*a).clone())
            .unwrap_or_default()
    }
}

/// A session-bound dictionary in the node store.
#[derive(Clone)]
pub struct ManagedDict {
    store: Arc<NodeStore>,
    key: String,
}

impl ManagedDict {
    pub fn bind(store: Arc<NodeStore>, session: SessionId, name: &str) -> Self {
        let key = keys::session_state(session, name);
        ManagedDict { store, key }
    }

    pub fn insert(&self, k: &str, v: Value) {
        let k = k.to_string();
        self.store.update(&self.key, Map::new(), |m| {
            m.insert(k, v);
        });
    }

    pub fn get(&self, k: &str) -> Option<Value> {
        self.store
            .get::<Map>(&self.key)
            .and_then(|m| m.get(k).cloned())
    }

    pub fn remove(&self, k: &str) -> bool {
        let k = k.to_string();
        let mut removed = false;
        self.store.update(&self.key, Map::new(), |m| {
            removed = m.remove(&k).is_some();
        });
        removed
    }

    pub fn contains(&self, k: &str) -> bool {
        self.get(k).is_some()
    }

    pub fn len(&self) -> usize {
        self.store.get::<Map>(&self.key).map(|m| m.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> Map {
        self.store
            .get::<Map>(&self.key)
            .map(|a| (*a).clone())
            .unwrap_or_default()
    }
}

/// Relocate every `state/{session}/*` entry from `src` to `dst` (Fig. 8
/// step 5). Returns `(entries_moved, approx_bytes)` — the byte estimate
/// feeds the migration cost model.
pub fn migrate_session_state(src: &NodeStore, dst: &NodeStore, session: SessionId) -> (usize, u64) {
    let prefix = keys::session_prefix(session);
    let mut moved = 0usize;
    let mut bytes = 0u64;
    // lists
    for (k, v) in src.scan::<Vec<Value>>(&prefix) {
        bytes += v.iter().map(|x| estimate_bytes(x) as u64).sum::<u64>();
        dst.put_arc(&k, v);
        src.remove(&k);
        moved += 1;
    }
    // dicts
    for (k, v) in src.scan::<Map>(&prefix) {
        bytes += v
            .iter()
            .map(|(k2, v2)| (k2.len() + estimate_bytes(v2)) as u64)
            .sum::<u64>();
        dst.put_arc(&k, v);
        src.remove(&k);
        moved += 1;
    }
    (moved, bytes)
}

/// Rough wire-size estimate of a JSON value (migration cost model).
pub fn estimate_bytes(v: &Value) -> usize {
    match v {
        Value::Null => 4,
        Value::Bool(_) => 1,
        Value::Num(_) => 8,
        Value::Str(s) => s.len(),
        Value::Arr(a) => a.iter().map(estimate_bytes).sum(),
        Value::Obj(o) => o.iter().map(|(k, v)| k.len() + estimate_bytes(v)).sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn store() -> Arc<NodeStore> {
        Arc::new(NodeStore::new())
    }

    #[test]
    fn list_like_a_list() {
        let s = store();
        let l = ManagedList::bind(s.clone(), SessionId(1), "drafts");
        assert!(l.is_empty());
        l.push(json!("draft-0"));
        l.push(json!("draft-1"));
        assert_eq!(l.len(), 2);
        assert_eq!(l.get(0), Some(json!("draft-0")));
        assert!(l.set(1, json!("draft-1b")));
        assert!(!l.set(5, json!("nope")));
        assert_eq!(l.snapshot(), vec![json!("draft-0"), json!("draft-1b")]);
    }

    #[test]
    fn dict_like_a_dict() {
        let s = store();
        let d = ManagedDict::bind(s.clone(), SessionId(1), "docs");
        d.insert("oauth", json!({"hits": 3}));
        assert!(d.contains("oauth"));
        assert_eq!(d.get("oauth").unwrap().get("hits").as_i64(), Some(3));
        assert!(d.remove("oauth"));
        assert!(!d.remove("oauth"));
        assert!(d.is_empty());
    }

    #[test]
    fn sessions_isolated() {
        let s = store();
        let a = ManagedList::bind(s.clone(), SessionId(1), "x");
        let b = ManagedList::bind(s.clone(), SessionId(2), "x");
        a.push(json!(1));
        assert_eq!(b.len(), 0);
    }

    #[test]
    fn concurrent_pushes_all_land() {
        let s = store();
        let mut joins = vec![];
        for t in 0..4 {
            let l = ManagedList::bind(s.clone(), SessionId(5), "shared");
            joins.push(std::thread::spawn(move || {
                for i in 0..100 {
                    l.push(json!(t * 1000 + i));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(
            ManagedList::bind(s, SessionId(5), "shared").len(),
            400,
            "update() must be atomic RMW"
        );
    }

    #[test]
    fn migration_moves_everything() {
        let src = store();
        let dst = store();
        let l = ManagedList::bind(src.clone(), SessionId(9), "traces");
        l.push(json!("t1"));
        let d = ManagedDict::bind(src.clone(), SessionId(9), "cache");
        d.insert("k", json!("v"));
        // unrelated session untouched
        ManagedList::bind(src.clone(), SessionId(8), "other").push(json!(0));

        let (moved, bytes) = migrate_session_state(&src, &dst, SessionId(9));
        assert_eq!(moved, 2);
        assert!(bytes > 0);
        // rebinding at the destination sees the data (transparent to devs)
        let l2 = ManagedList::bind(dst.clone(), SessionId(9), "traces");
        assert_eq!(l2.get(0), Some(json!("t1")));
        assert!(!src.contains(&keys::session_state(SessionId(9), "traces")));
        assert!(src.contains(&keys::session_state(SessionId(8), "other")));
    }
}
