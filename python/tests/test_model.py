"""L2 correctness: the transformer trunk, prefill/decode consistency, embed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    ModelConfig,
    decode,
    embed,
    flat_params,
    init_params,
    param_spec,
    prefill,
)

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig()
PARAMS = init_params(CFG)


def _prompt_batch(texts):
    b = len(texts)
    tokens = np.full((b, CFG.max_seq), CFG.PAD, np.int32)
    lengths = np.zeros((b,), np.int32)
    for i, s in enumerate(texts):
        ids = [CFG.BOS] + list(s.encode())[: CFG.max_seq - 1]
        tokens[i, : len(ids)] = ids
        lengths[i] = len(ids)
    return jnp.asarray(tokens), jnp.asarray(lengths)


class TestShapes:
    def test_prefill_shapes(self):
        tokens, length = _prompt_batch(["hello", "hi"])
        logits, kv = prefill(PARAMS, tokens, length, CFG)
        assert logits.shape == (2, CFG.vocab)
        assert kv.shape == (CFG.n_layers, 2, 2, CFG.n_heads, CFG.max_seq, CFG.head_dim)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_decode_shapes(self):
        tokens, length = _prompt_batch(["abc"])
        logits, kv = prefill(PARAMS, tokens, length, CFG)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits2, kv2 = decode(PARAMS, nxt, length, kv, CFG)
        assert logits2.shape == (1, CFG.vocab)
        assert kv2.shape == kv.shape

    def test_embed_unit_norm(self):
        tokens, length = _prompt_batch(["market analysis", "q"])
        e = embed(PARAMS, tokens, length, CFG)
        assert e.shape == (2, CFG.d_model)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(e), axis=-1), 1.0, rtol=1e-5)


class TestConsistency:
    """The invariant that makes the Rust engine's incremental decoding valid:
    decode over the prefill KV must equal a longer prefill."""

    def test_decode_matches_extended_prefill(self):
        tokens, length = _prompt_batch(["the quick brown fox", "pay"])
        logits, kv = prefill(PARAMS, tokens, length, CFG)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        step_logits, _ = decode(PARAMS, nxt, length, kv, CFG)

        ext = tokens
        for i in range(2):
            ext = ext.at[i, int(length[i])].set(int(nxt[i]))
        full_logits, _ = prefill(PARAMS, ext, length + 1, CFG)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full_logits), rtol=3e-4, atol=3e-4
        )

    def test_multi_step_decode_consistency(self):
        tokens, length = _prompt_batch(["ab"])
        logits, kv = prefill(PARAMS, tokens, length, CFG)
        pos = length
        ext = tokens
        for _ in range(4):
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            ext = ext.at[0, int(pos[0])].set(int(nxt[0]))
            logits, kv = decode(PARAMS, nxt, pos, kv, CFG)
            pos = pos + 1
        full_logits, _ = prefill(PARAMS, ext, pos, CFG)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits), rtol=1e-3, atol=1e-3
        )

    def test_pallas_matches_ref_trunk(self):
        tokens, length = _prompt_batch(["compare paths", "x"])
        lp, kvp = prefill(PARAMS, tokens, length, CFG, use_pallas=True)
        lr, kvr = prefill(PARAMS, tokens, length, CFG, use_pallas=False)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lr), rtol=2e-4, atol=2e-4)

    def test_padding_invariance(self):
        # logits must not depend on what sits in the PAD region
        tokens, length = _prompt_batch(["stable"])
        noisy = tokens.at[0, int(length[0]) :].set(77)
        l1, _ = prefill(PARAMS, tokens, length, CFG)
        l2, _ = prefill(PARAMS, noisy, length, CFG)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


class TestParams:
    def test_param_spec_order_stable(self):
        names = [n for n, _ in param_spec(CFG)]
        assert names[0] == "tok_emb" and names[-1] == "ln_f"
        assert len(names) == len(set(names))

    def test_flat_params_roundtrip(self):
        flat = flat_params(PARAMS, CFG)
        assert len(flat) == len(list(param_spec(CFG)))
        for arr, (_, shape) in zip(flat, param_spec(CFG)):
            assert arr.shape == shape

    def test_init_deterministic(self):
        p2 = init_params(CFG, seed=0)
        for k in PARAMS:
            np.testing.assert_array_equal(np.asarray(PARAMS[k]), np.asarray(p2[k]))

    def test_different_seed_differs(self):
        p2 = init_params(CFG, seed=1)
        assert not np.allclose(np.asarray(PARAMS["tok_emb"]), np.asarray(p2["tok_emb"]))


@settings(max_examples=8, deadline=None)
@given(st.lists(st.text(alphabet=st.characters(codec="ascii"), min_size=1, max_size=40), min_size=1, max_size=2))
def test_embed_sweep_finite_unit(texts):
    tokens, length = _prompt_batch(texts)
    e = embed(PARAMS, tokens, length, CFG)
    assert bool(jnp.all(jnp.isfinite(e)))
    np.testing.assert_allclose(np.linalg.norm(np.asarray(e), axis=-1), 1.0, rtol=1e-4)
