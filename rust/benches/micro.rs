//! Micro-benchmarks of the hot-path substrates (§Perf baseline numbers).
//!
//! Covers every operation on the request fast path: future create/resolve,
//! stub call end-to-end, routing, node-store ops, managed state, KV-cache
//! residency, JSON parse, and the sim-engine step machinery. Each line
//! reports mean/p50/p95/p99 via [`nalar::util::bench::Timing`]; the
//! figure-level reproductions live in `nalar bench` (`nalar::bench`).

use std::sync::Arc;
use std::time::Duration;

use nalar::coordinator::{LoadMap, Router};
use nalar::futures::{FutureCell, FutureMeta, FutureTable};
use nalar::ids::*;
use nalar::json;
use nalar::nodestore::NodeStore;
use nalar::state::kvcache::{KvCacheManager, KvPolicy};
use nalar::state::ManagedList;
use nalar::transport::Bus;
use nalar::util::bench::bench;

fn meta(id: u64) -> FutureMeta {
    FutureMeta::new(
        FutureId(id),
        SessionId(id % 64),
        RequestId(id % 256),
        AgentType::new("dev"),
        "m",
        Location::Driver(RequestId(0)),
    )
}

fn main() {
    let budget = Duration::from_millis(300);
    println!("=== micro benches (hot path) ===");

    // futures
    let mut i = 0u64;
    bench("future: create cell", 100, budget, || {
        i += 1;
        std::hint::black_box(FutureCell::new(meta(i)));
    });
    bench("future: create+resolve+read", 100, budget, || {
        i += 1;
        let c = FutureCell::new(meta(i));
        c.resolve(json!({"text": "done"}), 5);
        std::hint::black_box(c.try_value());
    });
    let table = FutureTable::new();
    bench("future table: insert+get+remove", 100, budget, || {
        i += 1;
        let c = FutureCell::new(meta(i));
        table.insert(c);
        std::hint::black_box(table.get(FutureId(i)));
        table.remove(FutureId(i));
    });

    // routing
    let bus = Bus::new(Duration::ZERO);
    let loads = LoadMap::new();
    for a in 0..8 {
        let id = InstanceId::new("dev", a);
        let _rx = Box::leak(Box::new(bus.register(id.clone(), NodeId(a % 2))));
        loads.register(id);
    }
    let router = Router::new(bus.clone(), loads, 3);
    bench("router: least-loaded route", 100, budget, || {
        i += 1;
        std::hint::black_box(router.route(SessionId(i), "dev", false).unwrap());
    });
    bench("router: sticky route (hit)", 100, budget, || {
        std::hint::black_box(router.route(SessionId(1), "dev", true).unwrap());
    });

    // node store
    let store = NodeStore::new();
    bench("nodestore: put", 100, budget, || {
        i += 1;
        store.put(&format!("k{}", i % 1024), i);
    });
    bench("nodestore: get", 100, budget, || {
        i += 1;
        std::hint::black_box(store.get::<u64>(&format!("k{}", i % 1024)));
    });
    for k in 0..256 {
        store.put(&format!("metrics/a{k}"), k as u64);
    }
    bench("nodestore: scan 256-key prefix", 20, budget, || {
        std::hint::black_box(store.scan::<u64>("metrics/"));
    });

    // managed state
    let s = Arc::new(NodeStore::new());
    let list = ManagedList::bind(s, SessionId(1), "hist");
    bench("managed list: push", 100, budget, || {
        list.push(json!(1));
    });

    // kv cache
    let kv = KvCacheManager::new(64 << 20, 512 << 20, KvPolicy::HintDriven);
    bench("kvcache: ensure_resident (hit)", 100, budget, || {
        i += 1;
        std::hint::black_box(kv.ensure_resident(SessionId(i % 16), 1 << 20, 64));
    });

    // json
    let text =
        r#"{"prompt": "analyze the market", "max_new_tokens": 96, "nested": {"a": [1,2,3]}}"#;
    bench("json: parse call args", 100, budget, || {
        std::hint::black_box(nalar::util::json::parse(text).unwrap());
    });
    let v = nalar::util::json::parse(text).unwrap();
    bench("json: serialize call args", 100, budget, || {
        std::hint::black_box(v.to_string());
    });

    // end-to-end stub call against a live instance (queue + resolve path)
    let cfg = nalar::config::DeploymentConfig::from_json(
        r#"{"time_scale": 0.00001,
            "agents": [{"name": "echo", "kind": "web_search", "instances": 2,
                        "profile": {"base_s": 0.0}, "methods": ["search"]}]}"#,
    )
    .unwrap();
    let d = nalar::server::Deployment::launch(cfg).unwrap();
    bench("stub call -> tool exec -> resolve", 20, budget, || {
        let ctx = d.ctx(SessionId(0));
        let f = ctx.agent("echo").call("search", json!({"query": "q"}));
        std::hint::black_box(f.value(Duration::from_secs(5)).unwrap());
    });
    d.shutdown();
}
