//! Error type for the NALAR runtime.
//!
//! Per the paper's fault-tolerance stance (§5): NALAR does not mask faults;
//! failed requests are reported back to the driver with the workflow path,
//! the failing agent and the underlying cause, and the driver decides
//! whether to retry.
//!
//! The offline build has no `thiserror`/`anyhow`; `Display`, `Error` and
//! the `From` conversions are written out by hand (DESIGN.md §3).

use std::fmt;

use crate::ids::{FutureId, InstanceId};

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    /// `(future, failing instance, cause)`.
    FutureFailed(FutureId, InstanceId, String),
    FutureTimeout(FutureId, std::time::Duration),
    NoInstance(String),
    UnknownAgent(String),
    /// Admission control rejected the request at the ingress front door
    /// (`(workflow, reason)`). Always retryable: the request never entered
    /// the system, so the caller may back off and resubmit.
    Shed(String, String),
    /// The request's end-to-end deadline expired before (or while) a
    /// driver ran it.
    Deadline(std::time::Duration),
    /// The caller cancelled the request (`Ticket::cancel`). Terminal and
    /// NOT retryable: the caller explicitly withdrew the work, so backing
    /// off and resubmitting would resurrect what was just killed.
    Cancelled,
    InstanceKilled(InstanceId),
    Engine(String),
    Runtime(String),
    Artifact(String),
    Config(String),
    State(String),
    Io(std::io::Error),
    Json(crate::util::json::ParseError),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::FutureFailed(id, agent, cause) => {
                write!(f, "future {id} failed at {agent}: {cause}")
            }
            Error::FutureTimeout(id, after) => write!(f, "future {id} timed out after {after:?}"),
            Error::NoInstance(agent) => write!(f, "no instance available for agent type `{agent}`"),
            Error::Shed(workflow, reason) => {
                write!(f, "request shed at ingress for `{workflow}`: {reason}")
            }
            Error::Deadline(after) => write!(f, "request deadline expired after {after:?}"),
            Error::Cancelled => write!(f, "request cancelled by the caller"),
            Error::UnknownAgent(agent) => write!(f, "unknown agent type `{agent}`"),
            Error::InstanceKilled(i) => write!(f, "instance {i} was killed"),
            Error::Engine(e) => write!(f, "engine error: {e}"),
            Error::Runtime(e) => write!(f, "runtime (PJRT) error: {e}"),
            Error::Artifact(e) => write!(f, "artifact error: {e}"),
            Error::Config(e) => write!(f, "config error: {e}"),
            Error::State(e) => write!(f, "state error: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(e) => write!(f, "json: {e}"),
            Error::Msg(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Self {
        Error::Json(e)
    }
}

impl From<crate::runtime::xla::Error> for Error {
    fn from(e: crate::runtime::xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error::Msg(s.into())
    }

    /// True when the driver may meaningfully retry (per-§5 semantics).
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            Error::FutureFailed(..)
                | Error::FutureTimeout(..)
                | Error::InstanceKilled(..)
                | Error::NoInstance(..)
                | Error::Shed(..)
                | Error::Deadline(..)
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryable_classification() {
        assert!(Error::FutureTimeout(FutureId(1), std::time::Duration::from_secs(1)).retryable());
        assert!(Error::NoInstance("x".into()).retryable());
        assert!(Error::Shed("router".into(), "queue full".into()).retryable());
        assert!(Error::Deadline(std::time::Duration::from_secs(3)).retryable());
        assert!(!Error::Cancelled.retryable(), "a cancel must not invite a resubmit");
        assert!(!Error::Config("bad".into()).retryable());
        assert!(!Error::Engine("x".into()).retryable());
    }

    #[test]
    fn display_includes_context() {
        let e = Error::FutureFailed(FutureId(7), InstanceId::new("dev", 1), "oom".into());
        let s = e.to_string();
        assert!(s.contains("f7") && s.contains("dev:1") && s.contains("oom"));
    }

    #[test]
    fn io_and_json_sources_chain() {
        let io = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(std::error::Error::source(&io).is_some());
        assert!(io.to_string().contains("gone"));
        let js = Error::from(crate::util::json::parse("{").unwrap_err());
        assert!(js.to_string().contains("json"));
    }
}
