//! Integration tests for the event-driven ingress scheduler: in-flight
//! requests are stored continuations, so a small fixed thread pool must
//! carry far more concurrent requests than it has threads, and a stalled
//! agent type must park its requests without wedging unrelated work.

use std::time::{Duration, Instant};

use nalar::config::DeploymentConfig;
use nalar::ingress::{AdmissionPolicy, Ingress, SchedulerOpts, Ticket};
use nalar::json;
use nalar::server::Deployment;
use nalar::workflow::WorkflowKind;

/// ≥512 concurrent in-flight requests on a 4-thread scheduler: every
/// admitted request completes. Under the old one-request-per-thread pool
/// this workload would need 512 OS threads (or serialize 128-deep per
/// thread); with resumable drivers 4 threads multiplex the whole set.
#[test]
fn four_threads_complete_512_concurrent_requests() {
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = 0.002;
    cfg.control.global_period_ms = 10;
    // Keep the capacity policies out of this test: a reallocation kill
    // would fail futures retryably, which is orthogonal to what is being
    // proven here (thread-decoupled completion).
    cfg.policies = vec!["load_balance".into()];
    let d = Deployment::launch(cfg).unwrap();
    let ing = Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router],
        AdmissionPolicy::Unbounded,
        SchedulerOpts { workers: 4, max_in_flight: 1024 },
    );
    let timeout = Duration::from_secs(120);
    let tickets: Vec<Ticket> = (0..512)
        .map(|i| {
            let class = if i % 4 == 0 { "coder" } else { "chat" };
            ing.submit(
                WorkflowKind::Router,
                None,
                json!({"prompt": "multiplex me", "class": class}),
                timeout,
            )
            .unwrap()
        })
        .collect();
    // All 512 were admitted before the workload can drain: the scheduler
    // is carrying far more live requests than it has threads.
    let m = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!(m.workers, 4);
    assert!(
        m.in_flight + m.depth > 4 * m.workers,
        "in-flight ({}) + queued ({}) should dwarf {} threads right after the burst",
        m.in_flight,
        m.depth,
        m.workers
    );
    for t in &tickets {
        t.wait(timeout).unwrap();
    }
    let m = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!(m.accepted, 512);
    assert_eq!(m.completed, 512, "every admitted request must complete");
    assert_eq!(m.failed, 0);
    assert_eq!(m.expired_in_queue, 0);
    assert_eq!(m.in_flight, 0, "drained");
    ing.stop();
    d.shutdown();
}

/// Two workflows behind one 2-thread front door; the chat agent is
/// stalled (500 paper-s per reply). The router requests park on their
/// chat futures without occupying the scheduler's threads, so the SWE
/// workflow's requests keep completing — head-of-line isolation that the
/// old thread-per-request pool could not provide (6 stalled requests
/// would have pinned both threads).
#[test]
fn stalled_agent_type_parks_without_wedging_other_workflows() {
    let cfg = DeploymentConfig::from_json(
        r#"{
  "nodes": 2,
  "time_scale": 0.001,
  "seed": 5,
  "control": {"global_period_ms": 20, "hol_threshold_ms": 120},
  "engine": {"max_batch": 8, "executor": "sim", "kv_policy": "hint"},
  "ingress": {"policy": "unbounded", "workers": 2, "max_in_flight": 64},
  "policies": ["load_balance"],
  "agents": [
    {"name": "router", "kind": "llm", "instances": 1,
     "profile": {"base_s": 0.05, "mean_output_tokens": 6, "per_output_token_s": 0.01},
     "methods": ["classify"]},
    {"name": "chat", "kind": "llm", "instances": 2,
     "profile": {"base_s": 500.0, "mean_output_tokens": 1, "per_output_token_s": 0.0},
     "methods": ["reply"]},
    {"name": "coder", "kind": "llm", "instances": 1,
     "profile": {"base_s": 0.3, "mean_output_tokens": 20, "per_output_token_s": 0.01},
     "methods": ["implement"]},
    {"name": "planner", "kind": "llm", "instances": 1,
     "profile": {"base_s": 0.3, "mean_output_tokens": 60, "per_output_token_s": 0.008},
     "methods": ["plan"]},
    {"name": "developer", "kind": "llm", "instances": 2,
     "profile": {"base_s": 0.4, "mean_output_tokens": 240, "per_output_token_s": 0.011},
     "methods": ["implement"]},
    {"name": "documentation", "kind": "vector_store", "instances": 1,
     "profile": {"base_s": 0.15},
     "methods": ["get", "add", "query"]},
    {"name": "test_harness", "kind": "test_harness", "instances": 2,
     "profile": {"base_s": 0.6},
     "failure_rate": 0.1,
     "methods": ["unit_test", "integration_test"]}
  ]
}"#,
    )
    .unwrap();
    let d = Deployment::launch(cfg).unwrap();
    let ing = Ingress::start_with_opts(
        &d,
        &[WorkflowKind::Router, WorkflowKind::Swe],
        AdmissionPolicy::Unbounded,
        SchedulerOpts { workers: 2, max_in_flight: 64 },
    );
    let long = Duration::from_secs(60);

    // 6 requests that will all stall on the chat agent (3x the thread
    // count: the old pool would be wedged solid).
    let stalled: Vec<Ticket> = (0..6)
        .map(|_| {
            ing.submit(
                WorkflowKind::Router,
                None,
                json!({"prompt": "hang", "class": "chat"}),
                long,
            )
            .unwrap()
        })
        .collect();
    // Wait until every stalled request has actually started (left the
    // admission queue) so the isolation claim is about parked work, not
    // work that merely never began.
    let t0 = Instant::now();
    while ing.in_flight(WorkflowKind::Router) < stalled.len() {
        assert!(t0.elapsed() < Duration::from_secs(10), "stalled requests never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    // An unrelated workflow must make progress on the same two threads.
    let swe: Vec<Ticket> = (0..6)
        .map(|_| {
            ing.submit(WorkflowKind::Swe, None, json!({"task": "isolate me"}), long).unwrap()
        })
        .collect();
    for t in &swe {
        t.wait(long).unwrap();
    }
    let m_swe = ing.metrics(WorkflowKind::Swe).unwrap();
    assert_eq!(m_swe.completed, 6, "swe must complete while router is stalled");
    // The stall (6 chats x 0.5s wall on 2 instances = >=1.5s of chat
    // service) must outlast the ~50ms SWE phase: stalled requests stay
    // parked, not failed, and don't hold the scheduler's threads. Avoid
    // asserting exactly-zero completions — on a badly overloaded runner a
    // first chat reply may sneak in — but all 6 finishing during the SWE
    // phase would mean the stall never happened.
    let m_router = ing.metrics(WorkflowKind::Router).unwrap();
    assert_eq!(m_router.failed, 0, "parked requests must not be failed");
    assert!(
        m_router.in_flight >= 1,
        "stalled requests must still be parked (in_flight {}, completed {})",
        m_router.in_flight,
        m_router.completed
    );

    // Tear down without waiting out the stall: stop() fails parked work
    // fast rather than masking it — no ticket may be left hanging.
    ing.stop();
    for t in &stalled {
        let _ = t.wait(Duration::from_secs(1));
        assert!(t.latency().is_some(), "every ticket must be fulfilled (ok or failed) at stop");
    }
    d.shutdown();
}
