//! Deployment configuration (JSON) — agents, directives, policies, engine.
//!
//! This is the serving-side analog of the paper's deployment setup: the
//! stub-generation declaration lists agents/tools and their callable
//! methods (§3.1 — YAML in the paper, JSON here: the offline toolchain has
//! no YAML parser and JSON is isomorphic for these declarations), the
//! `init(...)` runtime directives map to [`Directives`] (Table 1), and the
//! operator picks control policies by name (§4.2).
//!
//! See `configs/*.json` for the three evaluation workflows.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// Top-level deployment config.
#[derive(Debug, Clone)]
pub struct DeploymentConfig {
    /// Emulated node count.
    pub nodes: u32,
    /// Paper-seconds → real-seconds multiplier for simulated service times
    /// (0.01 = 100x speedup; metrics are reported scaled back).
    pub time_scale: f64,
    /// One-way cross-node message latency (µs) injected by the bus.
    pub cross_node_latency_us: u64,
    pub control: ControlConfig,
    pub agents: Vec<AgentConfig>,
    /// Global-controller policies, by registry name (§4.2). Order matters:
    /// later policies see earlier policies' effects next tick.
    pub policies: Vec<String>,
    pub engine: EngineConfig,
    pub ingress: IngressSettings,
    pub seed: u64,
}

/// Two-level control plane knobs (§4.1).
#[derive(Debug, Clone)]
pub struct ControlConfig {
    /// Global controller period (ms). The paper's loop is periodic; local
    /// controllers are event-driven.
    pub global_period_ms: u64,
    /// Disable to emulate baselines without migration.
    pub enable_migration: bool,
    /// Queue-wait threshold (wall-clock ms) that flags head-of-line blocking.
    pub hol_threshold_ms: u64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig { global_period_ms: 100, enable_migration: true, hol_threshold_ms: 250 }
    }
}

/// What computes behind an agent type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    /// LLM-backed agent served by the engine (vLLM substitute).
    Llm,
    /// Documentation lookup over the vector store (ChromaDB substitute).
    VectorStore,
    /// External web-search API (simulated latency + canned results).
    WebSearch,
    /// Test harness tool (simulated pass/fail with configured rate).
    TestHarness,
}

impl AgentKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "llm" => AgentKind::Llm,
            "vector_store" => AgentKind::VectorStore,
            "web_search" => AgentKind::WebSearch,
            "test_harness" => AgentKind::TestHarness,
            other => return Err(Error::Config(format!("unknown agent kind `{other}`"))),
        })
    }
}

/// Runtime directives — paper Table 1, passed at `agent.init(...)`.
#[derive(Debug, Clone)]
pub struct Directives {
    /// All requests of a session are ordered + routed to one instance; the
    /// session may NOT be migrated (strict form, §5 Discussion).
    pub stateful: bool,
    /// The instance can execute a batch of compatible requests together.
    pub batchable: bool,
    /// Running requests may be preempted.
    pub preemptable: bool,
    pub min_instances: u32,
    pub max_instances: u32,
    /// Resource demands per instance, e.g. {"GPU": 1, "CPU": 2}.
    pub resources: HashMap<String, f64>,
    /// Uses managed state: sessions route sticky but MAY migrate with
    /// their state (relaxed form, §5 Discussion).
    pub managed_state: bool,
}

impl Default for Directives {
    fn default() -> Self {
        Directives {
            stateful: false,
            batchable: false,
            preemptable: false,
            min_instances: 1,
            max_instances: 8,
            resources: HashMap::new(),
            managed_state: false,
        }
    }
}

/// Service-time profile for the Sim executor (calibrated against the PJRT
/// path; see EXPERIMENTS.md §Calibration). Times are in *paper seconds*;
/// the deployment's `time_scale` converts to wall clock.
#[derive(Debug, Clone)]
pub struct LatencyProfile {
    /// Fixed overhead per call.
    pub base_s: f64,
    /// Prefill cost per prompt token.
    pub per_prompt_token_s: f64,
    /// Decode cost per generated token (at batch size 1).
    pub per_output_token_s: f64,
    /// Mean generated tokens (lognormal).
    pub mean_output_tokens: f64,
    /// Lognormal sigma of generated tokens.
    pub output_sigma: f64,
    /// Batching efficiency: a decode step with batch size `b` costs
    /// `1 + batch_slope*(b-1)` step-times, so per-request cost shrinks.
    pub batch_slope: f64,
}

impl Default for LatencyProfile {
    fn default() -> Self {
        LatencyProfile {
            base_s: 0.2,
            per_prompt_token_s: 0.001,
            per_output_token_s: 0.03,
            mean_output_tokens: 120.0,
            output_sigma: 0.6,
            batch_slope: 0.15,
        }
    }
}

/// One agent/tool declaration (the stub-generation declaration of §3.1).
#[derive(Debug, Clone)]
pub struct AgentConfig {
    pub name: String,
    pub kind: AgentKind,
    /// Initial instance count (between min/max_instances).
    pub instances: u32,
    pub directives: Directives,
    pub profile: LatencyProfile,
    /// Methods callable through the generated stub.
    pub methods: Vec<String>,
    /// TestHarness: probability a run fails (drives SWE retries).
    pub failure_rate: f64,
}

/// One tenant sharing the ingress front door (`ingress.tenants[]`).
///
/// Tenancy is the §4 policy story applied to the front door itself:
/// heterogeneous traffic classes (interactive users vs batch pipelines,
/// or different customers) share each workflow's queue, and without
/// isolation one aggressive tenant starves everyone behind the same
/// admission cap. Each tenant gets a weight (deficit-round-robin share
/// of front-door service under backlog) and, optionally, its own token
/// bucket layered *under* the shared admission policy.
#[derive(Debug, Clone)]
pub struct TenantSettings {
    pub name: String,
    /// DRR weight: relative share of front-door service while the tenant
    /// stays backlogged. Must be > 0; equal weights = plain round-robin.
    pub weight: f64,
    /// Per-tenant token-bucket refill (requests/second on the scheduler's
    /// clock). 0 = no per-tenant bucket (the shared policy still applies).
    pub token_rate: f64,
    /// Per-tenant token-bucket burst size (only meaningful with a rate).
    pub token_burst: f64,
}

impl Default for TenantSettings {
    fn default() -> Self {
        TenantSettings { name: "default".into(), weight: 1.0, token_rate: 0.0, token_burst: 32.0 }
    }
}

/// Ingress front-door settings (the open-loop serving mode; see
/// [`crate::ingress`]). Baselines are forced to `unbounded` admission by
/// [`crate::baselines::SystemUnderTest::apply`] — none of the compared
/// systems ships an admission controller.
#[derive(Debug, Clone)]
pub struct IngressSettings {
    /// Admission policy: `unbounded` | `bounded` | `token_bucket`.
    pub policy: String,
    /// Ready/admission-queue ordering: `fifo` | `deadline_slack` (pop the
    /// minimum `deadline − now − estimated_remaining`, SRTF at the front
    /// door) | `stage` (drain later-stage work first). Baselines are
    /// forced to `fifo` by `baselines::SystemUnderTest::apply` — none of
    /// the compared systems schedules its front door.
    pub schedule: String,
    /// Per-call model routing: `fixed` (no routing — the pre-variant
    /// behaviour, default) | `jit` (pick a variant per call from deadline
    /// slack at dispatch time, DESIGN.md §13) | `fixed-<variant>` (pin
    /// every call to one named variant — the bench's comparison arms).
    /// Anything but `fixed` requires `engine.variants` to be non-empty.
    pub route: String,
    /// Bounded-queue capacity per workflow queue.
    pub queue_cap: usize,
    /// Scheduler OS threads. This bounds *threads*, not in-flight
    /// requests: drivers are resumable state machines, so each thread
    /// multiplexes many parked requests (`max_in_flight` is the
    /// concurrency bound).
    pub workers: usize,
    /// Concurrent started (in-flight) requests across the front door —
    /// the backpressure bound behind the admission queues.
    pub max_in_flight: usize,
    /// Token-bucket refill rate (requests/second, wall clock). 0 means
    /// unlimited (the bucket never runs dry).
    pub token_rate: f64,
    /// Token-bucket burst size.
    pub token_burst: f64,
    /// Tenants sharing this front door (weighted-fair DRR queues +
    /// per-tenant token buckets). Empty = one implicit `default` tenant,
    /// which degenerates to the pre-tenancy single queue. Baselines are
    /// forced back to that single tenant by
    /// `baselines::SystemUnderTest::apply` — none of the compared systems
    /// isolates tenants at its front door.
    pub tenants: Vec<TenantSettings>,
    /// HTTP serving-plane sizing (`nalar serve --listen`).
    pub http: HttpSettings,
    /// Request-tracing flight recorder (`ingress.trace`; see
    /// [`crate::trace`] and DESIGN.md §10).
    pub trace: TraceSettings,
    /// Durable request journal (`ingress.journal`; see [`crate::journal`]
    /// and DESIGN.md §12). Disabled unless a path is set.
    pub journal: JournalSettings,
}

impl Default for IngressSettings {
    fn default() -> Self {
        IngressSettings {
            policy: "bounded".into(),
            schedule: "fifo".into(),
            route: "fixed".into(),
            queue_cap: 256,
            workers: 8,
            max_in_flight: 1024,
            token_rate: 0.0,
            token_burst: 32.0,
            tenants: Vec::new(),
            http: HttpSettings::default(),
            trace: TraceSettings::default(),
            journal: JournalSettings::default(),
        }
    }
}

/// Durable request journal (`ingress.journal`). When `path` is set,
/// every front-door request appends its lifecycle records there
/// ([`crate::journal`]), and `Ingress::start` replays the file on boot —
/// completed requests skipped, in-flight ones re-admitted. An empty
/// `path` (the default) disables journaling entirely: the serving hot
/// path pays one enum-discriminant branch per record site.
#[derive(Debug, Clone)]
pub struct JournalSettings {
    /// Append-only journal file. Empty = journaling off.
    pub path: String,
    /// Durability: `always` (fsync per record) | `batch` (fsync every 64
    /// records — the default) | `never` (flush to the OS only; survives
    /// process death, not power loss). See `journal::FsyncPolicy`.
    pub fsync: String,
}

impl Default for JournalSettings {
    fn default() -> Self {
        JournalSettings { path: String::new(), fsync: "batch".into() }
    }
}

/// Flight-recorder sizing (`ingress.trace`). The recorder is a bounded
/// ring sharded across 32 locks ([`crate::trace::FlightRecorder`]);
/// `capacity` is the *total* event budget, split evenly across shards.
/// Memory is `capacity × sizeof(TraceEvent)` ≈ `capacity × 40 B` — the
/// default 65536 events is ~2.6 MB per node, about 8000 requests of
/// 8-event timelines before overwrite. 0 disables tracing entirely
/// (the sink becomes a no-op; the stage-latency histograms still fold).
#[derive(Debug, Clone)]
pub struct TraceSettings {
    pub capacity: usize,
}

impl Default for TraceSettings {
    fn default() -> Self {
        TraceSettings { capacity: 65536 }
    }
}

/// Socket front-door sizing (`ingress.http`; see [`crate::server::http`]).
/// This sizes the wire layer only — admission, scheduling and tenancy
/// stay with the [`IngressSettings`] fields above, exactly as for
/// in-process submits.
#[derive(Debug, Clone)]
pub struct HttpSettings {
    /// Acceptor threads pulling connections off the listener.
    pub acceptors: usize,
    /// Connection workers. Each owns one connection until it closes, so
    /// this bounds concurrently *served* connections (accepted-but-queued
    /// connections wait in the hand-off channel).
    pub workers: usize,
    /// Request line + headers cap (bytes); beyond it the request is
    /// answered `431` and the connection closed.
    pub max_header_bytes: usize,
    /// Body cap (bytes); beyond it `413` and close.
    pub max_body_bytes: usize,
}

impl Default for HttpSettings {
    fn default() -> Self {
        HttpSettings {
            acceptors: 1,
            workers: 16,
            max_header_bytes: 16 << 10,
            max_body_bytes: 1 << 20,
        }
    }
}

/// One named model variant behind the engine class (`engine.variants[]`,
/// DESIGN.md §13). Variants share an engine's batch former and KV plumbing
/// but trade service time against answer quality — the JIT router picks
/// one per call at dispatch time from the request's deadline slack.
#[derive(Debug, Clone)]
pub struct ModelVariant {
    pub name: String,
    /// Service-time multiplier applied to the agent's latency profile
    /// (1.0 = the profile as written; < 1 is a faster, cheaper model).
    pub latency_mult: f64,
    /// Answer-quality score in (0, 1] folded into the bench's quality
    /// accounting (goodput at equal quality / quality at equal goodput).
    pub quality: f64,
}

/// LLM engine settings (vLLM substitute).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    /// Executor: `sim` (profiled latency) or `pjrt` (real AOT compute).
    pub executor: String,
    pub kv_hbm_bytes: u64,
    pub kv_dram_bytes: u64,
    /// `lru` or `hint` KV policy (§4.3.2).
    pub kv_policy: String,
    /// Artifacts directory for the pjrt executor.
    pub artifacts_dir: String,
    /// Named model variants selectable per call (`ingress.route`). Empty
    /// (the default) means no variants exist and routing is inert — every
    /// call runs the agent's profile curve exactly as before.
    pub variants: Vec<ModelVariant>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 8,
            executor: "sim".into(),
            kv_hbm_bytes: 64 << 20,
            kv_dram_bytes: 512 << 20,
            kv_policy: "hint".into(),
            artifacts_dir: "artifacts".into(),
            variants: Vec::new(),
        }
    }
}

impl EngineConfig {
    pub fn variant(&self, name: &str) -> Option<&ModelVariant> {
        self.variants.iter().find(|v| v.name == name)
    }
}

impl DeploymentConfig {
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let cfg = Self::from_value(&v)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let control = {
            let c = v.get("control");
            ControlConfig {
                global_period_ms: c.u64_or("global_period_ms", 100),
                enable_migration: c.bool_or("enable_migration", true),
                hol_threshold_ms: c.u64_or("hol_threshold_ms", 250),
            }
        };
        let engine = {
            let e = v.get("engine");
            let variants = e
                .get("variants")
                .as_arr()
                .map(|a| {
                    a.iter()
                        .map(|m| ModelVariant {
                            name: m.str_or("name", "").to_string(),
                            latency_mult: m.f64_or("latency_mult", 1.0),
                            quality: m.f64_or("quality", 1.0),
                        })
                        .collect()
                })
                .unwrap_or_default();
            EngineConfig {
                max_batch: e.u64_or("max_batch", 8) as usize,
                executor: e.str_or("executor", "sim").to_string(),
                kv_hbm_bytes: e.u64_or("kv_hbm_bytes", 64 << 20),
                kv_dram_bytes: e.u64_or("kv_dram_bytes", 512 << 20),
                kv_policy: e.str_or("kv_policy", "hint").to_string(),
                artifacts_dir: e.str_or("artifacts_dir", "artifacts").to_string(),
                variants,
            }
        };
        let ingress = {
            let i = v.get("ingress");
            let di = IngressSettings::default();
            let tenants = i
                .get("tenants")
                .as_arr()
                .map(|a| {
                    a.iter()
                        .map(|t| {
                            let dt = TenantSettings::default();
                            TenantSettings {
                                name: t.str_or("name", &dt.name).to_string(),
                                weight: t.f64_or("weight", dt.weight),
                                token_rate: t.f64_or("token_rate", dt.token_rate),
                                token_burst: t.f64_or("token_burst", dt.token_burst),
                            }
                        })
                        .collect()
                })
                .unwrap_or_default();
            let http = {
                let h = i.get("http");
                let dh = HttpSettings::default();
                HttpSettings {
                    acceptors: h.u64_or("acceptors", dh.acceptors as u64) as usize,
                    workers: h.u64_or("workers", dh.workers as u64) as usize,
                    max_header_bytes: h.u64_or("max_header_bytes", dh.max_header_bytes as u64)
                        as usize,
                    max_body_bytes: h.u64_or("max_body_bytes", dh.max_body_bytes as u64) as usize,
                }
            };
            let trace = TraceSettings {
                capacity: i
                    .get("trace")
                    .u64_or("capacity", TraceSettings::default().capacity as u64)
                    as usize,
            };
            let journal = {
                let j = i.get("journal");
                let dj = JournalSettings::default();
                JournalSettings {
                    path: j.str_or("path", &dj.path).to_string(),
                    fsync: j.str_or("fsync", &dj.fsync).to_string(),
                }
            };
            IngressSettings {
                policy: i.str_or("policy", &di.policy).to_string(),
                schedule: i.str_or("schedule", &di.schedule).to_string(),
                route: i.str_or("route", &di.route).to_string(),
                queue_cap: i.u64_or("queue_cap", di.queue_cap as u64) as usize,
                workers: i.u64_or("workers", di.workers as u64) as usize,
                max_in_flight: i.u64_or("max_in_flight", di.max_in_flight as u64) as usize,
                token_rate: i.f64_or("token_rate", di.token_rate),
                token_burst: i.f64_or("token_burst", di.token_burst),
                tenants,
                http,
                trace,
                journal,
            }
        };
        let agents = v
            .get("agents")
            .as_arr()
            .ok_or_else(|| Error::Config("`agents` must be an array".into()))?
            .iter()
            .map(Self::agent_from_value)
            .collect::<Result<Vec<_>>>()?;
        let policies = v
            .get("policies")
            .as_arr()
            .map(|a| {
                a.iter()
                    .filter_map(|p| p.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        Ok(DeploymentConfig {
            nodes: v.u64_or("nodes", 2) as u32,
            time_scale: v.f64_or("time_scale", 0.01),
            cross_node_latency_us: v.u64_or("cross_node_latency_us", 200),
            control,
            agents,
            policies,
            engine,
            ingress,
            seed: v.u64_or("seed", 0),
        })
    }

    fn agent_from_value(v: &Value) -> Result<AgentConfig> {
        let name = v
            .get("name")
            .as_str()
            .ok_or_else(|| Error::Config("agent missing `name`".into()))?
            .to_string();
        let kind = AgentKind::parse(v.str_or("kind", "llm"))?;
        let d = v.get("directives");
        let mut resources = HashMap::new();
        if let Some(obj) = d.get("resources").as_obj() {
            for (k, rv) in obj {
                resources.insert(k.clone(), rv.as_f64().unwrap_or(0.0));
            }
        }
        let directives = Directives {
            stateful: d.bool_or("stateful", false),
            batchable: d.bool_or("batchable", false),
            preemptable: d.bool_or("preemptable", false),
            min_instances: d.u64_or("min_instances", 1) as u32,
            max_instances: d.u64_or("max_instances", 8) as u32,
            resources,
            managed_state: d.bool_or("managed_state", false),
        };
        let p = v.get("profile");
        let dp = LatencyProfile::default();
        let profile = LatencyProfile {
            base_s: p.f64_or("base_s", dp.base_s),
            per_prompt_token_s: p.f64_or("per_prompt_token_s", dp.per_prompt_token_s),
            per_output_token_s: p.f64_or("per_output_token_s", dp.per_output_token_s),
            mean_output_tokens: p.f64_or("mean_output_tokens", dp.mean_output_tokens),
            output_sigma: p.f64_or("output_sigma", dp.output_sigma),
            batch_slope: p.f64_or("batch_slope", dp.batch_slope),
        };
        let methods = v
            .get("methods")
            .as_arr()
            .map(|a| a.iter().filter_map(|m| m.as_str().map(String::from)).collect())
            .unwrap_or_default();
        Ok(AgentConfig {
            name,
            kind,
            instances: v.u64_or("instances", 1) as u32,
            directives,
            profile,
            methods,
            failure_rate: v.f64_or("failure_rate", 0.0),
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(Error::Config("nodes must be >= 1".into()));
        }
        if !(self.time_scale > 0.0) {
            return Err(Error::Config("time_scale must be > 0".into()));
        }
        let mut seen = std::collections::HashSet::new();
        for a in &self.agents {
            if !seen.insert(&a.name) {
                return Err(Error::Config(format!("duplicate agent `{}`", a.name)));
            }
            let d = &a.directives;
            if d.min_instances > d.max_instances {
                return Err(Error::Config(format!(
                    "{}: min_instances > max_instances",
                    a.name
                )));
            }
            if a.instances < d.min_instances || a.instances > d.max_instances {
                return Err(Error::Config(format!(
                    "{}: instances {} outside [{}, {}]",
                    a.name, a.instances, d.min_instances, d.max_instances
                )));
            }
            // §5 Discussion: managed state cannot combine with batching —
            // batching mixes sessions, making state attribution impossible.
            if d.managed_state && d.batchable {
                return Err(Error::Config(format!(
                    "{}: managed_state is incompatible with batchable (paper §5)",
                    a.name
                )));
            }
            if !(0.0..=1.0).contains(&a.failure_rate) {
                return Err(Error::Config(format!("{}: failure_rate out of range", a.name)));
            }
        }
        if self.agents.is_empty() {
            return Err(Error::Config("no agents declared".into()));
        }
        // One parse authority per name set: `AdmissionPolicy::parse` owns
        // the admission names (previously a typo silently fell through
        // `from_settings`' Bounded fallback), `SchedulePolicy::parse` the
        // scheduling names.
        if crate::ingress::AdmissionPolicy::parse(&self.ingress.policy).is_none() {
            return Err(Error::Config(format!(
                "unknown ingress policy `{}` (known: unbounded, bounded, token_bucket)",
                self.ingress.policy
            )));
        }
        if crate::ingress::SchedulePolicy::parse(&self.ingress.schedule).is_none() {
            return Err(Error::Config(format!(
                "unknown ingress schedule `{}` (known: fifo, deadline_slack, stage)",
                self.ingress.schedule
            )));
        }
        // `RouteMode::parse` owns the route names (same one-authority rule);
        // referential checks against `engine.variants` live here too.
        let route = crate::ingress::RouteMode::parse(&self.ingress.route).ok_or_else(|| {
            Error::Config(format!(
                "unknown ingress route `{}` (known: fixed, jit, fixed-<variant>)",
                self.ingress.route
            ))
        })?;
        let mut variant_names = std::collections::HashSet::new();
        for mv in &self.engine.variants {
            if mv.name.is_empty() {
                return Err(Error::Config("engine variant with empty name".into()));
            }
            if !variant_names.insert(&mv.name) {
                return Err(Error::Config(format!("duplicate engine variant `{}`", mv.name)));
            }
            if !(mv.latency_mult > 0.0 && mv.latency_mult.is_finite()) {
                return Err(Error::Config(format!(
                    "variant `{}`: latency_mult must be a finite number > 0",
                    mv.name
                )));
            }
            if !(mv.quality > 0.0 && mv.quality <= 1.0) {
                return Err(Error::Config(format!(
                    "variant `{}`: quality must be in (0, 1]",
                    mv.name
                )));
            }
        }
        match &route {
            crate::ingress::RouteMode::Fixed(None) => {}
            crate::ingress::RouteMode::Jit if self.engine.variants.is_empty() => {
                return Err(Error::Config(
                    "ingress route `jit` requires engine.variants to be declared".into(),
                ));
            }
            crate::ingress::RouteMode::Fixed(Some(name))
                if self.engine.variant(name).is_none() =>
            {
                return Err(Error::Config(format!(
                    "ingress route pins unknown variant `{name}` (declared: {})",
                    self.engine
                        .variants
                        .iter()
                        .map(|v| v.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )));
            }
            _ => {}
        }
        if self.ingress.workers == 0 {
            return Err(Error::Config("ingress.workers must be >= 1".into()));
        }
        if self.ingress.max_in_flight == 0 {
            return Err(Error::Config("ingress.max_in_flight must be >= 1".into()));
        }
        if self.ingress.http.acceptors == 0 {
            return Err(Error::Config("ingress.http.acceptors must be >= 1".into()));
        }
        if self.ingress.http.workers == 0 {
            return Err(Error::Config("ingress.http.workers must be >= 1".into()));
        }
        if self.ingress.http.max_header_bytes < 256 {
            return Err(Error::Config("ingress.http.max_header_bytes must be >= 256".into()));
        }
        if self.ingress.http.max_body_bytes == 0 {
            return Err(Error::Config("ingress.http.max_body_bytes must be >= 1".into()));
        }
        // `FsyncPolicy::parse` owns the fsync names (same one-authority
        // rule as admission/schedule above); checked even with journaling
        // off so a typo surfaces before the path is ever set.
        if let Err(e) = crate::journal::FsyncPolicy::parse(&self.ingress.journal.fsync) {
            return Err(e);
        }
        let mut tenant_names = std::collections::HashSet::new();
        for t in &self.ingress.tenants {
            if t.name.is_empty() {
                return Err(Error::Config("ingress tenant with empty name".into()));
            }
            if !tenant_names.insert(&t.name) {
                return Err(Error::Config(format!("duplicate ingress tenant `{}`", t.name)));
            }
            if !(t.weight > 0.0 && t.weight.is_finite()) {
                return Err(Error::Config(format!(
                    "tenant `{}`: weight must be a finite number > 0",
                    t.name
                )));
            }
            if !(t.token_rate >= 0.0 && t.token_rate.is_finite()) {
                return Err(Error::Config(format!(
                    "tenant `{}`: token_rate must be a finite number >= 0",
                    t.name
                )));
            }
            if t.token_rate > 0.0 && (!t.token_burst.is_finite() || t.token_burst < 1.0) {
                return Err(Error::Config(format!(
                    "tenant `{}`: token_burst must be >= 1 when token_rate is set",
                    t.name
                )));
            }
        }
        Ok(())
    }

    pub fn agent(&self, name: &str) -> Option<&AgentConfig> {
        self.agents.iter().find(|a| a.name == name)
    }

    /// Scale a paper-seconds duration to wall clock.
    pub fn scaled(&self, paper_seconds: f64) -> std::time::Duration {
        std::time::Duration::from_secs_f64((paper_seconds * self.time_scale).max(0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINIMAL: &str = r#"{"agents": [{"name": "planner", "kind": "llm", "methods": ["plan"]}]}"#;

    #[test]
    fn minimal_defaults() {
        let c = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert_eq!(c.nodes, 2);
        assert_eq!(c.control.global_period_ms, 100);
        assert_eq!(c.agents[0].instances, 1);
        assert!(!c.agents[0].directives.stateful);
        assert_eq!(c.agents[0].methods, vec!["plan"]);
        assert_eq!(c.ingress.policy, "bounded");
        assert_eq!(c.ingress.schedule, "fifo");
        assert_eq!(c.ingress.queue_cap, 256);
    }

    #[test]
    fn ingress_section_parses_and_validates() {
        let y = r#"{"ingress": {"policy": "token_bucket", "queue_cap": 32, "workers": 8,
                     "max_in_flight": 96, "token_rate": 50.0, "token_burst": 10.0,
                     "schedule": "deadline_slack"},
                    "agents": [{"name": "a", "kind": "llm", "methods": ["m"]}]}"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.ingress.policy, "token_bucket");
        assert_eq!(c.ingress.schedule, "deadline_slack");
        assert_eq!(c.ingress.queue_cap, 32);
        assert_eq!(c.ingress.workers, 8);
        assert_eq!(c.ingress.max_in_flight, 96);
        assert_eq!(c.ingress.token_rate, 50.0);
        let bad = r#"{"ingress": {"policy": "magic"},
                      "agents": [{"name": "a", "kind": "llm"}]}"#;
        assert!(DeploymentConfig::from_json(bad).is_err());
        let bad_mif = r#"{"ingress": {"max_in_flight": 0},
                          "agents": [{"name": "a", "kind": "llm"}]}"#;
        assert!(DeploymentConfig::from_json(bad_mif).is_err());
        let bad_sched = r#"{"ingress": {"schedule": "lifo"},
                            "agents": [{"name": "a", "kind": "llm"}]}"#;
        assert!(DeploymentConfig::from_json(bad_sched).is_err());
    }

    #[test]
    fn admission_policy_typos_fail_at_load_time() {
        // Regression: `AdmissionPolicy::from_settings` silently mapped any
        // unknown name to `Bounded`; validation must reject the typo
        // before a deployment launches with the wrong admission behaviour.
        for typo in ["bouned", "token-bucket", "Unbounded", ""] {
            let y = format!(
                r#"{{"ingress": {{"policy": "{typo}"}},
                     "agents": [{{"name": "a", "kind": "llm"}}]}}"#
            );
            let err = DeploymentConfig::from_json(&y).unwrap_err();
            assert!(err.to_string().contains("unknown ingress policy"), "{typo}: {err}");
        }
    }

    #[test]
    fn tenants_block_parses_and_validates() {
        let y = r#"{"ingress": {"tenants": [
                      {"name": "interactive", "weight": 3.0},
                      {"name": "batch", "weight": 1.0, "token_rate": 20.0, "token_burst": 8.0}]},
                    "agents": [{"name": "a", "kind": "llm", "methods": ["m"]}]}"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.ingress.tenants.len(), 2);
        assert_eq!(c.ingress.tenants[0].name, "interactive");
        assert_eq!(c.ingress.tenants[0].weight, 3.0);
        assert_eq!(c.ingress.tenants[0].token_rate, 0.0, "no bucket unless configured");
        assert_eq!(c.ingress.tenants[1].token_rate, 20.0);
        assert_eq!(c.ingress.tenants[1].token_burst, 8.0);
        // no tenants block = empty table (the ingress substitutes the
        // implicit single `default` tenant)
        let none = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert!(none.ingress.tenants.is_empty());
    }

    #[test]
    fn http_block_parses_and_validates() {
        let y = r#"{"ingress": {"http": {"acceptors": 2, "workers": 4,
                      "max_header_bytes": 4096, "max_body_bytes": 65536}},
                    "agents": [{"name": "a", "kind": "llm", "methods": ["m"]}]}"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.ingress.http.acceptors, 2);
        assert_eq!(c.ingress.http.workers, 4);
        assert_eq!(c.ingress.http.max_header_bytes, 4096);
        assert_eq!(c.ingress.http.max_body_bytes, 65536);
        // no http block = defaults
        let none = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert_eq!(none.ingress.http.acceptors, 1);
        assert_eq!(none.ingress.http.workers, 16);
        for (http, what) in [
            (r#"{"acceptors": 0}"#, "zero acceptors"),
            (r#"{"workers": 0}"#, "zero workers"),
            (r#"{"max_header_bytes": 64}"#, "header cap below floor"),
            (r#"{"max_body_bytes": 0}"#, "zero body cap"),
        ] {
            let y = format!(
                r#"{{"ingress": {{"http": {http}}},
                     "agents": [{{"name": "x", "kind": "llm"}}]}}"#
            );
            assert!(DeploymentConfig::from_json(&y).is_err(), "must reject: {what}");
        }
    }

    #[test]
    fn trace_block_parses_with_zero_meaning_disabled() {
        let y = r#"{"ingress": {"trace": {"capacity": 1024}},
                    "agents": [{"name": "a", "kind": "llm", "methods": ["m"]}]}"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.ingress.trace.capacity, 1024);
        // no trace block = default recorder budget
        let none = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert_eq!(none.ingress.trace.capacity, 65536);
        // 0 is valid: tracing off, not an error
        let off = r#"{"ingress": {"trace": {"capacity": 0}},
                      "agents": [{"name": "a", "kind": "llm"}]}"#;
        assert_eq!(DeploymentConfig::from_json(off).unwrap().ingress.trace.capacity, 0);
    }

    #[test]
    fn journal_block_parses_with_empty_path_meaning_disabled() {
        let y = r#"{"ingress": {"journal": {"path": "/tmp/n.journal", "fsync": "always"}},
                    "agents": [{"name": "a", "kind": "llm", "methods": ["m"]}]}"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.ingress.journal.path, "/tmp/n.journal");
        assert_eq!(c.ingress.journal.fsync, "always");
        // no journal block = disabled (empty path), batch durability
        let none = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert!(none.ingress.journal.path.is_empty());
        assert_eq!(none.ingress.journal.fsync, "batch");
        // fsync typos fail at load time, even with journaling off
        let bad = r#"{"ingress": {"journal": {"fsync": "sometimes"}},
                      "agents": [{"name": "a", "kind": "llm"}]}"#;
        let err = DeploymentConfig::from_json(bad).unwrap_err();
        assert!(err.to_string().contains("journal.fsync"), "{err}");
    }

    #[test]
    fn rejects_invalid_tenants() {
        for (tenants, what) in [
            (r#"[{"name": "a"}, {"name": "a"}]"#, "duplicate"),
            (r#"[{"name": ""}]"#, "empty name"),
            (r#"[{"name": "a", "weight": 0.0}]"#, "zero weight"),
            (r#"[{"name": "a", "weight": -2.0}]"#, "negative weight"),
            (r#"[{"name": "a", "token_rate": -1.0}]"#, "negative rate"),
            (r#"[{"name": "a", "token_rate": 5.0, "token_burst": 0.0}]"#, "zero burst"),
        ] {
            let y = format!(
                r#"{{"ingress": {{"tenants": {tenants}}},
                     "agents": [{{"name": "x", "kind": "llm"}}]}}"#
            );
            assert!(DeploymentConfig::from_json(&y).is_err(), "must reject: {what}");
        }
    }

    #[test]
    fn variants_block_parses_and_validates() {
        let y = r#"{"engine": {"variants": [
                      {"name": "fast", "latency_mult": 0.35, "quality": 0.82},
                      {"name": "base", "latency_mult": 1.0, "quality": 0.92},
                      {"name": "large", "latency_mult": 2.2, "quality": 0.99}]},
                    "ingress": {"route": "jit"},
                    "agents": [{"name": "a", "kind": "llm", "methods": ["m"]}]}"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.engine.variants.len(), 3);
        assert_eq!(c.engine.variant("fast").unwrap().latency_mult, 0.35);
        assert_eq!(c.ingress.route, "jit");
        // no variants block = empty table, routing inert, route `fixed`
        let none = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert!(none.engine.variants.is_empty());
        assert_eq!(none.ingress.route, "fixed");
    }

    #[test]
    fn rejects_invalid_variants_and_routes() {
        for (engine, ingress, what) in [
            (
                r#"{"variants": [{"name": ""}]}"#,
                r#"{}"#,
                "empty variant name",
            ),
            (
                r#"{"variants": [{"name": "a"}, {"name": "a"}]}"#,
                r#"{}"#,
                "duplicate variant",
            ),
            (
                r#"{"variants": [{"name": "a", "latency_mult": 0.0}]}"#,
                r#"{}"#,
                "zero latency_mult",
            ),
            (
                r#"{"variants": [{"name": "a", "quality": 1.5}]}"#,
                r#"{}"#,
                "quality above 1",
            ),
            (r#"{}"#, r#"{"route": "jit"}"#, "jit without variants"),
            (r#"{}"#, r#"{"route": "jitt"}"#, "route typo"),
            (
                r#"{"variants": [{"name": "fast"}]}"#,
                r#"{"route": "fixed-huge"}"#,
                "pin to unknown variant",
            ),
        ] {
            let y = format!(
                r#"{{"engine": {engine}, "ingress": {ingress},
                     "agents": [{{"name": "x", "kind": "llm"}}]}}"#
            );
            assert!(DeploymentConfig::from_json(&y).is_err(), "must reject: {what}");
        }
    }

    #[test]
    fn rejects_managed_state_plus_batchable() {
        let y = r#"{"agents": [{"name": "a", "kind": "llm",
                     "directives": {"managed_state": true, "batchable": true}}]}"#;
        assert!(DeploymentConfig::from_json(y).is_err());
    }

    #[test]
    fn rejects_duplicate_agents() {
        let y = r#"{"agents": [{"name": "a", "kind": "llm"}, {"name": "a", "kind": "llm"}]}"#;
        assert!(DeploymentConfig::from_json(y).is_err());
    }

    #[test]
    fn rejects_instances_outside_bounds() {
        let y = r#"{"agents": [{"name": "a", "kind": "llm", "instances": 9,
                     "directives": {"max_instances": 4}}]}"#;
        assert!(DeploymentConfig::from_json(y).is_err());
    }

    #[test]
    fn rejects_unknown_kind() {
        let y = r#"{"agents": [{"name": "a", "kind": "quantum"}]}"#;
        assert!(DeploymentConfig::from_json(y).is_err());
    }

    #[test]
    fn scaled_duration() {
        let c = DeploymentConfig::from_json(MINIMAL).unwrap();
        assert_eq!(c.scaled(2.0), std::time::Duration::from_millis(20));
    }

    #[test]
    fn full_roundtrip() {
        let y = r#"{
            "nodes": 4,
            "time_scale": 0.005,
            "policies": ["load_balance", "hol_migration"],
            "control": {"global_period_ms": 50, "enable_migration": true},
            "engine": {"max_batch": 4, "executor": "sim", "kv_policy": "lru"},
            "agents": [{
                "name": "dev", "kind": "llm", "instances": 2,
                "directives": {"batchable": true, "max_instances": 4, "resources": {"GPU": 1}},
                "profile": {"mean_output_tokens": 200},
                "methods": ["implement_and_test"]
            }]
        }"#;
        let c = DeploymentConfig::from_json(y).unwrap();
        assert_eq!(c.nodes, 4);
        assert_eq!(c.agent("dev").unwrap().profile.mean_output_tokens, 200.0);
        assert_eq!(c.agent("dev").unwrap().directives.resources["GPU"], 1.0);
        assert_eq!(c.policies.len(), 2);
        assert_eq!(c.engine.kv_policy, "lru");
    }
}
