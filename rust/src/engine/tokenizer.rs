//! Byte-level tokenizer for the served LM (vocab = 256 bytes + specials).

use crate::runtime::manifest::ModelDims;

/// Stateless byte tokenizer; ids 0..255 are raw bytes, then BOS/EOS/PAD.
#[derive(Debug, Clone, Copy)]
pub struct Tokenizer {
    pub bos: i32,
    pub eos: i32,
    pub pad: i32,
    pub max_seq: usize,
}

impl Tokenizer {
    pub fn new(dims: &ModelDims) -> Self {
        Tokenizer { bos: dims.bos, eos: dims.eos, pad: dims.pad, max_seq: dims.max_seq }
    }

    /// `[BOS] + bytes`, truncated so at least `reserve` positions remain
    /// for generation.
    pub fn encode(&self, text: &str, reserve: usize) -> Vec<i32> {
        let budget = self.max_seq.saturating_sub(reserve).max(1);
        let mut out = Vec::with_capacity(budget.min(text.len() + 1));
        out.push(self.bos);
        for &b in text.as_bytes().iter().take(budget.saturating_sub(1)) {
            out.push(b as i32);
        }
        out
    }

    /// Decode generated ids back to text (stops at EOS, skips specials).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len());
        for &id in ids {
            if id == self.eos {
                break;
            }
            if (0..256).contains(&id) {
                bytes.push(id as u8);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn is_special(&self, id: i32) -> bool {
        id == self.bos || id == self.eos || id == self.pad
    }
}

/// Greedy argmax sampling (deterministic serving).
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer { bos: 256, eos: 257, pad: 258, max_seq: 16 }
    }

    #[test]
    fn encode_roundtrip() {
        let t = tok();
        let ids = t.encode("hi", 4);
        assert_eq!(ids, vec![256, b'h' as i32, b'i' as i32]);
        assert_eq!(t.decode(&ids[1..]), "hi");
    }

    #[test]
    fn encode_truncates_with_reserve() {
        let t = tok();
        let ids = t.encode("abcdefghijklmnopqrstuvwxyz", 8);
        assert_eq!(ids.len(), 8); // 16 - 8 budget
        assert_eq!(ids[0], 256);
    }

    #[test]
    fn decode_stops_at_eos_and_skips_specials() {
        let t = tok();
        assert_eq!(t.decode(&[b'a' as i32, 257, b'b' as i32]), "a");
        assert_eq!(t.decode(&[258, b'x' as i32]), "x");
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn specials() {
        let t = tok();
        assert!(t.is_special(256) && t.is_special(257) && t.is_special(258));
        assert!(!t.is_special(65));
    }
}
