//! §6.2 reproduction: adding new policies in ~12 lines.
//!
//! * Minimize JCT — SRTF (prioritize later-stage calls) vs FCFS on the
//!   financial workflow: paper reports avg JCT -2.4%, P95 +3.3%.
//! * Control makespan — LPT (prioritize re-entrant jobs) vs FCFS on the
//!   SWE workflow, closed batch: paper reports makespan -5.8%, P95 +2.6%.
//!
//! Thin wrapper over [`nalar::bench::sec62`] — the same code path as
//! `nalar bench --only sec62`; writes `BENCH_sec62.json`.

use std::path::Path;

fn main() {
    let quick = std::env::var("NALAR_BENCH_QUICK").is_ok();
    let report = nalar::bench::sec62(quick).expect("sec62 reproduction failed");
    nalar::bench::validate(&report).expect("sec62 report schema");
    let path = nalar::bench::write_report(Path::new("."), "sec62", &report).expect("write report");
    println!("wrote {}", path.display());
}
