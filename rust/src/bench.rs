//! `nalar bench` — the paper-figure reporting subsystem.
//!
//! One entrypoint ([`run`]) reproduces the paper's headline measurements
//! headlessly and emits machine-readable reports at the repo root:
//!
//! * `BENCH_fig9.json` — end-to-end latency vs request rate, three
//!   workflows × four systems (paper Fig. 9);
//! * `BENCH_fig10.json` — global control-loop latency vs live futures,
//!   up to 131K futures / 128 agents (paper Fig. 10: 464 ms at 131K);
//! * `BENCH_table4.json` — one-level vs two-level per-future scheduling
//!   latency (paper Table 4);
//! * `BENCH_sec62.json` — the §6.2 SRTF/LPT policy studies.
//!
//! Every report follows one stable schema (`nalar-bench/v1`, DESIGN.md §4):
//! a top-level `schema`/`bench`/`quick`/`latency_unit` header plus a
//! `points` array in which **every point carries a `latency` object with
//! `p50`/`p95`/`p99`** (computed via [`crate::metrics::LatencyRecorder`])
//! and the sweep coordinates that produced it. [`validate`] enforces the
//! schema; CI's bench-smoke job fails on invalid output, and future PRs
//! regress against these files as the perf trajectory.
//!
//! `--quick` scales every reproduction down to CI-smoke size (seconds, not
//! minutes); the full profile reproduces the paper's sweep ranges.

use std::path::{Path, PathBuf};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::baselines::SystemUnderTest;
use crate::config::{ModelVariant, TenantSettings};
use crate::coordinator::policy::make_policy;
use crate::coordinator::{GlobalController, InstanceMetrics, LoadMap, Router};
use crate::error::{Error, Result};
use crate::futures::{FutureCell, FutureMeta, FutureTable};
use crate::ids::{AgentType, FutureId, InstanceId, Location, NodeId, RequestId, SessionId};
use crate::ingress::loadgen::{run_point, LoadgenOpts};
use crate::ingress::{
    AdmissionPolicy, HoldOp, HoldStats, Ingress, SchedulerOpts, SubmitRequest, Ticket,
};
use crate::journal::{FsyncPolicy, JournalSink};
use crate::json;
use crate::metrics::LatencyRecorder;
use crate::nodestore::{keys, StoreDirectory};
use crate::server::Deployment;
use crate::testkit::ScriptedEngine;
use crate::transport::{Bus, Message};
use crate::util::bench::Table;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workflow::{run_open_loop, run_request, RunConfig, WorkflowKind};
use crate::workload;

/// Schema tag stamped on every report.
pub const SCHEMA: &str = "nalar-bench/v1";

/// Report names in execution order.
pub const ALL: &[&str] = &["fig9", "fig10", "table4", "sec62"];

/// The §6 saturation sweep written by `nalar loadgen` (not part of
/// [`ALL`]: it has its own subcommand), validated by the same schema gate.
pub const RPS_SWEEP: &str = "rps_sweep";

/// The scheduler lock-scaling microbenchmark written by `nalar bench
/// contention` (own subcommand, like [`RPS_SWEEP`]): submit/wake/poll/
/// complete throughput and p99 shard-lock hold time across worker-thread
/// × workflow × tenant sweeps. Schema arm `contention/v1`.
pub const CONTENTION: &str = "contention";

/// The kill-and-recover scenario written by `nalar bench recovery` (own
/// subcommand, like [`CONTENTION`]): a journal-enabled ingress is killed
/// mid-load ([`Ingress::halt`]), its journal replayed into a fresh
/// ingress ([`Ingress::recover_with`]), and every replayed request is
/// driven to completion. One point per fsync policy. Schema arm
/// `recovery/v1`.
pub const RECOVERY: &str = "recovery";

/// The JIT-model-routing comparison written by `nalar bench routing`
/// (own subcommand, like [`RECOVERY`]): the identical open-loop RPS
/// point run once per routing arm — `jit` against a `fixed-large` pin —
/// over a three-variant latency/quality table, reporting goodput and
/// dispatch-weighted mean quality per arm. The run itself gates on jit
/// achieving strictly higher goodput than the pin on at least one swept
/// rate (DESIGN.md §13). Schema arm `routing/v1`.
pub const ROUTING: &str = "routing";

/// Options for one `nalar bench` invocation.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// CI-smoke profile: scaled-down sweeps, shorter windows.
    pub quick: bool,
    /// Where `BENCH_*.json` files land (repo root by default).
    pub out_dir: PathBuf,
    /// Subset of [`ALL`] to run (`None` = everything).
    pub only: Option<Vec<String>>,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { quick: false, out_dir: PathBuf::from("."), only: None }
    }
}

impl BenchOpts {
    fn selected(&self, name: &str) -> bool {
        match &self.only {
            Some(list) => list.iter().any(|n| n == name),
            None => true,
        }
    }
}

fn check_known(names: &[String], known: &[&str]) -> Result<()> {
    for n in names {
        if !known.contains(&n.as_str()) {
            return Err(Error::Config(format!(
                "unknown bench `{n}` (known: {})",
                known.join(", ")
            )));
        }
    }
    Ok(())
}

/// Every report name the schema gate accepts (`ALL` + the loadgen sweep
/// + the contention and recovery sweeps).
fn known_reports() -> Vec<&'static str> {
    let mut v = ALL.to_vec();
    v.push(RPS_SWEEP);
    v.push(CONTENTION);
    v.push(RECOVERY);
    v.push(ROUTING);
    v
}

/// Run the selected reproductions, validate each report against the
/// schema, and write `BENCH_<name>.json` files. Returns the paths written.
pub fn run(opts: &BenchOpts) -> Result<Vec<PathBuf>> {
    if let Some(list) = &opts.only {
        check_known(list, ALL)?;
    }
    let mut written = Vec::new();
    for name in ALL {
        if !opts.selected(name) {
            continue;
        }
        let t0 = Instant::now();
        let report = match *name {
            "fig9" => fig9(opts.quick)?,
            "fig10" => fig10(opts.quick)?,
            "table4" => table4(opts.quick)?,
            "sec62" => sec62(opts.quick)?,
            _ => unreachable!("ALL out of sync with run()"),
        };
        validate(&report)?;
        let path = write_report(&opts.out_dir, name, &report)?;
        println!("[bench] {name} done in {:.1?} -> {}", t0.elapsed(), path.display());
        written.push(path);
    }
    if written.is_empty() {
        return Err(Error::Config(format!(
            "no benches selected (known: {})",
            ALL.join(", ")
        )));
    }
    Ok(written)
}

/// Canonical report location for a bench name.
pub fn report_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("BENCH_{name}.json"))
}

/// Serialize a validated report to its canonical path.
pub fn write_report(dir: &Path, name: &str, report: &Value) -> Result<PathBuf> {
    let path = report_path(dir, name);
    std::fs::write(&path, report.pretty() + "\n")?;
    Ok(path)
}

/// Re-validate reports already on disk (CI's schema gate).
pub fn check_files(dir: &Path, names: &[&str]) -> Result<()> {
    let owned: Vec<String> = names.iter().map(|n| n.to_string()).collect();
    check_known(&owned, &known_reports())?;
    for name in names {
        let path = report_path(dir, name);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Msg(format!("{}: {e}", path.display())))?;
        let report = crate::util::json::parse(&text)?;
        validate(&report)
            .map_err(|e| Error::Msg(format!("{}: {e}", path.display())))?;
        println!("[bench] {} schema ok", path.display());
    }
    Ok(())
}

/// Enforce the `nalar-bench/v1` schema. Every point must carry its sweep
/// coordinates and a `latency` object with numeric `p50`/`p95`/`p99`.
pub fn validate(report: &Value) -> Result<()> {
    let fail = |msg: String| Error::Msg(format!("bench schema: {msg}"));
    if report.get("schema").as_str() != Some(SCHEMA) {
        return Err(fail(format!("`schema` must be \"{SCHEMA}\"")));
    }
    let bench = report
        .get("bench")
        .as_str()
        .ok_or_else(|| fail("missing `bench`".into()))?;
    if report.get("quick").as_bool().is_none() {
        return Err(fail("missing bool `quick`".into()));
    }
    if report.get("latency_unit").as_str().is_none() {
        return Err(fail("missing `latency_unit`".into()));
    }
    let points = report
        .get("points")
        .as_arr()
        .ok_or_else(|| fail("missing `points` array".into()))?;
    if points.is_empty() {
        return Err(fail("`points` is empty".into()));
    }
    // The contention report versions its point shape explicitly so later
    // PRs can evolve the hold-time fields without silently invalidating
    // recorded lock-scaling curves.
    if bench == CONTENTION && report.get("arm").as_str() != Some("contention/v1") {
        return Err(fail("contention report: `arm` must be \"contention/v1\"".into()));
    }
    // Same deal for the kill-and-recover scenario: the point shape is
    // versioned so the recorded recovery curves stay interpretable.
    if bench == RECOVERY && report.get("arm").as_str() != Some("recovery/v1") {
        return Err(fail("recovery report: `arm` must be \"recovery/v1\"".into()));
    }
    // And the routing comparison: its quality accounting columns are the
    // part later PRs must not silently drop.
    if bench == ROUTING && report.get("arm").as_str() != Some("routing/v1") {
        return Err(fail("routing report: `arm` must be \"routing/v1\"".into()));
    }
    let required: &[&str] = match bench {
        "fig9" => &["workflow", "system", "rps_wall", "rps_paper", "completed", "failed"],
        "fig10" => &["nodes", "agents", "futures"],
        "table4" => &["futures", "one_level", "speedup"],
        "sec62" => &["study", "policy"],
        "rps_sweep" => &[
            "workflow",
            "system",
            "transport",
            "rps_wall",
            "rps_paper",
            "offered",
            "completed",
            "failed",
            "expired_in_queue",
            "shed",
            "cancelled",
            "schedule",
            "tenants",
            "breakdown",
            "goodput_rps",
            "shed_rate",
        ],
        "contention" => &[
            "threads",
            "workflows",
            "tenants",
            "total",
            "completed",
            "submit_per_s",
            "poll_per_s",
            "complete_per_s",
            "wake_per_s",
            "hold",
        ],
        "recovery" => &[
            "fsync",
            "submitted",
            "completed_before_crash",
            "inflight_at_crash",
            "skipped_complete",
            "recovered",
            "recovered_completed",
            "lost",
            "corrupt",
            "replay_ms",
        ],
        "routing" => &[
            "workflow",
            "system",
            "route",
            "rps_wall",
            "offered",
            "completed",
            "shed",
            "expired_in_queue",
            "goodput_rps",
            "quality_floor",
            "quality_mean",
        ],
        other => return Err(fail(format!("unknown bench `{other}`"))),
    };
    for (i, p) in points.iter().enumerate() {
        for key in required {
            if p.get(key).is_null() {
                return Err(fail(format!("{bench} point {i}: missing `{key}`")));
            }
        }
        // `transport` says which submit path produced the point: the
        // in-process API or the HTTP serving plane. Anything else is a
        // typo the consumers downstream would silently mis-bucket.
        if bench == "rps_sweep"
            && !matches!(p.get("transport").as_str(), Some("inproc") | Some("http"))
        {
            return Err(fail(format!(
                "{bench} point {i}: `transport` must be \"inproc\" or \"http\""
            )));
        }
        // The per-tenant split must be a non-empty map: every point has
        // at least the implicit `default` tenant, and each entry carries
        // its own goodput (the ROADMAP's "report per-tenant goodput in
        // the rps_sweep schema").
        if bench == "rps_sweep" {
            match p.get("tenants").as_obj() {
                Some(m) if !m.is_empty() => {
                    for (name, t) in m {
                        for key in ["offered", "completed", "shed", "goodput_rps", "weight"] {
                            if t.get(key).is_null() {
                                return Err(fail(format!(
                                    "{bench} point {i}: tenant `{name}` missing `{key}`"
                                )));
                            }
                        }
                    }
                }
                _ => {
                    return Err(fail(format!(
                        "{bench} point {i}: `tenants` must be a non-empty map"
                    )))
                }
            }
            // The per-stage latency decomposition (DESIGN.md §10): one
            // entry per stage, each with its own quantiles and fold count
            // — the fields the saturation analysis reads to tell
            // queueing delay from service time.
            for stage in crate::metrics::STAGE_NAMES {
                let s = p.get("breakdown").get(stage);
                for q in ["p50", "p95", "p99"] {
                    if s.get(q).as_f64().is_none() {
                        return Err(fail(format!(
                            "{bench} point {i}: breakdown.{stage}.{q} not numeric"
                        )));
                    }
                }
                if s.get("count").as_u64().is_none() {
                    return Err(fail(format!(
                        "{bench} point {i}: breakdown.{stage}.count not an integer"
                    )));
                }
            }
        }
        // Each point of the lock-scaling curve carries a per-op
        // critical-section hold-time block; p99 hold-ns is the headline
        // the curve regresses against.
        if bench == "contention" {
            for op in ["submit", "wake", "poll", "complete", "sweep"] {
                let h = p.get("hold").get(op);
                for q in ["p50_ns", "p95_ns", "p99_ns"] {
                    if h.get(q).as_f64().is_none() {
                        return Err(fail(format!(
                            "{bench} point {i}: hold.{op}.{q} not numeric"
                        )));
                    }
                }
                if h.get("count").as_u64().is_none() {
                    return Err(fail(format!(
                        "{bench} point {i}: hold.{op}.count not an integer"
                    )));
                }
            }
        }
        // Recovery points must conserve requests: everything admitted is
        // either terminal before the crash or in flight at it, and every
        // in-flight request is either replayed or accounted lost.
        if bench == RECOVERY {
            let n = |k: &str| p.get(k).as_u64();
            let (Some(sub), Some(done), Some(inflight), Some(rec), Some(lost)) = (
                n("submitted"),
                n("completed_before_crash"),
                n("inflight_at_crash"),
                n("recovered"),
                n("lost"),
            ) else {
                return Err(fail(format!("{bench} point {i}: counts must be integers")));
            };
            if done + inflight != sub || rec + lost != inflight {
                return Err(fail(format!(
                    "{bench} point {i}: counts don't conserve \
                     (submitted = completed_before_crash + inflight_at_crash, \
                     inflight_at_crash = recovered + lost)"
                )));
            }
            if p.get("replay_ms").as_f64().is_none() {
                return Err(fail(format!("{bench} point {i}: replay_ms not numeric")));
            }
        }
        // A routing arm must actually have dispatched through its variant
        // table: the per-variant split is what the quality accounting and
        // the goodput-at-equal-quality claim rest on.
        if bench == ROUTING {
            match p.get("variants").as_obj() {
                Some(m) if !m.is_empty() => {}
                _ => {
                    return Err(fail(format!(
                        "{bench} point {i}: `variants` must be a non-empty map"
                    )))
                }
            }
            for q in ["quality_floor", "quality_mean"] {
                if p.get(q).as_f64().is_none() {
                    return Err(fail(format!("{bench} point {i}: {q} not numeric")));
                }
            }
        }
        let lat = p.get("latency");
        for q in ["p50", "p95", "p99"] {
            if lat.get(q).as_f64().is_none() {
                return Err(fail(format!("{bench} point {i}: latency.{q} not numeric")));
            }
        }
    }
    Ok(())
}

pub(crate) fn report(bench: &str, quick: bool, latency_unit: &str, points: Vec<Value>) -> Value {
    let mut v = json!({
        "schema": SCHEMA,
        "bench": bench,
        "quick": quick,
        "latency_unit": latency_unit
    });
    v.insert("points", Value::Arr(points));
    v
}

fn full_env() -> bool {
    std::env::var("NALAR_BENCH_FULL").is_ok()
}

// ------------------------------------------------------------------- fig 9

/// Fig. 9: end-to-end latency vs request rate, three workflows × systems.
/// Latencies are reported in paper-equivalent seconds.
pub fn fig9(quick: bool) -> Result<Value> {
    let plan: Vec<(WorkflowKind, Vec<f64>)> = if quick {
        vec![
            (WorkflowKind::Financial, vec![40.0]),
            (WorkflowKind::Router, vec![120.0]),
            (WorkflowKind::Swe, vec![20.0]),
        ]
    } else {
        vec![
            (WorkflowKind::Financial, vec![40.0, 80.0, 120.0, 160.0]),
            (WorkflowKind::Router, vec![120.0, 240.0, 360.0, 480.0]),
            (WorkflowKind::Swe, vec![20.0, 40.0, 60.0, 80.0]),
        ]
    };
    let systems: Vec<SystemUnderTest> = if quick {
        vec![SystemUnderTest::Nalar, SystemUnderTest::AutoGenLike]
    } else {
        SystemUnderTest::all().to_vec()
    };
    let secs = if quick {
        1
    } else if full_env() {
        10
    } else {
        4
    };

    let mut points = Vec::new();
    for (wf, rates) in &plan {
        let mut table = Table::new(&[
            "system", "rate", "avg(s)", "p50(s)", "p95(s)", "p99(s)", "ok", "fail", "imbalance",
        ]);
        for &rps in rates {
            for &system in &systems {
                let mut cfg = wf.config();
                if quick {
                    cfg.time_scale = 0.002;
                }
                let d = Deployment::launch_as(cfg, system)?;
                let rc = RunConfig {
                    workflow: *wf,
                    rps,
                    duration: Duration::from_secs(secs),
                    session_pool: if quick { 16 } else { 48 },
                    request_timeout: Duration::from_secs(6),
                    seed: 0xF19,
                };
                let (stats, rec) = run_open_loop(&d, &rc);
                let paper = rec.summary_scaled(1.0 / stats.time_scale);
                table.row(&[
                    system.name().to_string(),
                    format!("{:.1}", rps * stats.time_scale),
                    format!("{:.0}", paper.avg),
                    format!("{:.0}", paper.p50),
                    format!("{:.0}", paper.p95),
                    format!("{:.0}", paper.p99),
                    stats.completed.to_string(),
                    stats.failed.to_string(),
                    format!("{:.2}", stats.imbalance),
                ]);
                let mut p = json!({
                    "workflow": wf.name(),
                    "system": system.name(),
                    "rps_wall": rps,
                    "rps_paper": rps * stats.time_scale,
                    "duration_s": secs,
                    "completed": stats.completed,
                    "failed": stats.failed,
                    "imbalance": stats.imbalance
                });
                p.insert("latency", paper.to_json());
                points.push(p);
                d.shutdown();
            }
        }
        println!("\n=== Fig 9 — {} workflow ===", wf.name());
        table.print();
    }
    Ok(report("fig9", quick, "paper_s", points))
}

// ------------------------------------------------------------------ fig 10

/// Build the Fig-10 control plane: `agents` instances spread over `nodes`
/// emulated nodes with telemetry in place, plus `futures` live futures in
/// the table, under an SRTF policy. The receivers keep the bus endpoints
/// deliverable for the measurement's lifetime.
fn control_plane(
    nodes: u32,
    agents: u32,
    futures: usize,
) -> (Arc<GlobalController>, Vec<Receiver<Message>>) {
    let node_ids: Vec<NodeId> = (0..nodes).map(NodeId).collect();
    let bus = Bus::new(Duration::ZERO);
    let stores = StoreDirectory::new(&node_ids);
    let loads = LoadMap::new();
    let table = Arc::new(FutureTable::new());
    let router = Arc::new(Router::new(bus.clone(), loads.clone(), 1));

    let mut rxs = Vec::with_capacity(agents as usize);
    for a in 0..agents {
        let id = InstanceId::new("agent", a);
        let node = NodeId(a % nodes);
        rxs.push(bus.register(id.clone(), node));
        loads.register(id.clone());
        stores.node(node).put(
            &keys::instance_metrics(&id),
            InstanceMetrics {
                agent: "agent".into(),
                node: node.0,
                queue_len: (a % 7) as usize,
                waiting_sessions: vec![(SessionId(a as u64), 50 + a as u64)],
                oldest_wait_ms: 50 + a as u64,
                ..Default::default()
            },
        );
    }
    for i in 0..futures {
        let mut meta = FutureMeta::new(
            FutureId(i as u64),
            SessionId((i % 1024) as u64),
            RequestId((i % 4096) as u64),
            AgentType::new("agent"),
            "m",
            Location::Driver(RequestId(0)),
        );
        meta.stage = (i % 5) as u32;
        table.insert(FutureCell::new(meta));
    }
    let g = GlobalController::new(
        bus,
        stores,
        router,
        loads,
        table,
        vec![make_policy("srtf").expect("srtf registered")],
        Arc::new(|_| None),
    );
    (g, rxs)
}

/// Fig. 10: global control-loop latency vs live futures. The full profile
/// reaches the paper's 131K futures / 128 agents point; latencies are in
/// milliseconds per loop iteration.
pub fn fig10(quick: bool) -> Result<Value> {
    let configs: &[(u32, u32)] = if quick { &[(8, 16)] } else { &[(32, 64), (64, 128)] };
    let sweep: &[usize] = if quick {
        &[1024, 8192]
    } else {
        &[1024, 4096, 16384, 65536, 131072]
    };
    let iters = if quick { 3u32 } else { 5 };

    let mut table = Table::new(&[
        "nodes", "agents", "futures", "collect(ms)", "policy(ms)", "apply(ms)", "p50(ms)",
        "p99(ms)",
    ]);
    let mut points = Vec::new();
    for &(nodes, agents) in configs {
        for &futures in sweep {
            let (g, _rxs) = control_plane(nodes, agents, futures);
            g.tick(); // warm
            let rec = LatencyRecorder::new();
            let (mut collect_s, mut policy_s, mut apply_s) = (0.0f64, 0.0, 0.0);
            for _ in 0..iters {
                let t = g.tick();
                rec.record(t.total());
                collect_s += t.collect.as_secs_f64();
                policy_s += t.policy.as_secs_f64();
                apply_s += t.apply.as_secs_f64();
            }
            let ms = rec.summary_scaled(1e3);
            let n = iters as f64;
            table.row(&[
                nodes.to_string(),
                agents.to_string(),
                futures.to_string(),
                format!("{:.1}", collect_s / n * 1e3),
                format!("{:.1}", policy_s / n * 1e3),
                format!("{:.1}", apply_s / n * 1e3),
                format!("{:.1}", ms.p50),
                format!("{:.1}", ms.p99),
            ]);
            let mut p = json!({
                "nodes": nodes,
                "agents": agents,
                "futures": futures,
                "iters": iters,
                "collect_ms_avg": collect_s / n * 1e3,
                "policy_ms_avg": policy_s / n * 1e3,
                "apply_ms_avg": apply_s / n * 1e3
            });
            p.insert("latency", ms.to_json());
            points.push(p);
        }
    }
    println!("\n=== Fig 10 — global control loop latency vs #futures ===");
    table.print();
    println!("paper reference: 64 nodes/131K futures => 464ms total, >65% policy");
    Ok(report("fig10", quick, "ms", points))
}

// ----------------------------------------------------------------- table 4

fn table4_router(agents: u32) -> (Bus, Arc<Router>, Vec<Receiver<Message>>) {
    let bus = Bus::new(Duration::ZERO);
    let loads = LoadMap::new();
    let mut rxs = Vec::with_capacity(agents as usize);
    for a in 0..agents {
        let id = InstanceId::new("agent", a);
        rxs.push(bus.register(id.clone(), NodeId(a % 64)));
        loads.register(id);
    }
    let router = Arc::new(Router::new(bus.clone(), loads, 9));
    (bus, router, rxs)
}

/// One-level: all pending futures drain through one decision loop; a probe
/// future submitted at the back observes the queueing delay.
fn one_level(pending: usize, router: &Router) -> Duration {
    let t0 = Instant::now();
    for i in 0..pending {
        let _ = router.route(SessionId(i as u64), "agent", false);
    }
    let _ = router.route(SessionId(pending as u64), "agent", false);
    t0.elapsed()
}

/// Two-level: the same pending work is split across component-level
/// controllers running concurrently; the probe only waits for one local
/// decision.
fn two_level(pending: usize, controllers: usize, router: &Arc<Router>) -> Duration {
    let per = pending / controllers.max(1);
    std::thread::scope(|scope| {
        for c in 0..controllers {
            let router = router.clone();
            scope.spawn(move || {
                for i in 0..per {
                    let _ = router.route(SessionId((c * per + i) as u64), "agent", false);
                }
            });
        }
        let t0 = Instant::now();
        let _ = router.route(SessionId(u64::MAX), "agent", false);
        t0.elapsed()
    })
}

/// Table 4: per-future scheduling latency, one-level vs two-level, swept
/// over the pending-future count. Latencies are in milliseconds.
pub fn table4(quick: bool) -> Result<Value> {
    let agents: u32 = if quick { 32 } else { 128 };
    let controllers: usize = agents as usize;
    let sweep: &[usize] = if quick {
        &[1024, 8192]
    } else {
        &[1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072]
    };
    let reps = 3;

    let mut table = Table::new(&["futures", "one-level(ms)", "two-level p50(ms)", "ratio"]);
    let mut points = Vec::new();
    for &futures in sweep {
        let one_rec = LatencyRecorder::new();
        let two_rec = LatencyRecorder::new();
        for _ in 0..reps {
            let (_bus1, r1, _rx1) = table4_router(agents);
            one_rec.record(one_level(futures, &r1));
            let (_bus2, r2, _rx2) = table4_router(agents);
            two_rec.record(two_level(futures, controllers, &r2));
        }
        let one_ms = one_rec.summary_scaled(1e3);
        let two_ms = two_rec.summary_scaled(1e3);
        let speedup = one_ms.p50 / two_ms.p50.max(1e-9);
        table.row(&[
            futures.to_string(),
            format!("{:.2}", one_ms.p50),
            format!("{:.3}", two_ms.p50),
            format!("{speedup:.0}x"),
        ]);
        let mut p = json!({
            "futures": futures,
            "agents": agents,
            "reps": reps,
            "speedup": speedup
        });
        p.insert("one_level", one_ms.to_json());
        // `latency` is the two-level (NALAR) number — the regression target.
        p.insert("latency", two_ms.to_json());
        points.push(p);
    }
    println!("\n=== Table 4 — per-future scheduling: one-level vs two-level ===");
    table.print();
    println!("paper reference: one-level 1.2 -> 72.3 ms; two-level 0.1 -> 0.4 ms");
    Ok(report("table4", quick, "ms", points))
}

// ------------------------------------------------------------------- §6.2

/// §6.2: SRTF-vs-FCFS (minimize JCT, financial workflow) and LPT-vs-FCFS
/// (control makespan, SWE closed batch). Latencies in paper seconds.
pub fn sec62(quick: bool) -> Result<Value> {
    let mut points = Vec::new();

    // Minimize JCT — open loop on the financial workflow.
    let mut jct_results: Vec<(f64, f64)> = Vec::new(); // (avg, p95) paper-s
    for policy in ["fcfs", "srtf"] {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.policies = vec!["load_balance".into(), policy.into()];
        if quick {
            cfg.time_scale = 0.002;
        }
        let d = Deployment::launch_as(cfg, SystemUnderTest::Nalar)?;
        let rc = RunConfig {
            workflow: WorkflowKind::Financial,
            rps: if quick { 60.0 } else { 110.0 },
            duration: Duration::from_secs(if quick { 1 } else { 5 }),
            session_pool: if quick { 16 } else { 48 },
            request_timeout: Duration::from_secs(8),
            seed: 62,
        };
        let (stats, rec) = run_open_loop(&d, &rc);
        let paper = rec.summary_scaled(1.0 / stats.time_scale);
        println!(
            "[sec62/jct] {policy}: avg {:.1} p95 {:.1} paper-s over {} requests",
            paper.avg, paper.p95, stats.completed
        );
        let mut p = json!({
            "study": "jct",
            "workflow": "financial",
            "policy": policy,
            "completed": stats.completed,
            "failed": stats.failed
        });
        p.insert("latency", paper.to_json());
        jct_results.push((paper.avg, paper.p95));
        points.push(p);
        d.shutdown();
    }
    // §6.2 headline: the SRTF-vs-FCFS deltas (paper: avg -2.4% / p95 +3.3%).
    if let [(avg_f, p95_f), (avg_s, p95_s)] = jct_results[..] {
        let avg_delta = 100.0 * (avg_s - avg_f) / avg_f.max(1e-9);
        let p95_delta = 100.0 * (p95_s - p95_f) / p95_f.max(1e-9);
        println!(
            "SRTF vs FCFS: avg JCT {avg_delta:+.1}%  p95 {p95_delta:+.1}%  \
             (paper: -2.4% / +3.3%)"
        );
        if let Some(p) = points.last_mut() {
            p.insert("avg_delta_pct_vs_fcfs", avg_delta);
            p.insert("p95_delta_pct_vs_fcfs", p95_delta);
        }
    }

    // Control makespan — closed batch on the SWE workflow.
    let batch = if quick { 8 } else { 36 };
    let mut makespan_results: Vec<(f64, f64)> = Vec::new(); // (makespan, p95)
    for policy in ["fcfs", "lpt"] {
        let mut cfg = WorkflowKind::Swe.config();
        cfg.policies = vec!["load_balance".into(), policy.into()];
        if quick {
            cfg.time_scale = 0.002;
        }
        let d = Deployment::launch_as(cfg, SystemUnderTest::Nalar)?;
        let time_scale = d.cfg().time_scale;
        let mut rng = Rng::new(62);
        let rec = LatencyRecorder::new();
        let ok = std::sync::atomic::AtomicU64::new(0);
        let t0 = Instant::now();
        let timeout = Duration::from_secs(30);
        std::thread::scope(|scope| {
            for _ in 0..batch {
                let session = d.new_session();
                let input = json!({"task": workload::swe_task(&mut rng)});
                let d = &d;
                let rec = &rec;
                let ok = &ok;
                scope.spawn(move || {
                    let t = Instant::now();
                    let res = run_request(d, WorkflowKind::Swe, session, &input, timeout);
                    rec.record(t.elapsed());
                    if res.is_ok() {
                        ok.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                });
            }
        });
        let makespan = t0.elapsed().as_secs_f64() / time_scale;
        let paper = rec.summary_scaled(1.0 / time_scale);
        println!(
            "[sec62/makespan] {policy}: makespan {makespan:.1} p95 JCT {:.1} paper-s ({}/{batch} ok)",
            paper.p95,
            ok.load(std::sync::atomic::Ordering::Relaxed)
        );
        let mut p = json!({
            "study": "makespan",
            "workflow": "swe",
            "policy": policy,
            "batch": batch,
            "completed": ok.load(std::sync::atomic::Ordering::Relaxed),
            "makespan_paper_s": makespan
        });
        p.insert("latency", paper.to_json());
        makespan_results.push((makespan, paper.p95));
        points.push(p);
        d.shutdown();
    }
    // §6.2 headline: the LPT-vs-FCFS deltas (paper: makespan -5.8% / p95 +2.6%).
    if let [(mk_f, p95_f), (mk_l, p95_l)] = makespan_results[..] {
        let mk_delta = 100.0 * (mk_l - mk_f) / mk_f.max(1e-9);
        let p95_delta = 100.0 * (p95_l - p95_f) / p95_f.max(1e-9);
        println!(
            "LPT vs FCFS: makespan {mk_delta:+.1}%  p95 {p95_delta:+.1}%  \
             (paper: -5.8% / +2.6%)"
        );
        if let Some(p) = points.last_mut() {
            p.insert("makespan_delta_pct_vs_fcfs", mk_delta);
            p.insert("p95_delta_pct_vs_fcfs", p95_delta);
        }
    }

    Ok(report("sec62", quick, "paper_s", points))
}

// ------------------------------------------------------------- contention

/// One cell of the lock-scaling sweep: `threads` submitter threads race a
/// same-sized scheduler pool over `nkinds` workflow shards split across
/// `ntenants` tenants. Every request is a scripted one-wait driver (see
/// [`crate::testkit::ScriptedEngine`]); a resolver thread plays the
/// engine, resolving each scripted call the moment it exists, so every
/// request exercises the full hot path exactly once: one submit, two
/// polls, one wake, one completion. Returns one schema point.
fn contention_point(threads: usize, nkinds: usize, ntenants: usize, total: usize) -> Result<Value> {
    let all_kinds = [WorkflowKind::Router, WorkflowKind::Financial, WorkflowKind::Swe];
    let kinds: Vec<WorkflowKind> = all_kinds[..nkinds].to_vec();
    let tenant_names: Vec<String> = (0..ntenants).map(|t| format!("t{t}")).collect();
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = 0.0005;
    if ntenants > 1 {
        // Equal-weight tenants with no token bucket: the DRR still splits
        // every shard's queue per tenant (the structure under test) while
        // admission stays unbounded — no submit may shed.
        cfg.ingress.tenants = tenant_names
            .iter()
            .map(|name| TenantSettings { name: name.clone(), ..TenantSettings::default() })
            .collect();
    }
    let d = Deployment::launch(cfg)?;
    let hold = HoldStats::new();
    let mut opts = SchedulerOpts::new(threads, total.max(1));
    opts.hold = Some(hold.clone());
    let ing = Ingress::start_with_opts(&d, &kinds, AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let deadline = Duration::from_secs(120);

    let t0 = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(total);
    let mut submit_secs = 0.0f64;
    let mut resolved = true;
    std::thread::scope(|s| {
        let mut subs = Vec::new();
        for w in 0..threads {
            let eng = eng.clone();
            let ing = &ing;
            let kinds = &kinds;
            let tenant_names = &tenant_names;
            subs.push(s.spawn(move || {
                let t = Instant::now();
                let mut out = Vec::new();
                let mut i = w;
                while i < total {
                    let mut req = SubmitRequest::workflow(kinds[i % kinds.len()])
                        .driver(eng.driver(&format!("c{i}"), 1))
                        .deadline(deadline);
                    if tenant_names.len() > 1 {
                        req = req.tenant(tenant_names[i % tenant_names.len()].clone());
                    }
                    out.push(ing.submit(req).expect("unbounded admission must accept"));
                    i += threads;
                }
                (out, t.elapsed().as_secs_f64())
            }));
        }
        let resolver = {
            let eng = eng.clone();
            s.spawn(move || {
                for i in 0..total {
                    if !eng.wait_created(i + 1, Duration::from_secs(60)) {
                        return false;
                    }
                    eng.cell(i).resolve(json!({"ok": true}), 1);
                }
                true
            })
        };
        for h in subs {
            let (out, secs) = h.join().expect("submitter panicked");
            tickets.extend(out);
            submit_secs = submit_secs.max(secs);
        }
        resolved = resolver.join().expect("resolver panicked");
    });
    if !resolved {
        return Err(Error::Msg("contention bench: scripted calls never appeared".into()));
    }
    let rec = LatencyRecorder::new();
    let mut completed = 0usize;
    for t in &tickets {
        t.wait(deadline)?;
        completed += 1;
        if let Some(l) = t.latency() {
            rec.record(l);
        }
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    ing.stop();
    d.shutdown();

    // Per-op critical-section hold times: the histograms record
    // microseconds, so quantile * 1000 is nanoseconds.
    let mut holds = json!({});
    for (name, op) in [
        ("submit", HoldOp::Submit),
        ("wake", HoldOp::Wake),
        ("poll", HoldOp::Poll),
        ("complete", HoldOp::Complete),
        ("sweep", HoldOp::Sweep),
    ] {
        let st = hold.snapshot(op).stat();
        holds.insert(
            name,
            json!({
                "count": st.count,
                "p50_ns": st.p50 * 1000.0,
                "p95_ns": st.p95 * 1000.0,
                "p99_ns": st.p99 * 1000.0
            }),
        );
    }
    let mut p = json!({
        "threads": threads,
        "workflows": kinds.len(),
        "tenants": tenant_names.len(),
        "total": total,
        "completed": completed,
        "wall_s": wall,
        "submit_per_s": total as f64 / submit_secs.max(1e-9),
        "poll_per_s": 2.0 * total as f64 / wall,
        "complete_per_s": completed as f64 / wall,
        "wake_per_s": total as f64 / wall
    });
    p.insert("hold", holds);
    p.insert("latency", rec.summary_scaled(1e6).to_json());
    Ok(p)
}

/// `nalar bench contention`: the scheduler lock-scaling microbenchmark.
/// Sweeps worker-thread count × workflow (= shard) count × tenant count
/// and reports submit/wake/poll/complete throughput plus per-op p99
/// shard-lock hold time ([`crate::ingress::HoldStats`]) — the curve every
/// later PR regresses against (ROADMAP "sharded front door + hot-path
/// contention overhaul").
pub fn contention(quick: bool) -> Result<Value> {
    let threads: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8, 16] };
    let workflows: &[usize] = if quick { &[1] } else { &[1, 3] };
    let tenants: &[usize] = &[1, 4];
    let per_point = if quick { 240 } else { 2000 };

    let mut table =
        Table::new(&["threads", "wfs", "tenants", "submit/s", "complete/s", "poll p99 hold(ns)"]);
    let mut points = Vec::new();
    for &nw in workflows {
        for &nt in tenants {
            for &th in threads {
                let p = contention_point(th, nw, nt, per_point)?;
                table.row(&[
                    th.to_string(),
                    nw.to_string(),
                    nt.to_string(),
                    format!("{:.0}", p.get("submit_per_s").as_f64().unwrap_or(0.0)),
                    format!("{:.0}", p.get("complete_per_s").as_f64().unwrap_or(0.0)),
                    format!(
                        "{:.0}",
                        p.get("hold").get("poll").get("p99_ns").as_f64().unwrap_or(0.0)
                    ),
                ]);
                points.push(p);
            }
        }
    }
    println!("\n=== Contention — shard-lock scaling ===");
    table.print();
    let mut r = report(CONTENTION, quick, "us", points);
    r.insert("arm", "contention/v1");
    Ok(r)
}

/// Run the contention sweep, schema-validate it, and write
/// `BENCH_contention.json` (the `nalar bench contention` subcommand).
pub fn run_contention(quick: bool, out_dir: &Path) -> Result<PathBuf> {
    let t0 = Instant::now();
    let r = contention(quick)?;
    validate(&r)?;
    let path = write_report(out_dir, CONTENTION, &r)?;
    println!("[bench] contention done in {:.1?} -> {}", t0.elapsed(), path.display());
    Ok(path)
}

// --------------------------------------------------------------- recovery

/// One kill-and-recover cell. Phase 1 runs a journal-enabled ingress
/// under `fsync`, submits `total` one-wait scripted requests, resolves
/// the first `pre` of them (their terminal outcomes reach the journal),
/// then kills the node with [`Ingress::halt`] — no drain, no shed, the
/// crash-realistic stop. Phase 2 folds the journal
/// ([`crate::journal::load`]), replays it into a fresh deployment
/// ([`Ingress::recover_with`]), re-resolves every re-issued scripted
/// call, and drives all survivors to completion. Returns one schema
/// point; the `latency` block is the recovered requests'
/// replay-to-terminal time in milliseconds.
fn recovery_point(total: usize, pre: usize, fsync: FsyncPolicy) -> Result<Value> {
    let path = std::env::temp_dir().join(format!(
        "nalar-bench-recovery-{}-{}-{total}.jsonl",
        std::process::id(),
        fsync.name()
    ));
    let _ = std::fs::remove_file(&path);
    let kinds = [WorkflowKind::Router];
    let deadline = Duration::from_secs(120);

    // Phase 1: load the node, then kill it mid-flight.
    let mut cfg = WorkflowKind::Router.config();
    cfg.time_scale = 0.0005;
    let d = Deployment::launch(cfg)?;
    let mut opts = SchedulerOpts::new(2, total.max(1));
    opts.journal = JournalSink::open(&path, fsync)?;
    let ing = Ingress::start_with_opts(&d, &kinds, AdmissionPolicy::Unbounded, opts);
    let eng = ScriptedEngine::new();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(total);
    for i in 0..total {
        let req = SubmitRequest::workflow(WorkflowKind::Router)
            .driver(eng.driver(&format!("r{i}"), 1))
            .deadline(deadline);
        tickets.push(ing.submit(req)?);
    }
    if !eng.wait_created(total, Duration::from_secs(60)) {
        return Err(Error::Msg("recovery bench: scripted calls never appeared".into()));
    }
    for i in 0..pre {
        eng.cell(i).resolve(json!({"ok": true}), 1);
    }
    // Wait until the `pre` resolved requests reach terminal (their
    // records hit the journal); everything else stays parked — in
    // flight at the crash by construction.
    let t0 = Instant::now();
    let mut done = vec![false; total];
    let mut finished = 0usize;
    while finished < pre && t0.elapsed() < Duration::from_secs(60) {
        for (i, t) in tickets.iter().enumerate() {
            if !done[i] && t.try_take().is_some() {
                done[i] = true;
                finished += 1;
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    if finished < pre {
        return Err(Error::Msg("recovery bench: pre-crash completions never landed".into()));
    }
    ing.halt();
    d.shutdown();
    drop(tickets); // the dead node's callers are gone too

    // Phase 2: fold the journal and replay it into a fresh node.
    let plan = crate::journal::load(&path)?;
    let completed_before = plan.completed;
    let inflight_at_crash = plan.inflight.len();
    let mut cfg2 = WorkflowKind::Router.config();
    cfg2.time_scale = 0.0005;
    let d2 = Deployment::launch(cfg2)?;
    let mut opts2 = SchedulerOpts::new(2, total.max(1));
    opts2.journal = JournalSink::open(&path, fsync)?;
    let ing2 = Ingress::start_with_opts(&d2, &kinds, AdmissionPolicy::Unbounded, opts2);
    let eng2 = ScriptedEngine::new();
    let t_replay = Instant::now();
    let outcome = ing2.recover_with(&plan, |_, _, _| eng2.driver("replay", 1));
    let replay_ms = t_replay.elapsed().as_secs_f64() * 1e3;
    let stats = outcome.stats.clone();
    if stats.recovered > 0 {
        if !eng2.wait_created(stats.recovered, Duration::from_secs(60)) {
            return Err(Error::Msg("recovery bench: replayed calls never re-issued".into()));
        }
        for i in 0..stats.recovered {
            eng2.cell(i).resolve(json!({"ok": true}), 1);
        }
    }
    let rec = LatencyRecorder::new();
    let mut recovered_completed = 0usize;
    for t in &outcome.tickets {
        t.wait(deadline)?;
        recovered_completed += 1;
        if let Some(l) = t.latency() {
            rec.record(l);
        }
    }
    if recovered_completed == 0 {
        rec.record(Duration::ZERO); // the schema needs quantiles even for an empty replay
    }
    ing2.stop();
    d2.shutdown();
    let _ = std::fs::remove_file(&path);

    let mut p = json!({
        "fsync": fsync.name(),
        "submitted": total,
        "completed_before_crash": completed_before,
        "inflight_at_crash": inflight_at_crash,
        "skipped_complete": stats.skipped_complete,
        "recovered": stats.recovered,
        "recovered_completed": recovered_completed,
        "lost": stats.lost,
        "corrupt": stats.corrupt,
        "replay_ms": replay_ms
    });
    p.insert("latency", rec.summary_scaled(1e3).to_json());
    Ok(p)
}

/// `nalar bench recovery`: the kill-and-recover scenario (ROADMAP
/// "durable request journal"). One point per fsync policy, so the
/// report shows what each durability level costs and that replay is
/// lossless under all of them (`lost` stays 0, counts conserve — the
/// schema gate enforces both).
pub fn recovery(quick: bool) -> Result<Value> {
    let (total, pre) = if quick { (64, 16) } else { (512, 128) };
    let policies: &[FsyncPolicy] = if quick {
        &[FsyncPolicy::Batch]
    } else {
        &[FsyncPolicy::Always, FsyncPolicy::Batch, FsyncPolicy::Never]
    };
    let mut table = Table::new(&[
        "fsync", "submitted", "done@crash", "inflight", "recovered", "lost", "replay(ms)",
    ]);
    let mut points = Vec::new();
    for &f in policies {
        let p = recovery_point(total, pre, f)?;
        table.row(&[
            f.name().to_string(),
            p.get("submitted").as_u64().unwrap_or(0).to_string(),
            p.get("completed_before_crash").as_u64().unwrap_or(0).to_string(),
            p.get("inflight_at_crash").as_u64().unwrap_or(0).to_string(),
            p.get("recovered").as_u64().unwrap_or(0).to_string(),
            p.get("lost").as_u64().unwrap_or(0).to_string(),
            format!("{:.1}", p.get("replay_ms").as_f64().unwrap_or(0.0)),
        ]);
        points.push(p);
    }
    println!("\n=== Recovery — kill-and-recover via the request journal ===");
    table.print();
    let mut r = report(RECOVERY, quick, "ms", points);
    r.insert("arm", "recovery/v1");
    Ok(r)
}

/// Run the kill-and-recover scenario, schema-validate it, and write
/// `BENCH_recovery.json` (the `nalar bench recovery` subcommand).
pub fn run_recovery(quick: bool, out_dir: &Path) -> Result<PathBuf> {
    let t0 = Instant::now();
    let r = recovery(quick)?;
    validate(&r)?;
    let path = write_report(out_dir, RECOVERY, &r)?;
    println!("[bench] recovery done in {:.1?} -> {}", t0.elapsed(), path.display());
    Ok(path)
}

// ---------------------------------------------------------------- routing

/// The bench's three-variant latency/quality curve (also the reference
/// table in `configs/*.json` and DESIGN.md §13): a fast draft-class
/// model, the calibrated base profile, and a large high-quality model.
fn routing_variants() -> Vec<ModelVariant> {
    vec![
        ModelVariant { name: "fast".into(), latency_mult: 0.35, quality: 0.82 },
        ModelVariant { name: "base".into(), latency_mult: 1.0, quality: 0.92 },
        ModelVariant { name: "large".into(), latency_mult: 2.2, quality: 0.99 },
    ]
}

/// Dispatch-weighted mean quality of one arm's per-variant counts (0.0
/// before anything was dispatched — the validator's non-empty-map check
/// keeps that out of written reports).
fn quality_mean(variants: &[ModelVariant], counts: &Value) -> f64 {
    let mut n = 0.0f64;
    let mut sum = 0.0f64;
    for v in variants {
        let c = counts.get(&v.name).as_f64().unwrap_or(0.0);
        n += c;
        sum += c * v.quality;
    }
    if n > 0.0 {
        sum / n
    } else {
        0.0
    }
}

/// `nalar bench routing`: the JIT-routing goodput comparison (DESIGN.md
/// §13). Each swept rate runs the identical open-loop point twice — once
/// pinned to the large variant (`fixed-large`: every call pays 2.2x
/// latency for 0.99 quality) and once under `jit` with the `jit_route`
/// policy tuning the thresholds — against a deadline sized so the base
/// curve fits comfortably and the pinned-large curve does not. The run
/// errors unless jit achieves strictly higher goodput than the pin on at
/// least one swept rate: the claim this subcommand exists to measure.
pub fn routing(quick: bool) -> Result<Value> {
    let variants = routing_variants();
    let floor = crate::coordinator::policies::JitRoute::default().quality_floor;
    let rates: Vec<f64> = if quick { vec![60.0, 120.0] } else { vec![40.0, 80.0, 120.0, 160.0] };
    let routes = ["fixed-large", "jit"];
    let mut table = Table::new(&[
        "route", "rps", "offered", "ok", "shed", "expired", "goodput", "quality", "p50(s)",
        "p99(s)",
    ]);
    let mut points = Vec::new();
    let mut jit_beats_pin = false;
    for &rps in &rates {
        let mut goodputs = [0.0f64; 2];
        for (ri, route) in routes.iter().enumerate() {
            let opts = LoadgenOpts {
                systems: vec![SystemUnderTest::Nalar],
                rates: vec![rps],
                secs: if quick { 1 } else { 4 },
                session_pool: 16,
                // ~1.3 paper-s for the base chat path, ~3.6 for the base
                // coder path: a 4 paper-s deadline admits the base curve
                // and rejects most of the 2.2x one.
                timeout_paper_s: 4.0,
                // 80ms wall deadlines: tight enough to discriminate, wide
                // enough that scheduler jitter doesn't decide the arms.
                time_scale: Some(0.02),
                // Pin the policy list so both arms run identical control:
                // `jit_route` is inert on the pinned arm (it only tunes
                // front doors whose route is `jit`), and the provisioning
                // / realloc policies would add cross-arm noise.
                policies: Some(vec!["load_balance".into(), "jit_route".into()]),
                variants: Some(variants.clone()),
                ..LoadgenOpts::quick(WorkflowKind::Router)
            };
            let mut p = run_point(&opts, rps, SystemUnderTest::Nalar, None, Some(route))?;
            let q = quality_mean(&variants, p.get("variants"));
            p.insert("quality_floor", floor);
            p.insert("quality_mean", q);
            goodputs[ri] = p.get("goodput_rps").as_f64().unwrap_or(0.0);
            table.row(&[
                route.to_string(),
                format!("{rps:.0}"),
                p.get("offered").as_u64().unwrap_or(0).to_string(),
                p.get("completed").as_u64().unwrap_or(0).to_string(),
                p.get("shed").as_u64().unwrap_or(0).to_string(),
                p.get("expired_in_queue").as_u64().unwrap_or(0).to_string(),
                format!("{:.1}", goodputs[ri]),
                format!("{q:.3}"),
                format!("{:.1}", p.get("latency").get("p50").as_f64().unwrap_or(0.0)),
                format!("{:.1}", p.get("latency").get("p99").as_f64().unwrap_or(0.0)),
            ]);
            points.push(p);
        }
        println!(
            "[bench/routing] @ {rps:.0} rps: jit {:.1} vs fixed-large {:.1} goodput rps",
            goodputs[1], goodputs[0]
        );
        if goodputs[1] > goodputs[0] {
            jit_beats_pin = true;
        }
    }
    println!("\n=== Routing — jit vs fixed-large at quality floor {floor} ===");
    table.print();
    if !jit_beats_pin {
        return Err(Error::Msg(
            "routing bench: jit never beat the fixed-large pin on goodput at any swept rate"
                .into(),
        ));
    }
    let mut r = report(ROUTING, quick, "paper_s", points);
    r.insert("arm", "routing/v1");
    r.insert("quality_floor", floor);
    Ok(r)
}

/// Run the routing comparison, schema-validate it, and write
/// `BENCH_routing.json` (the `nalar bench routing` subcommand).
pub fn run_routing(quick: bool, out_dir: &Path) -> Result<PathBuf> {
    let t0 = Instant::now();
    let r = routing(quick)?;
    validate(&r)?;
    let path = write_report(out_dir, ROUTING, &r)?;
    println!("[bench] routing done in {:.1?} -> {}", t0.elapsed(), path.display());
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn minimal_report(bench: &str, point: Value) -> Value {
        report(bench, true, "ms", vec![point])
    }

    fn lat() -> Value {
        json!({"count": 3, "avg": 1.0, "p50": 1.0, "p95": 2.0, "p99": 2.0, "max": 2.0})
    }

    #[test]
    fn validate_accepts_well_formed_reports() {
        let mut p = json!({"nodes": 8, "agents": 16, "futures": 1024});
        p.insert("latency", lat());
        validate(&minimal_report("fig10", p)).unwrap();
    }

    #[test]
    fn validate_rejects_missing_quantiles() {
        let mut p = json!({"nodes": 8, "agents": 16, "futures": 1024});
        p.insert("latency", json!({"p50": 1.0}));
        let err = validate(&minimal_report("fig10", p)).unwrap_err();
        assert!(err.to_string().contains("p95"));
    }

    #[test]
    fn validate_rejects_missing_sweep_keys() {
        let mut p = json!({"nodes": 8, "agents": 16});
        p.insert("latency", lat());
        let err = validate(&minimal_report("fig10", p)).unwrap_err();
        assert!(err.to_string().contains("futures"), "{err}");
    }

    #[test]
    fn validate_rejects_wrong_schema_and_empty_points() {
        let bad = json!({"schema": "nope", "bench": "fig10", "quick": true});
        assert!(validate(&bad).is_err());
        let empty = report("fig10", true, "ms", vec![]);
        assert!(validate(&empty).is_err());
    }

    fn tenants_map() -> Value {
        json!({"default": {
            "weight": 1.0, "offered": 640, "completed": 600, "shed": 30, "cancelled": 2,
            "missed": 8, "goodput_rps": 75.0
        }})
    }

    /// A full five-stage decomposition, one entry per
    /// [`crate::metrics::STAGE_NAMES`].
    fn breakdown_map() -> Value {
        let mut m = crate::util::json::Map::new();
        for stage in crate::metrics::STAGE_NAMES {
            m.insert(
                stage.to_string(),
                json!({"p50": 0.1, "p95": 0.4, "p99": 0.9, "count": 600}),
            );
        }
        Value::Obj(m)
    }

    #[test]
    fn validate_accepts_rps_sweep_points() {
        let mut p = json!({
            "workflow": "router", "system": "NALAR", "transport": "inproc",
            "rps_wall": 80.0, "rps_paper": 8.0,
            "offered": 640, "completed": 600, "failed": 4, "expired_in_queue": 4, "shed": 30,
            "cancelled": 2, "schedule": "deadline_slack",
            "goodput_rps": 75.0, "shed_rate": 0.047
        });
        p.insert("latency", lat());
        p.insert("tenants", tenants_map());
        p.insert("breakdown", breakdown_map());
        validate(&minimal_report("rps_sweep", p.clone())).unwrap();
        // both transports validate; anything else is rejected
        p.insert("transport", "http");
        validate(&minimal_report("rps_sweep", p.clone())).unwrap();
        // a decomposition missing a stage (or a stage's count) fails
        let mut partial = p.clone();
        partial.insert("breakdown", json!({"queue_wait": {"p50": 0.1, "p95": 0.4, "p99": 0.9,
            "count": 600}}));
        let err = validate(&minimal_report("rps_sweep", partial)).unwrap_err();
        assert!(err.to_string().contains("breakdown.sched_delay"), "{err}");
        p.insert("transport", "carrier-pigeon");
        let err = validate(&minimal_report("rps_sweep", p)).unwrap_err();
        assert!(err.to_string().contains("transport"), "{err}");
        let mut missing = json!({"workflow": "router", "system": "NALAR"});
        missing.insert("latency", lat());
        assert!(validate(&minimal_report("rps_sweep", missing)).is_err());
        // pre-lifecycle reports (no `cancelled`/`schedule`) must fail now
        let mut stale = json!({
            "workflow": "router", "system": "NALAR", "transport": "inproc",
            "rps_wall": 80.0, "rps_paper": 8.0,
            "offered": 640, "completed": 600, "failed": 6, "expired_in_queue": 4, "shed": 30,
            "goodput_rps": 75.0, "shed_rate": 0.047
        });
        stale.insert("latency", lat());
        stale.insert("tenants", tenants_map());
        let err = validate(&minimal_report("rps_sweep", stale)).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "{err}");
    }

    #[test]
    fn validate_requires_the_per_tenant_map() {
        let base = || {
            let mut p = json!({
                "workflow": "router", "system": "NALAR", "transport": "inproc",
                "rps_wall": 80.0, "rps_paper": 8.0,
                "offered": 640, "completed": 600, "failed": 4, "expired_in_queue": 4,
                "shed": 30, "cancelled": 2, "schedule": "fifo",
                "goodput_rps": 75.0, "shed_rate": 0.047
            });
            p.insert("latency", lat());
            p.insert("breakdown", breakdown_map());
            p
        };
        // pre-tenancy reports (no map at all) fail
        let err = validate(&minimal_report("rps_sweep", base())).unwrap_err();
        assert!(err.to_string().contains("tenants"), "{err}");
        // an empty map fails: every point has at least the default tenant
        let mut empty = base();
        empty.insert("tenants", json!({}));
        assert!(validate(&minimal_report("rps_sweep", empty)).is_err());
        // a tenant entry without its goodput fails
        let mut no_goodput = base();
        no_goodput.insert("tenants", json!({"hog": {"weight": 1.0, "offered": 10,
            "completed": 5, "shed": 0}}));
        let err = validate(&minimal_report("rps_sweep", no_goodput)).unwrap_err();
        assert!(err.to_string().contains("goodput_rps"), "{err}");
    }

    /// A full per-op hold block, one entry per [`HoldOp`].
    fn hold_map() -> Value {
        let mut m = crate::util::json::Map::new();
        for op in ["submit", "wake", "poll", "complete", "sweep"] {
            m.insert(
                op.to_string(),
                json!({"count": 240, "p50_ns": 120.0, "p95_ns": 900.0, "p99_ns": 2400.0}),
            );
        }
        Value::Obj(m)
    }

    #[test]
    fn validate_accepts_contention_points() {
        let mut p = json!({
            "threads": 4, "workflows": 1, "tenants": 4, "total": 240, "completed": 240,
            "wall_s": 0.5, "submit_per_s": 1000.0, "poll_per_s": 960.0,
            "complete_per_s": 480.0, "wake_per_s": 480.0
        });
        p.insert("hold", hold_map());
        p.insert("latency", lat());
        // the report must carry the `contention/v1` arm tag
        let untagged = minimal_report(CONTENTION, p.clone());
        let err = validate(&untagged).unwrap_err();
        assert!(err.to_string().contains("contention/v1"), "{err}");
        let mut r = minimal_report(CONTENTION, p.clone());
        r.insert("arm", "contention/v1");
        validate(&r).unwrap();
        // a hold block missing an op (or its p99) fails
        let mut partial = p.clone();
        partial.insert(
            "hold",
            json!({"submit": {"count": 1, "p50_ns": 1.0, "p95_ns": 1.0, "p99_ns": 1.0}}),
        );
        let mut bad = minimal_report(CONTENTION, partial);
        bad.insert("arm", "contention/v1");
        let err = validate(&bad).unwrap_err();
        assert!(err.to_string().contains("hold.wake"), "{err}");
        // a point missing a sweep coordinate fails
        let mut missing = json!({"workflows": 1, "tenants": 1});
        missing.insert("hold", hold_map());
        missing.insert("latency", lat());
        let mut bad = minimal_report(CONTENTION, missing);
        bad.insert("arm", "contention/v1");
        let err = validate(&bad).unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
    }

    #[test]
    fn contention_point_reports_throughput_and_holds() {
        // One small real cell: 2 submitters × 2 tenants × 40 requests
        // through the sharded scheduler with hold instrumentation on.
        let p = contention_point(2, 1, 2, 40).unwrap();
        let mut r = minimal_report(CONTENTION, p);
        r.insert("arm", "contention/v1");
        validate(&r).unwrap();
        let p = &r.get("points").as_arr().unwrap()[0];
        assert_eq!(p.get("completed").as_u64(), Some(40));
        assert!(p.get("submit_per_s").as_f64().unwrap() > 0.0);
        // every submit held the shard lock exactly once
        assert_eq!(p.get("hold").get("submit").get("count").as_u64(), Some(40));
        assert!(p.get("hold").get("poll").get("count").as_u64().unwrap() >= 80);
    }

    /// A well-formed recovery point: 64 submitted, 16 terminal before
    /// the crash, all 48 survivors replayed and completed.
    fn recovery_base_point() -> Value {
        let mut p = json!({
            "fsync": "batch", "submitted": 64, "completed_before_crash": 16,
            "inflight_at_crash": 48, "skipped_complete": 16, "recovered": 48,
            "recovered_completed": 48, "lost": 0, "corrupt": 0, "replay_ms": 3.5
        });
        p.insert("latency", lat());
        p
    }

    #[test]
    fn validate_accepts_recovery_points() {
        // the report must carry the `recovery/v1` arm tag
        let untagged = minimal_report(RECOVERY, recovery_base_point());
        let err = validate(&untagged).unwrap_err();
        assert!(err.to_string().contains("recovery/v1"), "{err}");
        let mut r = minimal_report(RECOVERY, recovery_base_point());
        r.insert("arm", "recovery/v1");
        validate(&r).unwrap();
        // a missing required key fails
        let mut missing = recovery_base_point();
        missing.insert("replay_ms", Value::Null);
        let mut bad = minimal_report(RECOVERY, missing);
        bad.insert("arm", "recovery/v1");
        let err = validate(&bad).unwrap_err();
        assert!(err.to_string().contains("replay_ms"), "{err}");
        // counts that don't conserve fail: a replayed request can't
        // appear from (or vanish into) nowhere
        let mut skewed = recovery_base_point();
        skewed.insert("recovered", 47u64);
        let mut bad = minimal_report(RECOVERY, skewed);
        bad.insert("arm", "recovery/v1");
        let err = validate(&bad).unwrap_err();
        assert!(err.to_string().contains("conserve"), "{err}");
    }

    #[test]
    fn recovery_point_kills_and_recovers() {
        // One small real cell: 12 scripted requests, 4 resolved before
        // the halt, the other 8 replayed from the journal and driven to
        // completion on the fresh node.
        let p = recovery_point(12, 4, FsyncPolicy::Never).unwrap();
        let mut r = minimal_report(RECOVERY, p);
        r.insert("arm", "recovery/v1");
        validate(&r).unwrap();
        let p = &r.get("points").as_arr().unwrap()[0];
        assert_eq!(p.get("completed_before_crash").as_u64(), Some(4));
        assert_eq!(p.get("inflight_at_crash").as_u64(), Some(8));
        assert_eq!(p.get("recovered").as_u64(), Some(8));
        assert_eq!(p.get("recovered_completed").as_u64(), Some(8));
        assert_eq!(p.get("lost").as_u64(), Some(0));
        assert_eq!(p.get("corrupt").as_u64(), Some(0));
    }

    /// A well-formed routing point: a jit arm that dispatched across all
    /// three variants under the 0.9 floor.
    fn routing_base_point() -> Value {
        let mut p = json!({
            "workflow": "router", "system": "NALAR", "route": "jit",
            "rps_wall": 60.0, "offered": 60, "completed": 55, "shed": 2,
            "expired_in_queue": 3, "goodput_rps": 55.0,
            "quality_floor": 0.9, "quality_mean": 0.93
        });
        p.insert("variants", json!({"fast": 5, "base": 40, "large": 10}));
        p.insert("latency", lat());
        p
    }

    #[test]
    fn validate_accepts_routing_points() {
        // the report must carry the `routing/v1` arm tag
        let untagged = minimal_report(ROUTING, routing_base_point());
        let err = validate(&untagged).unwrap_err();
        assert!(err.to_string().contains("routing/v1"), "{err}");
        let mut r = minimal_report(ROUTING, routing_base_point());
        r.insert("arm", "routing/v1");
        validate(&r).unwrap();
        // an empty per-variant map fails: a routed arm must dispatch
        let mut empty = routing_base_point();
        empty.insert("variants", json!({}));
        let mut bad = minimal_report(ROUTING, empty);
        bad.insert("arm", "routing/v1");
        let err = validate(&bad).unwrap_err();
        assert!(err.to_string().contains("variants"), "{err}");
        // the quality accounting columns are required and numeric
        let mut missing = routing_base_point();
        missing.insert("quality_mean", Value::Null);
        let mut bad = minimal_report(ROUTING, missing);
        bad.insert("arm", "routing/v1");
        let err = validate(&bad).unwrap_err();
        assert!(err.to_string().contains("quality_mean"), "{err}");
    }

    #[test]
    fn quality_mean_weighs_dispatches() {
        let vs = routing_variants();
        let counts = json!({"fast": 1, "base": 0, "large": 1});
        let q = quality_mean(&vs, &counts);
        assert!((q - (0.82 + 0.99) / 2.0).abs() < 1e-9, "{q}");
        assert_eq!(quality_mean(&vs, &json!({})), 0.0, "no dispatches: 0");
    }

    #[test]
    fn routing_point_routes_and_counts_dispatches() {
        // One real low-rate jit cell through the loadgen point runner:
        // the injected variant table must reach the engine and every
        // dispatch must land in the per-variant split.
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![20.0],
            session_pool: 8,
            timeout_paper_s: 30.0,
            time_scale: Some(0.005),
            policies: Some(vec!["load_balance".into(), "jit_route".into()]),
            variants: Some(routing_variants()),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let p = run_point(&opts, 20.0, SystemUnderTest::Nalar, None, Some("jit")).unwrap();
        assert_eq!(p.get("route").as_str(), Some("jit"));
        assert!(p.get("completed").as_u64().unwrap() > 0, "uncontended point must complete");
        let vm = p.get("variants").as_obj().expect("per-variant map");
        let mut total = 0u64;
        for (_, n) in vm {
            total += n.as_u64().unwrap_or(0);
        }
        assert!(total > 0, "a jit arm must count its dispatches");
    }

    #[test]
    fn table4_quick_report_is_schema_valid() {
        let r = table4(true).unwrap();
        validate(&r).unwrap();
        assert_eq!(r.get("bench").as_str(), Some("table4"));
        assert!(r.get("points").as_arr().unwrap().len() >= 2);
    }

    #[test]
    fn fig10_quick_report_is_schema_valid() {
        let r = fig10(true).unwrap();
        validate(&r).unwrap();
        let pts = r.get("points").as_arr().unwrap().clone();
        assert!(pts.iter().all(|p| p.get("latency").get("p99").as_f64().is_some()));
    }

    #[test]
    fn write_and_check_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nalar-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut p = json!({"futures": 1024, "agents": 4, "reps": 1, "speedup": 2.0});
        p.insert("one_level", lat());
        p.insert("latency", lat());
        let r = minimal_report("table4", p);
        write_report(&dir, "table4", &r).unwrap();
        check_files(&dir, &["table4"]).unwrap();
        assert!(check_files(&dir, &["fig9"]).is_err(), "missing file must fail");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
