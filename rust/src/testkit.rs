//! Deterministic-testing kit (proptest substitute + scheduler harness,
//! offline build).
//!
//! Three tools, all seeded and wall-clock-free:
//!
//! * **Property checks** ([`check`] / [`check_n`]): run a property against
//!   many generated cases from a deterministic seed; on failure report the
//!   seed + case index so the exact counterexample replays with
//!   `NALAR_PROP_SEED=<seed>`. A light "shrink" retries the failing
//!   generator with progressively smaller size hints.
//! * **Virtual clock** ([`Clock`] / [`VirtualClock`]): an injectable time
//!   source for the ingress scheduler. Deadline sweeps, slack ordering and
//!   expiry races become functions of `advance()` instead of `sleep()` —
//!   a 30-second deadline test runs in milliseconds and never flakes on a
//!   loaded runner.
//! * **Scripted engine** ([`ScriptedEngine`]): a driver factory whose
//!   "agent calls" are bare [`FutureCell`]s the *test* resolves. Combined
//!   with the virtual clock, scheduler tests control exactly when each
//!   request parks, wakes, expires or completes — the cancel-race matrix
//!   and the FIFO-vs-slack A/B trace are deterministic replays, not
//!   timing hopes.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::futures::{FutureCell, FutureMeta};
use crate::ids::{AgentType, Location};
use crate::json;
use crate::util::rng::Rng;
use crate::workflow::{Driver, Env, Step};

/// Number of cases per property (override with NALAR_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("NALAR_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

fn base_seed() -> u64 {
    std::env::var("NALAR_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA11CE)
}

/// Size hint passed to generators: grows with the case index so early
/// cases are small (cheap, debuggable) and later cases stress harder.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub usize);

/// Check `prop` on `cases` generated inputs. Panics with a replayable
/// message on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng, Size) -> T,
    prop: impl FnMut(&T) -> bool,
) {
    check_n(name, default_cases(), gen, prop)
}

pub fn check_n<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    gen: impl Fn(&mut Rng, Size) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let size = Size(1 + case * 64 / cases.max(1));
        let input = gen(&mut rng, size);
        if !prop(&input) {
            // shrink: retry smaller sizes with the same stream
            let mut smallest = format!("{input:?}");
            for s in (0..size.0).rev() {
                let mut r2 = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
                let candidate = gen(&mut r2, Size(s));
                if !prop(&candidate) {
                    smallest = format!("{candidate:?}");
                }
            }
            panic!(
                "property `{name}` failed at case {case} (NALAR_PROP_SEED={seed}).\n\
                 counterexample: {smallest}"
            );
        }
    }
}

// --------------------------------------------------------- virtual clock

// The injectable time source itself lives in `util::clock` (the
// scheduler is a production consumer; test scaffolding must not be a
// production dependency) — re-exported here because tests are where the
// manual clock is actually driven.
pub use crate::util::clock::{Clock, VirtualClock};

// -------------------------------------------------------- scripted engine

/// A latch a scripted driver can block its *first* poll on. Blocking a
/// poll is forbidden for real drivers, which is exactly why tests want it:
/// holding a scheduler worker hostage lets wakeups pile into the ready
/// queue, making pop-order assertions deterministic. Capped internally so
/// a test that forgets `open()` fails instead of hanging CI.
pub struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<Gate> {
        Arc::new(Gate { open: Mutex::new(false), cv: Condvar::new() })
    }

    pub fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }

    fn wait(&self) {
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut g = self.open.lock().unwrap();
        while !*g {
            let now = Instant::now();
            assert!(now < deadline, "testkit::Gate was never opened");
            let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
        }
    }
}

/// Scripted stand-in for the agent/engine stack: drivers built by
/// [`ScriptedEngine::driver`] issue `waits` sequential "calls", each a
/// bare [`FutureCell`] registered in the deployment's future table, and
/// suspend on them exactly like real workflow drivers suspend on agent
/// futures. Nothing computes the futures — the test resolves (or fails)
/// them, deciding when each request wakes. Created cells and the
/// completion order are recorded for assertions.
pub struct ScriptedEngine {
    state: Mutex<ScriptState>,
    cv: Condvar,
}

#[derive(Default)]
struct ScriptState {
    created: Vec<Arc<FutureCell>>,
    completed: Vec<String>,
    /// Routed variant each call was dispatched under (None = unrouted),
    /// in creation order — deterministic routing A/B tests pick each
    /// call's simulated service time from this.
    variants: Vec<Option<String>>,
}

impl ScriptedEngine {
    #[allow(clippy::new_ret_no_self)]
    pub fn new() -> Arc<ScriptedEngine> {
        Arc::new(ScriptedEngine { state: Mutex::new(ScriptState::default()), cv: Condvar::new() })
    }

    /// A driver that makes `waits` scripted calls then completes. `label`
    /// identifies it in [`Self::completions`].
    pub fn driver(self: &Arc<Self>, label: &str, waits: usize) -> Box<dyn Driver> {
        self.build(label, waits, None)
    }

    /// Like [`Self::driver`], but the first poll blocks until `gate`
    /// opens — see [`Gate`].
    pub fn gated_driver(
        self: &Arc<Self>,
        label: &str,
        waits: usize,
        gate: Arc<Gate>,
    ) -> Box<dyn Driver> {
        self.build(label, waits, Some(gate))
    }

    fn build(
        self: &Arc<Self>,
        label: &str,
        waits: usize,
        gate: Option<Arc<Gate>>,
    ) -> Box<dyn Driver> {
        Box::new(ScriptedDriver {
            engine: self.clone(),
            label: label.to_string(),
            remaining: waits,
            consumed: 0,
            current: None,
            gate,
        })
    }

    /// Block (wall clock, event-driven) until `n` scripted calls exist.
    /// Returns false on timeout.
    pub fn wait_created(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut s = self.state.lock().unwrap();
        while s.created.len() < n {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (s2, _) = self.cv.wait_timeout(s, deadline - now).unwrap();
            s = s2;
        }
        true
    }

    /// The `i`-th scripted call, in creation order.
    pub fn cell(&self, i: usize) -> Arc<FutureCell> {
        self.state.lock().unwrap().created[i].clone()
    }

    pub fn created_count(&self) -> usize {
        self.state.lock().unwrap().created.len()
    }

    /// Routed variant the `i`-th scripted call was dispatched under
    /// (`None` = unrouted / no decision stamped yet).
    pub fn variant_of(&self, i: usize) -> Option<String> {
        self.state.lock().unwrap().variants[i].clone()
    }

    /// Labels of finished drivers, in the order their final poll ran.
    pub fn completions(&self) -> Vec<String> {
        self.state.lock().unwrap().completed.clone()
    }

    fn issue(&self, env: &Env, depth: u32) -> Arc<FutureCell> {
        // Consume the request's routing hint exactly like the real agent
        // stub does: the per-variant dispatch counters must tick once per
        // scripted call too, or counters-sum-to-dispatches would not hold
        // on scripted traces.
        let variant = env
            .ctx
            .route
            .as_ref()
            .and_then(|h| h.consume())
            .map(|(name, _)| name.to_string());
        let id = env.ctx.ids.future();
        let meta = FutureMeta::new(
            id,
            env.ctx.session,
            env.ctx.request,
            AgentType::new("scripted"),
            "step",
            Location::Driver(env.ctx.request),
        );
        let cell = FutureCell::new(meta);
        env.ctx.table.insert(cell.clone());
        env.ctx.graph.on_create(id, env.ctx.request, &[], depth);
        let mut s = self.state.lock().unwrap();
        s.created.push(cell.clone());
        s.variants.push(variant);
        drop(s);
        self.cv.notify_all();
        cell
    }

    fn note_done(&self, label: &str) {
        self.state.lock().unwrap().completed.push(label.to_string());
    }
}

struct ScriptedDriver {
    engine: Arc<ScriptedEngine>,
    label: String,
    remaining: usize,
    consumed: u32,
    current: Option<Arc<FutureCell>>,
    gate: Option<Arc<Gate>>,
}

impl Driver for ScriptedDriver {
    fn poll(&mut self, env: &Env) -> Step {
        if let Some(g) = self.gate.take() {
            g.wait();
        }
        loop {
            if let Some(cell) = self.current.clone() {
                match cell.try_value() {
                    None => return Step::Pending { waiting_on: vec![cell.id] },
                    Some(Err(e)) => {
                        self.engine.note_done(&self.label);
                        return Step::Done(Err(e));
                    }
                    Some(Ok(_)) => {
                        self.current = None;
                        self.consumed += 1;
                    }
                }
            }
            if self.remaining == 0 {
                self.engine.note_done(&self.label);
                return Step::Done(Ok(json!({
                    "scripted": self.label.as_str(),
                    "steps": self.consumed as i64,
                })));
            }
            self.remaining -= 1;
            let cell = self.engine.issue(env, self.consumed + 1);
            self.current = Some(cell);
        }
    }

    /// Scripted stage = calls already consumed, so the `stage` scheduling
    /// policy sees scripted progress the same way it sees real drivers'.
    fn stage(&self) -> u32 {
        self.consumed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-roundtrip", |r, s| {
            (0..s.0 + 1).map(|_| r.next_u64()).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_reports() {
        check_n("always-false", 4, |r, _| r.next_u64(), |_| false);
    }

    #[test]
    fn clock_reexport_reaches_the_util_implementation() {
        // The real tests live in util::clock; this pins the re-export
        // (scheduler tests import Clock from testkit).
        let (clock, v) = Clock::manual();
        let t0 = clock.now();
        v.advance(Duration::from_secs(1));
        assert_eq!(clock.now() - t0, Duration::from_secs(1));
    }

    #[test]
    fn gate_releases_waiters_once_open() {
        let g = Gate::new();
        let g2 = g.clone();
        let j = std::thread::spawn(move || g2.wait());
        std::thread::sleep(Duration::from_millis(5));
        g.open();
        j.join().unwrap();
        g.wait(); // already open: returns immediately
    }

    #[test]
    fn scripted_driver_parks_on_test_resolved_cells() {
        use crate::server::Deployment;
        use crate::workflow::WorkflowKind;
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let eng = ScriptedEngine::new();
        let mut drv = eng.driver("r1", 2);
        // First poll issues call 0 and suspends on it.
        let Step::Pending { waiting_on } = drv.poll(&env) else { panic!("must suspend") };
        assert_eq!(waiting_on, vec![eng.cell(0).id]);
        assert_eq!(drv.stage(), 0);
        // Still pending until the *test* resolves the cell.
        assert!(matches!(drv.poll(&env), Step::Pending { .. }));
        eng.cell(0).resolve(json!(1), 0);
        let Step::Pending { waiting_on } = drv.poll(&env) else { panic!("second call pends") };
        assert_eq!(waiting_on, vec![eng.cell(1).id]);
        assert_eq!(drv.stage(), 1, "one scripted call consumed");
        eng.cell(1).resolve(json!(2), 0);
        let Step::Done(out) = drv.poll(&env) else { panic!("must finish") };
        assert_eq!(out.unwrap().get("steps").as_i64(), Some(2));
        assert_eq!(eng.completions(), vec!["r1".to_string()]);
        assert_eq!(eng.created_count(), 2);
        d.shutdown();
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check_n("capture", 3, |r, s| {
            let v = (r.next_u64(), s.0);
            v
        }, |v| {
            first.push(*v);
            true
        });
        let mut second = Vec::new();
        check_n("capture", 3, |r, s| (r.next_u64(), s.0), |v| {
            second.push(*v);
            true
        });
        assert_eq!(first, second);
    }
}
