//! Routing state shared by stubs and controllers — where late binding lands.
//!
//! Resolution order for a call to agent type `A` in session `S`:
//!
//! 1. **Sticky route**: if `S` has a pinned instance for `A` (stateful or
//!    managed-state agents, or a policy `route(session, ...)` command),
//!    use it. Migration rewrites this pin (Fig. 8 step 4's "executor
//!    changed" notification).
//! 2. **Installed weights**: if the global controller installed
//!    `route(agent, instances, weights)`, sample accordingly.
//! 3. **Least-loaded fallback**: pick the instance with the smallest
//!    (queued + active) from the live load map.
//!
//! The load map holds per-instance atomic counters updated by component
//! controllers on every enqueue/start/finish — the "queue length" signal
//! the paper's local schedulers expose, without telemetry staleness.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::error::{Error, Result};
use crate::ids::{InstanceId, SessionId};
use crate::transport::Bus;
use crate::util::rng::Rng;

/// Live per-instance load counters.
#[derive(Default, Debug)]
pub struct InstanceLoad {
    pub queued: AtomicUsize,
    pub active: AtomicUsize,
}

impl InstanceLoad {
    pub fn total(&self) -> usize {
        self.queued.load(Ordering::Relaxed) + self.active.load(Ordering::Relaxed)
    }
}

/// Registry of live load counters (instances register at launch).
#[derive(Default, Clone)]
pub struct LoadMap {
    inner: Arc<RwLock<HashMap<InstanceId, Arc<InstanceLoad>>>>,
}

impl LoadMap {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&self, id: InstanceId) -> Arc<InstanceLoad> {
        let load = Arc::new(InstanceLoad::default());
        self.inner.write().unwrap().insert(id, load.clone());
        load
    }

    pub fn deregister(&self, id: &InstanceId) {
        self.inner.write().unwrap().remove(id);
    }

    pub fn get(&self, id: &InstanceId) -> Option<Arc<InstanceLoad>> {
        self.inner.read().unwrap().get(id).cloned()
    }

    pub fn total_of(&self, id: &InstanceId) -> usize {
        self.get(id).map(|l| l.total()).unwrap_or(usize::MAX)
    }
}

/// Fallback choice when neither sticky pin nor weights apply. The
/// non-default modes emulate baseline systems (paper §2.3 / §6):
/// hash-of-session models whole-workflow replication (CrewAI-like),
/// round-robin models uncoordinated event-driven dispatch (AutoGen-like).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FallbackMode {
    #[default]
    LeastLoaded,
    HashSession,
    RoundRobin,
}

/// See module docs.
pub struct Router {
    bus: Bus,
    loads: LoadMap,
    sticky: RwLock<HashMap<(SessionId, String), InstanceId>>,
    weights: RwLock<HashMap<String, Vec<(InstanceId, f64)>>>,
    rng: Mutex<Rng>,
    /// Baselines: sessions always pin to the first-chosen instance (their
    /// KV caches bind them to "the GPU originally assigned", §6.1).
    pub force_sticky: std::sync::atomic::AtomicBool,
    fallback: Mutex<FallbackMode>,
    rr_counter: std::sync::atomic::AtomicUsize,
}

impl Router {
    pub fn new(bus: Bus, loads: LoadMap, seed: u64) -> Self {
        Router {
            bus,
            loads,
            sticky: RwLock::new(HashMap::new()),
            weights: RwLock::new(HashMap::new()),
            rng: Mutex::new(Rng::new(seed)),
            force_sticky: std::sync::atomic::AtomicBool::new(false),
            fallback: Mutex::new(FallbackMode::LeastLoaded),
            rr_counter: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    pub fn set_fallback(&self, mode: FallbackMode) {
        *self.fallback.lock().unwrap() = mode;
    }

    /// Route one call. `pin_session` pins the chosen instance for future
    /// calls of this session (stateful / managed-state agents).
    pub fn route(&self, session: SessionId, agent: &str, pin_session: bool) -> Result<InstanceId> {
        let pin_session =
            pin_session || self.force_sticky.load(std::sync::atomic::Ordering::Relaxed);
        // 1. sticky
        if let Some(pin) = self
            .sticky
            .read()
            .unwrap()
            .get(&(session, agent.to_string()))
            .cloned()
        {
            if self.bus.is_registered(&pin) {
                return Ok(pin);
            }
            // pinned instance died: fall through and re-pin
        }
        let chosen = self.choose(agent, session)?;
        if pin_session {
            self.sticky
                .write()
                .unwrap()
                .insert((session, agent.to_string()), chosen.clone());
        }
        Ok(chosen)
    }

    fn choose(&self, agent: &str, session: SessionId) -> Result<InstanceId> {
        // 2. installed weights
        if let Some(w) = self.weights.read().unwrap().get(agent) {
            let live: Vec<&(InstanceId, f64)> = w
                .iter()
                .filter(|(i, wt)| *wt > 0.0 && self.bus.is_registered(i))
                .collect();
            if !live.is_empty() {
                let total: f64 = live.iter().map(|(_, wt)| wt).sum();
                let mut x = self.rng.lock().unwrap().f64() * total;
                for (i, wt) in &live {
                    x -= wt;
                    if x <= 0.0 {
                        return Ok(i.clone());
                    }
                }
                return Ok(live[live.len() - 1].0.clone());
            }
        }
        // 3. fallback — allocation-free over the bus's agent index (§Perf)
        let mode = *self.fallback.lock().unwrap();
        let chosen = self.bus.with_instances_of(agent, |instances| {
            if instances.is_empty() {
                return None;
            }
            Some(match mode {
                FallbackMode::LeastLoaded => instances
                    .iter()
                    .min_by_key(|i| self.loads.total_of(i))
                    .unwrap()
                    .clone(),
                FallbackMode::HashSession => {
                    instances[(session.0 as usize) % instances.len()].clone()
                }
                FallbackMode::RoundRobin => {
                    let idx = self
                        .rr_counter
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                        % instances.len();
                    instances[idx].clone()
                }
            })
        });
        chosen.ok_or_else(|| Error::NoInstance(agent.to_string()))
    }

    // --------------------------------------------- policy-facing mutators
    /// Table 2 `route(session-id, agent-type, agent-instance)`.
    pub fn pin(&self, session: SessionId, agent: &str, instance: InstanceId) {
        self.sticky
            .write()
            .unwrap()
            .insert((session, agent.to_string()), instance);
    }

    /// Table 2 `route(agent-type, instances, weights)`.
    pub fn set_weights(&self, agent: &str, weights: Vec<(InstanceId, f64)>) {
        self.weights
            .write()
            .unwrap()
            .insert(agent.to_string(), weights);
    }

    /// Repoint every sticky route of `session` at `agent` (migration
    /// completion, Fig. 8 step 4).
    pub fn repin_session(&self, session: SessionId, agent: &str, to: InstanceId) {
        self.pin(session, agent, to);
    }

    pub fn sticky_of(&self, session: SessionId, agent: &str) -> Option<InstanceId> {
        self.sticky
            .read()
            .unwrap()
            .get(&(session, agent.to_string()))
            .cloned()
    }

    pub fn clear_session(&self, session: SessionId) {
        self.sticky.write().unwrap().retain(|(s, _), _| *s != session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::NodeId;
    use std::time::Duration;

    type Inbox = std::sync::mpsc::Receiver<crate::transport::Message>;
    type Setup = (Bus, LoadMap, Router, Vec<Inbox>);

    fn setup(n: u32) -> Setup {
        let bus = Bus::new(Duration::ZERO);
        let loads = LoadMap::new();
        let mut rxs = Vec::new();
        for i in 0..n {
            let id = InstanceId::new("dev", i);
            rxs.push(bus.register(id.clone(), NodeId(i % 2)));
            loads.register(id);
        }
        let router = Router::new(bus.clone(), loads.clone(), 42);
        (bus, loads, router, rxs)
    }

    #[test]
    fn least_loaded_fallback() {
        let (_bus, loads, router, _rxs) = setup(3);
        loads
            .get(&InstanceId::new("dev", 0))
            .unwrap()
            .queued
            .store(5, Ordering::Relaxed);
        loads
            .get(&InstanceId::new("dev", 2))
            .unwrap()
            .queued
            .store(1, Ordering::Relaxed);
        let got = router.route(SessionId(1), "dev", false).unwrap();
        assert_eq!(got.index, 1, "dev:1 has zero load");
    }

    #[test]
    fn sticky_pins_and_survives_load_changes() {
        let (_bus, loads, router, _rxs) = setup(2);
        let first = router.route(SessionId(7), "dev", true).unwrap();
        // make the pinned instance look busy — sticky must still win
        loads.get(&first).unwrap().queued.store(100, Ordering::Relaxed);
        let second = router.route(SessionId(7), "dev", true).unwrap();
        assert_eq!(first, second);
        // other sessions avoid the busy one
        let other = router.route(SessionId(8), "dev", false).unwrap();
        assert_ne!(other, first);
    }

    #[test]
    fn dead_pin_reroutes() {
        let (bus, _loads, router, _rxs) = setup(2);
        router.pin(SessionId(1), "dev", InstanceId::new("dev", 0));
        bus.deregister(&InstanceId::new("dev", 0));
        let got = router.route(SessionId(1), "dev", true).unwrap();
        assert_eq!(got.index, 1);
    }

    #[test]
    fn weights_respected() {
        let (_bus, _loads, router, _rxs) = setup(2);
        router.set_weights(
            "dev",
            vec![
                (InstanceId::new("dev", 0), 0.0),
                (InstanceId::new("dev", 1), 1.0),
            ],
        );
        for s in 0..20 {
            let got = router.route(SessionId(s), "dev", false).unwrap();
            assert_eq!(got.index, 1, "zero-weight instance must never be chosen");
        }
    }

    #[test]
    fn unknown_agent_errors() {
        let (_bus, _loads, router, _rxs) = setup(1);
        assert!(matches!(
            router.route(SessionId(0), "nope", false),
            Err(Error::NoInstance(_))
        ));
    }

    #[test]
    fn repin_moves_session() {
        let (_bus, _loads, router, _rxs) = setup(2);
        router.pin(SessionId(3), "dev", InstanceId::new("dev", 0));
        router.repin_session(SessionId(3), "dev", InstanceId::new("dev", 1));
        assert_eq!(router.sticky_of(SessionId(3), "dev").unwrap().index, 1);
        router.clear_session(SessionId(3));
        assert!(router.sticky_of(SessionId(3), "dev").is_none());
    }
}
