//! Sharded registry of live futures (per node).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use std::sync::{Mutex, RwLock};

use crate::futures::{FutureCell, FutureState};
use crate::ids::{FutureId, RequestId};

const SHARDS: usize = 32;

/// Sharded `FutureId -> Arc<FutureCell>` map. The global controller scans
/// it (via telemetry snapshots, not directly) while component controllers
/// insert/resolve at event rate — sharding keeps those paths from
/// contending (§Perf: the Fig-10 loop reads while 128 agents write).
pub struct FutureTable {
    shards: Vec<RwLock<HashMap<FutureId, Arc<FutureCell>>>>,
    /// `RequestId -> FutureId`s created for it, maintained at
    /// [`FutureTable::insert`] so [`FutureTable::fail_request`] is
    /// O(futures-of-request) instead of a full-table scan — at the
    /// paper's 131K-live-futures scale a cancel must not walk every
    /// shard. Sharded by request id with the same fan-out as the cell
    /// map: the index rides the insert hot path, and a single mutex
    /// there would re-serialize exactly the concurrent writers the
    /// 32-way sharding exists for. Entries are evicted by the
    /// request-completion hook ([`FutureTable::on_request_complete`],
    /// called by the ingress scheduler and the blocking driver shim at
    /// every terminal outcome) or by `fail_request` itself, so the index
    /// cannot grow unboundedly. Ids may go stale between a future's GC
    /// and the request's end — lookups just miss; only the eviction hook
    /// removes the entry.
    by_request: Vec<Mutex<HashMap<RequestId, Vec<FutureId>>>>,
    /// Live cell count across all shards, maintained at insert/remove/GC
    /// (each update happens while the touched shard's write lock is
    /// held, so the counter agrees with the maps at every quiescent
    /// point). [`FutureTable::len`] reads this — snapshot and leak-gate
    /// paths must not lock all 32 shards just to sum sizes; the summed
    /// walk survives only inside [`FutureTable::debug_assert_len`].
    live: AtomicUsize,
}

impl Default for FutureTable {
    fn default() -> Self {
        Self::new()
    }
}

impl FutureTable {
    pub fn new() -> Self {
        FutureTable {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            by_request: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            live: AtomicUsize::new(0),
        }
    }

    fn shard(&self, id: FutureId) -> &RwLock<HashMap<FutureId, Arc<FutureCell>>> {
        &self.shards[(id.0 as usize) % SHARDS]
    }

    fn request_shard(&self, request: RequestId) -> &Mutex<HashMap<RequestId, Vec<FutureId>>> {
        &self.by_request[(request.0 as usize) % SHARDS]
    }

    pub fn insert(&self, cell: Arc<FutureCell>) {
        let (id, request) = (cell.id, cell.with_meta(|m| m.request));
        {
            let mut m = self.shard(id).write().unwrap();
            if m.insert(id, cell).is_none() {
                self.live.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.request_shard(request).lock().unwrap().entry(request).or_default().push(id);
    }

    pub fn get(&self, id: FutureId) -> Option<Arc<FutureCell>> {
        self.shard(id).read().unwrap().get(&id).cloned()
    }

    pub fn remove(&self, id: FutureId) -> Option<Arc<FutureCell>> {
        let mut m = self.shard(id).write().unwrap();
        let cell = m.remove(&id);
        if cell.is_some() {
            self.live.fetch_sub(1, Ordering::Relaxed);
        }
        cell
    }

    /// Live cell count — one atomic load (this rides the telemetry
    /// snapshot and leak-gate paths; see the `live` field).
    pub fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cross-check the O(1) counter against the authoritative summed
    /// walk over all shards (debug builds only — the walk takes every
    /// shard lock, which is the cost the counter exists to avoid). Only
    /// meaningful at quiescent points: the two reads are not atomic
    /// together under concurrent mutation.
    pub fn debug_assert_len(&self) {
        if cfg!(debug_assertions) {
            let walked: usize = self.shards.iter().map(|s| s.read().unwrap().len()).sum();
            assert_eq!(
                self.len(),
                walked,
                "FutureTable live counter diverged from the shard walk"
            );
        }
    }

    /// Count by state (telemetry snapshot for the global controller).
    pub fn state_counts(&self) -> HashMap<FutureState, usize> {
        let mut out = HashMap::new();
        for shard in &self.shards {
            for cell in shard.read().unwrap().values() {
                *out.entry(cell.state()).or_insert(0) += 1;
            }
        }
        out
    }

    /// Visit all live futures (used by policy loops and GC).
    pub fn for_each(&self, mut f: impl FnMut(&Arc<FutureCell>)) {
        for shard in &self.shards {
            for cell in shard.read().unwrap().values() {
                f(cell);
            }
        }
    }

    /// Fail every non-terminal future belonging to `request` (request
    /// cancellation via `Ticket::cancel`, deadline expiry of a started
    /// request, or ingress shutdown): consumers observe the failure
    /// immediately instead of waiting out an answer nobody wants.
    /// Returns how many futures were failed. O(futures-of-request) via
    /// the `by_request` index (this also consumes the request's index
    /// entry — abandonment is terminal, so a second call finds nothing).
    /// The cells are resolved outside both the index lock and the shard
    /// locks — `fail` fires wakers, and a waker is free to take
    /// unrelated locks (the ingress scheduler's, for one).
    pub fn fail_request(&self, request: RequestId, reason: &str) -> usize {
        let ids =
            self.request_shard(request).lock().unwrap().remove(&request).unwrap_or_default();
        let doomed: Vec<Arc<FutureCell>> = ids
            .into_iter()
            .filter_map(|id| self.get(id))
            .filter(|cell| !matches!(cell.state(), FutureState::Ready | FutureState::Failed))
            .collect();
        for cell in &doomed {
            cell.fail(reason);
        }
        doomed.len()
    }

    /// Request-completion hook: drop `request`'s entry from the
    /// per-request index. Called on every *terminal* outcome that does
    /// not go through [`Self::fail_request`] — ingress completion, and
    /// the blocking driver shim's exit — so the index stays bounded by
    /// the live request set. Idempotent; the futures themselves are
    /// untouched (`gc_terminal` reaps them on its own schedule).
    pub fn on_request_complete(&self, request: RequestId) {
        self.request_shard(request).lock().unwrap().remove(&request);
    }

    /// Live entries in the per-request index (telemetry / leak gates: a
    /// non-zero value after every request reached a terminal outcome is a
    /// lifecycle bug).
    pub fn request_index_len(&self) -> usize {
        self.by_request.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Total engine service time (µs) across `request`'s futures — the
    /// `engine_service` component of the per-stage latency breakdown
    /// (DESIGN.md §10). Reads the `by_request` index without consuming
    /// it, so the ingress scheduler must call this *before*
    /// [`Self::on_request_complete`]. Futures already GC'd are misses and
    /// contribute nothing (their service time was stamped at resolution,
    /// so a sufficiently aggressive GC undercounts — acceptable for a
    /// latency decomposition, never wrong for accounting that ran).
    pub fn request_service_us(&self, request: RequestId) -> u64 {
        let ids: Vec<FutureId> = self
            .request_shard(request)
            .lock()
            .unwrap()
            .get(&request)
            .cloned()
            .unwrap_or_default();
        ids.into_iter().filter_map(|id| self.get(id)).map(|cell| cell.service_us()).sum()
    }

    /// Drop terminal futures older than keeping is useful; returns count
    /// removed. (The paper scales to 131K live futures; GC keeps bench
    /// memory bounded.)
    pub fn gc_terminal(&self) -> usize {
        let mut removed = 0;
        for shard in &self.shards {
            let mut m = shard.write().unwrap();
            let before = m.len();
            m.retain(|_, c| !matches!(c.state(), FutureState::Ready | FutureState::Failed));
            let reaped = before - m.len();
            if reaped > 0 {
                self.live.fetch_sub(reaped, Ordering::Relaxed);
            }
            removed += reaped;
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::futures::FutureMeta;
    use crate::ids::*;

    fn cell(id: u64) -> Arc<FutureCell> {
        cell_for(id, 0)
    }

    fn cell_for(id: u64, request: u64) -> Arc<FutureCell> {
        FutureCell::new(FutureMeta::new(
            FutureId(id),
            SessionId(0),
            RequestId(request),
            AgentType::new("a"),
            "m",
            Location::Global,
        ))
    }

    #[test]
    fn insert_get_remove() {
        let t = FutureTable::new();
        t.insert(cell(1));
        t.insert(cell(2));
        assert_eq!(t.len(), 2);
        assert!(t.get(FutureId(1)).is_some());
        assert!(t.remove(FutureId(1)).is_some());
        assert!(t.get(FutureId(1)).is_none());
        assert_eq!(t.len(), 1);
        assert!(t.remove(FutureId(1)).is_none(), "double remove is a miss");
        assert_eq!(t.len(), 1, "a miss must not decrement the live counter");
        t.debug_assert_len();
    }

    #[test]
    fn state_counts_and_gc() {
        let t = FutureTable::new();
        for i in 0..10 {
            let c = cell(i);
            if i < 4 {
                c.resolve(crate::json!(i), 0);
            }
            t.insert(c);
        }
        let counts = t.state_counts();
        assert_eq!(counts[&FutureState::Ready], 4);
        assert_eq!(counts[&FutureState::Created], 6);
        assert_eq!(t.gc_terminal(), 4);
        assert_eq!(t.len(), 6);
        t.debug_assert_len();
    }

    #[test]
    fn fail_request_only_touches_the_request_and_spares_terminals() {
        let t = FutureTable::new();
        t.insert(cell_for(1, 7)); // doomed
        t.insert(cell_for(2, 7)); // doomed
        let done = cell_for(3, 7); // already terminal: untouched
        done.resolve(crate::json!("ok"), 0);
        t.insert(done.clone());
        t.insert(cell_for(4, 8)); // other request: untouched
        assert_eq!(t.fail_request(RequestId(7), "request cancelled"), 2);
        assert!(t.get(FutureId(1)).unwrap().try_value().unwrap().is_err());
        assert!(t.get(FutureId(2)).unwrap().try_value().unwrap().is_err());
        assert!(done.try_value().unwrap().is_ok(), "resolved value is immutable");
        assert_eq!(t.get(FutureId(4)).unwrap().state(), FutureState::Created);
        assert_eq!(t.fail_request(RequestId(7), "again"), 0, "idempotent");
    }

    #[test]
    fn request_index_is_maintained_and_evicted() {
        let t = FutureTable::new();
        // completion path: the hook alone evicts
        t.insert(cell_for(1, 7));
        t.insert(cell_for(2, 7));
        t.insert(cell_for(3, 8));
        assert_eq!(t.request_index_len(), 2, "one entry per live request");
        t.on_request_complete(RequestId(7));
        assert_eq!(t.request_index_len(), 1, "completion hook evicts");
        t.on_request_complete(RequestId(7)); // idempotent
        assert_eq!(t.request_index_len(), 1);
        // cancel/expiry path: fail_request consumes the entry itself
        assert_eq!(t.fail_request(RequestId(8), "request cancelled"), 1);
        assert_eq!(t.request_index_len(), 0, "abandonment evicts");
        // after eviction a fail_request finds no index entry and fails
        // nothing — eviction is only correct on *terminal* requests,
        // which is why the hook sits on the scheduler's terminal paths
        assert_eq!(t.fail_request(RequestId(7), "request deadline expired"), 0);
        assert_eq!(t.request_index_len(), 0);
        // GC'd futures leave stale ids behind; failing that request later
        // just misses them instead of erroring
        t.insert(cell_for(10, 9));
        t.get(FutureId(10)).unwrap().resolve(crate::json!(1), 0);
        assert_eq!(t.gc_terminal(), 1);
        assert_eq!(t.request_index_len(), 1, "index waits for the request hook");
        assert_eq!(t.fail_request(RequestId(9), "late cancel"), 0, "stale id is a miss");
        assert_eq!(t.request_index_len(), 0);
    }

    #[test]
    fn request_service_us_sums_without_consuming_the_index() {
        let t = FutureTable::new();
        t.insert(cell_for(1, 7));
        t.insert(cell_for(2, 7));
        t.insert(cell_for(3, 8)); // other request: not counted
        t.get(FutureId(1)).unwrap().resolve(crate::json!(1), 1_500);
        t.get(FutureId(2)).unwrap().resolve(crate::json!(2), 500);
        t.get(FutureId(3)).unwrap().resolve(crate::json!(3), 9_999);
        assert_eq!(t.request_service_us(RequestId(7)), 2_000);
        assert_eq!(t.request_service_us(RequestId(7)), 2_000, "read-only: repeatable");
        assert_eq!(t.request_index_len(), 2, "index intact");
        t.on_request_complete(RequestId(7));
        assert_eq!(t.request_service_us(RequestId(7)), 0, "evicted request sums to zero");
    }

    #[test]
    fn for_each_visits_all() {
        let t = FutureTable::new();
        for i in 0..100 {
            t.insert(cell(i));
        }
        let mut n = 0;
        t.for_each(|_| n += 1);
        assert_eq!(n, 100);
    }
}
