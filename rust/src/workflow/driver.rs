//! Resumable workflow drivers: the request lifecycle as a stored
//! continuation instead of a thread's stack.
//!
//! The paper's scale claims (130K live futures, 80 RPS where baselines
//! fail) rest on drivers that *suspend* on futures rather than parking OS
//! threads. [`Driver::poll`] is that suspension point: a driver advances
//! as far as the resolved futures allow and then returns
//! [`Step::Pending`] naming exactly the futures it is stuck on, so a
//! scheduler can shelve the continuation and re-run it when a
//! [`crate::futures::FutureCell`] waker fires — no thread is occupied
//! while the request waits on agent work.
//!
//! Two executors drive the same state machines:
//!
//! * the event-driven ingress scheduler ([`crate::ingress`]) multiplexes
//!   thousands of in-flight drivers over a small fixed thread pool;
//! * [`drive_blocking`] is the compat shim — poll in a loop, park the
//!   calling thread on a [`WakeSignal`] between polls — that keeps the
//!   blocking API (`workflow::run_request`, the closed-loop harness, the
//!   examples) byte-compatible.

use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::futures::{Value, WakeSignal};
use crate::ids::FutureId;
use crate::workflow::{financial, router, swe, Env, WorkflowKind};

/// What one `poll` produced.
pub enum Step {
    /// The driver cannot advance until at least one of these futures
    /// reaches a terminal state. The caller must subscribe for readiness
    /// (or re-poll) — the driver itself holds no thread while pending.
    Pending { waiting_on: Vec<FutureId> },
    /// The request finished (the driver must not be polled again).
    Done(Result<Value>),
}

/// A resumable workflow driver. `poll` must never block: it consumes
/// whatever futures are ready (`try_value`), issues any newly unblocked
/// agent calls, and reports `Pending`/`Done`. All request state lives in
/// the implementor — dropping it abandons the request.
pub trait Driver: Send {
    fn poll(&mut self, env: &Env) -> Step;

    /// Coarse progress index of the request's current suspension point
    /// (0 = nothing consumed yet), monotone over the driver's life. The
    /// front-door scheduler reads it for SRTF-style ordering: a
    /// later-stage request has the least remaining work (`stage` policy
    /// drains it first; `deadline_slack` keys its remaining-time estimate
    /// on it). The default suits drivers with no meaningful notion of
    /// progress — they sort as "not started".
    fn stage(&self) -> u32 {
        0
    }

    /// Serialize the current suspension point as plain JSON for the
    /// durable request journal. The snapshot records the *resume point* —
    /// which stage to re-enter and the data needed to re-issue that
    /// stage's agent calls — never in-flight future handles, because
    /// futures do not survive a crash; replay re-issues them afresh
    /// ([`restore_driver`]). `Null` (the default) means "no resumable
    /// snapshot": replay falls back to restarting the request from its
    /// first stage, which is always correct (stages are agent calls the
    /// driver could also have retried), just slower.
    fn serialize_state(&self) -> Value {
        Value::Null
    }
}

/// Instantiate the resumable driver for one admitted request.
pub fn driver_for(kind: WorkflowKind, input: &Value) -> Box<dyn Driver> {
    match kind {
        WorkflowKind::Financial => Box::new(financial::FinancialDriver::new(input)),
        WorkflowKind::Router => Box::new(router::RouterDriver::new(input)),
        WorkflowKind::Swe => Box::new(swe::SweDriver::new(input)),
    }
}

/// Re-instantiate a driver from a journaled suspension point
/// ([`Driver::serialize_state`]). A `Null` or unrecognized snapshot falls
/// back to [`driver_for`]'s fresh driver — the replayed request then
/// restarts from its first stage instead of resuming mid-flight.
pub fn restore_driver(kind: WorkflowKind, input: &Value, state: &Value) -> Box<dyn Driver> {
    match kind {
        WorkflowKind::Financial => Box::new(financial::FinancialDriver::restore(input, state)),
        WorkflowKind::Router => Box::new(router::RouterDriver::restore(input, state)),
        WorkflowKind::Swe => Box::new(swe::SweDriver::restore(input, state)),
    }
}

/// Compat shim: run a resumable driver to completion on the calling
/// thread. Between polls the thread parks on a [`WakeSignal`] subscribed
/// to every future the driver reported waiting on — push-based readiness,
/// not a poll interval — and the request's end-to-end `timeout` is
/// enforced here (the paper's "driver decides" retry semantics sit above
/// this, in the caller).
pub fn drive_blocking(driver: &mut dyn Driver, env: &Env, timeout: Duration) -> Result<Value> {
    let deadline = Instant::now() + timeout;
    let signal = WakeSignal::new();
    // Each future is subscribed at most once per request: a join pending
    // through many wake cycles must not pile duplicate wakers (and their
    // spurious wakeups) onto its slowest futures.
    let mut subscribed: std::collections::HashSet<FutureId> = std::collections::HashSet::new();
    loop {
        match driver.poll(env) {
            Step::Done(result) => {
                // Terminal: evict the request's entry from the table's
                // per-request future index (the shim is this request's
                // scheduler, so the completion hook is its job here).
                env.ctx.table.on_request_complete(env.ctx.request);
                return result;
            }
            Step::Pending { waiting_on } => {
                let now = Instant::now();
                if now >= deadline {
                    env.ctx.table.on_request_complete(env.ctx.request);
                    return Err(Error::Deadline(timeout));
                }
                let mut can_wake = false;
                for id in &waiting_on {
                    if subscribed.contains(id) {
                        can_wake = true;
                        continue;
                    }
                    if let Some(cell) = env.ctx.table.get(*id) {
                        subscribed.insert(*id);
                        let s = signal.clone();
                        cell.subscribe(Box::new(move || s.wake()));
                        can_wake = true;
                    }
                }
                // Subscribing to a future that resolved mid-poll fires the
                // waker inline, and a wake that raced ahead stays latched
                // in the signal until consumed — no lost wakeups. A future
                // missing from the table cannot push readiness (stubs
                // register every future, so this is a shouldn't-happen);
                // fall back to a short re-poll interval rather than
                // hanging until the deadline.
                let cap = if can_wake {
                    deadline - now
                } else {
                    Duration::from_millis(2).min(deadline - now)
                };
                signal.wait(cap);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::server::Deployment;

    /// A driver that issues one real agent call and suspends on it — the
    /// minimal poll/waker round trip through a live deployment.
    struct OneCall {
        call: Option<crate::futures::FutureHandle>,
        polls_while_pending: u32,
    }

    impl Driver for OneCall {
        fn poll(&mut self, env: &Env) -> Step {
            let call = self.call.get_or_insert_with(|| {
                env.ctx
                    .agent("router")
                    .call("classify", json!({"prompt": "hi", "max_new_tokens": 4}))
            });
            match call.try_value() {
                None => {
                    self.polls_while_pending += 1;
                    Step::Pending { waiting_on: vec![call.id()] }
                }
                Some(Ok(v)) => Step::Done(Ok(json!({
                    "tokens": v.get("generated_tokens").as_i64().unwrap_or(0)
                }))),
                Some(Err(e)) => Step::Done(Err(e)),
            }
        }
    }

    #[test]
    fn drive_blocking_completes_a_suspending_driver() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let mut drv = OneCall { call: None, polls_while_pending: 0 };
        let out = drive_blocking(&mut drv, &env, Duration::from_secs(20)).unwrap();
        assert!(out.get("tokens").as_i64().is_some());
        assert!(drv.polls_while_pending >= 1, "the driver must actually have suspended");
        d.shutdown();
    }

    /// A driver that never finishes: the shim must enforce the deadline.
    struct NeverDone;

    impl Driver for NeverDone {
        fn poll(&mut self, _env: &Env) -> Step {
            Step::Pending { waiting_on: vec![FutureId(u64::MAX)] }
        }
    }

    #[test]
    fn drive_blocking_enforces_the_deadline() {
        let mut cfg = WorkflowKind::Router.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let t0 = Instant::now();
        let err = drive_blocking(&mut NeverDone, &env, Duration::from_millis(40)).unwrap_err();
        assert!(matches!(err, Error::Deadline(..)), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(35));
        assert!(t0.elapsed() < Duration::from_secs(5), "must not hang");
        d.shutdown();
    }
}
