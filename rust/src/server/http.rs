//! The network serving plane: a dependency-free HTTP/1.1 front door over
//! the ingress scheduler (`nalar serve --listen <addr>`).
//!
//! Everything the wire layer does maps 1:1 onto machinery that already
//! exists in-process — this module adds sockets and parsing, not policy:
//!
//! * `POST /v1/workflows/{kind}/requests` builds a
//!   [`SubmitRequest`](crate::ingress::SubmitRequest) (tenant from
//!   `X-Nalar-Tenant`, deadline from `X-Nalar-Deadline-Ms`, payload from
//!   the body) and calls the one [`Ingress::submit`] entry point. By
//!   default it waits for the outcome (`200` + result); with
//!   `X-Nalar-Wait: 0` it parks the [`Ticket`] in a registry and answers
//!   `202` + request id immediately.
//! * `GET /v1/requests/{id}` polls a parked ticket ([`Ticket::try_take`]):
//!   `202` while live, the mapped terminal status once done.
//! * `DELETE /v1/requests/{id}` is [`Ticket::cancel`] — `200` when the
//!   cancel was delivered, `409` when the request already finished.
//! * `GET /metrics` hand-serializes the per-tenant
//!   [`IngressMetrics`](crate::coordinator::IngressMetrics) snapshots;
//!   `GET /metrics?format=prom` renders the same snapshots as
//!   Prometheus-style text exposition ([`prom_exposition`]) for scrapers.
//! * `GET /v1/requests/{id}/trace` returns the request's span timeline
//!   from the flight recorder ([`crate::trace`]) plus its per-stage
//!   decomposition — while the request runs, and after it finishes until
//!   the terminal result is consumed (the same consumption semantics the
//!   result registry has: polling the terminal result evicts the trace).
//!
//! Status codes and `Retry-After` come from the single wire-mapping
//! authority [`Error::http_status`] / [`Error::retry_after`] — the HTTP
//! layer never invents its own mapping (DESIGN.md §9).
//!
//! The connection machinery is a small fixed pool, sized by
//! [`HttpSettings`]: `acceptors` threads poll a non-blocking listener and
//! hand accepted sockets to `workers` connection workers over a channel.
//! Each worker owns one persistent connection at a time: it reads with a
//! short timeout (so the stop flag is honored promptly), feeds bytes to
//! the incremental [`parse_request`] parser (split-across-reads requests
//! just return [`Parsed::NeedMore`]), serves pipelined requests from the
//! leftover buffer, and keeps the connection open until the client closes
//! it, sends `Connection: close`, idles out, or breaks framing. An
//! `open_connections` gauge counts accepted-but-unfinished sockets;
//! [`HttpServer::stop`] reports it so callers (the serve-smoke CI gate)
//! can assert zero leaked connections at shutdown.

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::HttpSettings;
use crate::coordinator::IngressMetrics;
use crate::error::{Error, Result};
use crate::futures::Value;
use crate::ids::RequestId;
use crate::ingress::{Ingress, SubmitRequest, Ticket};
use crate::json;
use crate::server::Deployment;
use crate::trace::stage_durations;
use crate::workflow::WorkflowKind;

/// Deadline when the client sends no `X-Nalar-Deadline-Ms`. Matches
/// [`SubmitRequest::DEFAULT_DEADLINE`].
const DEFAULT_DEADLINE_MS: u64 = 30_000;
/// Slack past the request deadline a synchronous POST waits before giving
/// up on the scheduler: expiry is the scheduler's call (it fulfils the
/// ticket with `Error::Deadline` → `408`), the wire just needs a bound.
const WAIT_GRACE: Duration = Duration::from_secs(5);
/// Read timeout per attempt: the granularity at which a blocked
/// connection worker re-checks the stop flag.
const READ_TIMEOUT: Duration = Duration::from_millis(250);
/// Keep-alive connections idle longer than this are closed.
const IDLE_TIMEOUT: Duration = Duration::from_secs(30);
/// Parked tickets kept findable by `GET /v1/requests/{id}`. Above this,
/// inserting prunes tickets that already finished (a client that parks
/// work and never polls it forfeits the result, not server memory).
const REGISTRY_CAP: usize = 8192;

// --------------------------------------------------------------- parsing

/// One parsed request. Header names are lowercased at parse time; the
/// body is raw bytes (the JSON layer above decides what they mean).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Client asked for `Connection: close`.
    pub close: bool,
}

impl Request {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// What [`parse_request`] made of the buffer so far.
#[derive(Debug)]
pub enum Parsed {
    /// The buffer holds no complete request yet — read more bytes.
    NeedMore,
    /// One complete request, occupying the first `usize` bytes of the
    /// buffer (drain them; what follows is the next pipelined request).
    Request(Request, usize),
    /// Unrecoverable framing error: answer with this status + message and
    /// close the connection (byte sync with the client is lost).
    Error(u16, String),
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Incremental HTTP/1.1 request parser. Pure function of the buffer —
/// callers append reads and re-parse, so requests split across reads are
/// just a sequence of [`Parsed::NeedMore`]. Enforces `max_header` (→
/// `431`) and `max_body` (→ `413`) before buffering unbounded input.
pub fn parse_request(buf: &[u8], max_header: usize, max_body: usize) -> Parsed {
    let head_end = match find(buf, b"\r\n\r\n") {
        Some(i) => i,
        None => {
            if buf.len() > max_header {
                return Parsed::Error(431, format!("headers exceed {max_header} bytes"));
            }
            return Parsed::NeedMore;
        }
    };
    if head_end > max_header {
        return Parsed::Error(431, format!("headers exceed {max_header} bytes"));
    }
    let head = match std::str::from_utf8(&buf[..head_end]) {
        Ok(s) => s,
        Err(_) => return Parsed::Error(400, "request head is not UTF-8".into()),
    };
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None) => (m, p, v),
            _ => return Parsed::Error(400, format!("malformed request line `{request_line}`")),
        };
    if !version.starts_with("HTTP/1.") {
        return Parsed::Error(400, format!("unsupported protocol `{version}`"));
    }
    if !path.starts_with('/') {
        return Parsed::Error(400, format!("malformed request target `{path}`"));
    }
    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        match line.split_once(':') {
            Some((k, v)) => headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string())),
            None => return Parsed::Error(400, format!("malformed header line `{line}`")),
        }
    }
    let header = |name: &str| {
        headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    };
    if header("transfer-encoding").is_some() {
        return Parsed::Error(501, "transfer-encoding is not supported".into());
    }
    let body_len = match header("content-length") {
        None => 0,
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => return Parsed::Error(400, format!("invalid content-length `{v}`")),
        },
    };
    if body_len > max_body {
        return Parsed::Error(413, format!("body of {body_len} bytes exceeds {max_body}"));
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + body_len {
        return Parsed::NeedMore;
    }
    let close = header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false);
    let req = Request {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: buf[body_start..body_start + body_len].to_vec(),
        close,
    };
    Parsed::Request(req, body_start + body_len)
}

// -------------------------------------------------------------- response

/// One response on its way out. `close` forces `Connection: close` (set
/// on framing errors, where request byte sync is lost).
struct Response {
    status: u16,
    headers: Vec<(String, String)>,
    body: String,
    close: bool,
    /// `application/json` everywhere except the Prometheus exposition.
    content_type: &'static str,
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn json_response(status: u16, body: Value) -> Response {
    Response {
        status,
        headers: Vec::new(),
        body: body.to_string(),
        close: false,
        content_type: "application/json",
    }
}

fn text_response(status: u16, body: String) -> Response {
    Response {
        status,
        headers: Vec::new(),
        body,
        close: false,
        content_type: "text/plain; version=0.0.4",
    }
}

fn error_response(status: u16, msg: &str, close: bool) -> Response {
    let mut r = json_response(status, json!({"error": msg}));
    r.close = close;
    r
}

/// The wire mapping for a runtime error: status from
/// [`Error::http_status`], plus `Retry-After` on a shed so a backing-off
/// client knows when the token bucket refills one token.
fn error_to_response(e: &Error) -> Response {
    let status = e.http_status();
    let mut r = json_response(status, json!({"error": e.to_string(), "retryable": e.retryable()}));
    if status == 429 {
        let secs = e.retry_after().as_secs_f64().ceil().max(1.0) as u64;
        r.headers.push(("retry-after".into(), secs.to_string()));
    }
    r
}

fn write_response(stream: &mut TcpStream, r: &Response) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n",
        r.status,
        reason(r.status),
        r.content_type,
        r.body.len()
    );
    for (k, v) in &r.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    if r.close {
        head.push_str("connection: close\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(r.body.as_bytes())?;
    stream.flush()
}

// ---------------------------------------------------------------- server

struct State {
    d: Deployment,
    ingress: Arc<Ingress>,
    kinds: Vec<WorkflowKind>,
    opts: HttpSettings,
    stop: AtomicBool,
    /// Accepted-but-unfinished sockets; must read 0 after a clean stop.
    open: AtomicUsize,
    /// Parked tickets (`X-Nalar-Wait: 0` submits) by request id.
    registry: Mutex<HashMap<u64, Ticket>>,
}

/// A running HTTP front door. Stop it with [`HttpServer::stop`]; dropping
/// without stopping leaves threads serving until the process exits.
pub struct HttpServer {
    addr: SocketAddr,
    state: Arc<State>,
    joins: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, or port `0` for an ephemeral
    /// port — read the real one back from [`HttpServer::addr`]) and start
    /// the acceptor/worker pool. Pool sizing and parser caps come from
    /// the deployment's `ingress.http` settings.
    pub fn start(
        d: &Deployment,
        ingress: Arc<Ingress>,
        kinds: &[WorkflowKind],
        listen: &str,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| Error::Config(format!("cannot bind `{listen}`: {e}")))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let opts = d.cfg().ingress.http.clone();
        let state = Arc::new(State {
            d: d.clone(),
            ingress,
            kinds: kinds.to_vec(),
            opts,
            stop: AtomicBool::new(false),
            open: AtomicUsize::new(0),
            registry: Mutex::new(HashMap::new()),
        });
        let (tx, rx) = std::sync::mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let listener = Arc::new(listener);
        let mut joins = Vec::new();
        for w in 0..state.opts.workers {
            let state = state.clone();
            let rx = rx.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("nalar-http-conn-{w}"))
                    .spawn(move || conn_worker(&state, &rx))
                    .map_err(|e| Error::Msg(e.to_string()))?,
            );
        }
        for a in 0..state.opts.acceptors {
            let state = state.clone();
            let listener = listener.clone();
            let tx = tx.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("nalar-http-accept-{a}"))
                    .spawn(move || acceptor(&state, &listener, &tx))
                    .map_err(|e| Error::Msg(e.to_string()))?,
            );
        }
        drop(tx); // workers see Disconnected once every acceptor exits
        Ok(HttpServer { addr, state, joins })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accepted-but-unfinished connections right now.
    pub fn open_connections(&self) -> usize {
        self.state.open.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain the pool, join every thread. Returns the
    /// number of connections still open after the drain — 0 on a clean
    /// shutdown, and the serve-smoke CI gate fails on anything else.
    pub fn stop(mut self) -> usize {
        self.state.stop.store(true, Ordering::Relaxed);
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.state.open.load(Ordering::Relaxed)
    }
}

fn acceptor(state: &State, listener: &TcpListener, tx: &Sender<TcpStream>) {
    while !state.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                state.open.fetch_add(1, Ordering::Relaxed);
                if tx.send(stream).is_err() {
                    // worker pool gone: count the drop and bail
                    state.open.fetch_sub(1, Ordering::Relaxed);
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

fn conn_worker(state: &State, rx: &Arc<Mutex<Receiver<TcpStream>>>) {
    loop {
        let next = rx.lock().unwrap().recv_timeout(Duration::from_millis(50));
        match next {
            Ok(stream) => {
                if state.stop.load(Ordering::Relaxed) {
                    // accepted but never served: drop it, keep the gauge honest
                    state.open.fetch_sub(1, Ordering::Relaxed);
                    continue;
                }
                serve_conn(state, stream);
                state.open.fetch_sub(1, Ordering::Relaxed);
            }
            Err(RecvTimeoutError::Timeout) => {
                if state.stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// One persistent connection, served to completion: incremental reads
/// feed [`parse_request`]; pipelined requests drain from the leftover
/// buffer; framing errors answer and close. A client disconnect anywhere
/// — including mid-body — just ends the loop: nothing was submitted for
/// a half-received request, so no in-flight slot can leak.
fn serve_conn(state: &State, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut idle = Instant::now();
    loop {
        match parse_request(&buf, state.opts.max_header_bytes, state.opts.max_body_bytes) {
            Parsed::Error(status, msg) => {
                let _ = write_response(&mut stream, &error_response(status, &msg, true));
                return;
            }
            Parsed::Request(req, consumed) => {
                buf.drain(..consumed);
                let mut resp = route(state, &req);
                resp.close = resp.close || req.close;
                if write_response(&mut stream, &resp).is_err() || resp.close {
                    return;
                }
                idle = Instant::now();
            }
            Parsed::NeedMore => {
                if state.stop.load(Ordering::Relaxed) {
                    return;
                }
                match stream.read(&mut chunk) {
                    Ok(0) => return, // EOF: clean between requests, abrupt mid-request
                    Ok(n) => {
                        buf.extend_from_slice(&chunk[..n]);
                        idle = Instant::now();
                    }
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        if idle.elapsed() > IDLE_TIMEOUT {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            }
        }
    }
}

// ---------------------------------------------------------------- routes

fn route(state: &State, req: &Request) -> Response {
    // Split the query string off the route path (`/metrics?format=prom`
    // routes like `/metrics`); handlers that care parse `query`.
    let (path, query) = match req.path.split_once('?') {
        Some((p, q)) => (p, q),
        None => (req.path.as_str(), ""),
    };
    if path == "/metrics" {
        return match req.method.as_str() {
            "GET" if has_query(query, "format", "prom") => prom_response(state),
            "GET" => metrics_response(state),
            _ => error_response(405, "use GET", false),
        };
    }
    if path == "/healthz" {
        return json_response(200, json!({"ok": true}));
    }
    if let Some(kind) =
        path.strip_prefix("/v1/workflows/").and_then(|r| r.strip_suffix("/requests"))
    {
        return match req.method.as_str() {
            "POST" => post_workflow(state, kind, req),
            _ => error_response(405, "use POST", false),
        };
    }
    if let Some(id) = path.strip_prefix("/v1/requests/").and_then(|r| r.strip_suffix("/trace")) {
        return match req.method.as_str() {
            "GET" => trace_request(state, id),
            _ => error_response(405, "use GET", false),
        };
    }
    if let Some(id) = path.strip_prefix("/v1/requests/") {
        return match req.method.as_str() {
            "GET" => poll_request(state, id),
            "DELETE" => cancel_request(state, id),
            _ => error_response(405, "use GET or DELETE", false),
        };
    }
    error_response(404, &format!("no route for `{path}`"), false)
}

/// `key=value` membership in an `&`-separated query string.
fn has_query(query: &str, key: &str, value: &str) -> bool {
    query.split('&').any(|kv| kv.split_once('=') == Some((key, value)))
}

fn post_workflow(state: &State, kind: &str, req: &Request) -> Response {
    let kind = match WorkflowKind::parse(kind) {
        Some(k) => k,
        None => return error_response(404, &format!("unknown workflow `{kind}`"), false),
    };
    let input = if req.body.is_empty() {
        Value::Null
    } else {
        let text = match std::str::from_utf8(&req.body) {
            Ok(t) => t,
            Err(_) => return error_response(400, "body must be UTF-8 JSON", false),
        };
        match crate::util::json::parse(text) {
            Ok(v) => v,
            Err(e) => return error_response(400, &format!("body: {e}"), false),
        }
    };
    let deadline_ms = match req.header("x-nalar-deadline-ms") {
        None => DEFAULT_DEADLINE_MS,
        Some(v) => match v.parse::<u64>() {
            Ok(ms) if ms > 0 => ms,
            _ => {
                return error_response(
                    400,
                    "X-Nalar-Deadline-Ms must be a positive integer",
                    false,
                )
            }
        },
    };
    let timeout = Duration::from_millis(deadline_ms);
    // Wire submits keep their timeline past the terminal exit: the
    // registry owns the trace lifetime here (`/trace` answers until the
    // result is consumed), so opt out of the in-proc terminal eviction.
    let mut sub =
        SubmitRequest::workflow(kind).input(input).deadline(timeout).retain_trace();
    if let Some(t) = req.header("x-nalar-tenant") {
        sub = sub.tenant(t);
    }
    // `X-Nalar-Wait: 0` = park: answer 202 + id now, let the client poll.
    let park = matches!(req.header("x-nalar-wait"), Some("0") | Some("false"));
    let ticket = match state.ingress.submit(sub) {
        Ok(t) => t,
        Err(e) => return error_to_response(&e),
    };
    let id = ticket.request.0;
    if park {
        register(state, ticket);
        return json_response(202, json!({"request": id, "status": "accepted"}));
    }
    let out = ticket.wait(timeout + WAIT_GRACE);
    finished_response(id, out, ticket.latency())
}

fn finished_response(id: u64, out: Result<Value>, latency: Option<Duration>) -> Response {
    match out {
        Ok(v) => {
            let ms = latency.map(|l| l.as_secs_f64() * 1000.0).unwrap_or(0.0);
            json_response(200, json!({"request": id, "result": v, "latency_ms": ms}))
        }
        Err(e) => error_to_response(&e),
    }
}

fn parse_id(id: &str) -> Option<u64> {
    id.parse::<u64>().ok()
}

fn poll_request(state: &State, id: &str) -> Response {
    let id = match parse_id(id) {
        Some(i) => i,
        None => return error_response(400, "request id must be an integer", false),
    };
    let mut reg = state.registry.lock().unwrap();
    let ticket = match reg.get(&id) {
        Some(t) => t,
        None => return error_response(404, &format!("unknown request id {id}"), false),
    };
    match ticket.try_take() {
        None => json_response(202, json!({"request": id, "status": "running"})),
        Some(out) => {
            let latency = ticket.latency();
            reg.remove(&id);
            drop(reg);
            // Result consumption evicts the trace too (same lifetime as
            // the registry entry): after this, `/trace` answers 404.
            state.ingress.trace().forget(RequestId(id));
            finished_response(id, out, latency)
        }
    }
}

/// `GET /v1/requests/{id}/trace`: the request's span timeline from the
/// flight recorder, plus the per-stage decomposition derived from it.
/// Available while the request runs and until its terminal result is
/// consumed (or the bounded ring overwrites it); 404 afterwards.
fn trace_request(state: &State, id: &str) -> Response {
    let id = match parse_id(id) {
        Some(i) => i,
        None => return error_response(400, "request id must be an integer", false),
    };
    let sink = state.ingress.trace();
    let events = sink.timeline(RequestId(id));
    if events.is_empty() {
        let why = if sink.enabled() { "no trace for request" } else { "tracing is disabled" };
        return error_response(404, &format!("{why} {id}"), false);
    }
    let stages = stage_durations(&events);
    let events: Vec<Value> = events
        .iter()
        .map(|e| {
            json!({
                "seq": e.seq,
                "t_ns": e.clock_ns,
                "kind": e.kind.name(),
                "detail": e.detail
            })
        })
        .collect();
    json_response(
        200,
        json!({
            "request": id,
            "events": events,
            "dropped": sink.dropped(),
            "stages": {
                "queue_wait_ns": stages.queue_wait_ns,
                "sched_delay_ns": stages.sched_delay_ns,
                "poll_ns": stages.poll_ns,
                "future_wait_ns": stages.future_wait_ns,
                "engine_service_ns": stages.engine_service_ns,
                "total_ns": stages.total_ns
            }
        }),
    )
}

fn cancel_request(state: &State, id: &str) -> Response {
    let id = match parse_id(id) {
        Some(i) => i,
        None => return error_response(400, "request id must be an integer", false),
    };
    let mut reg = state.registry.lock().unwrap();
    let ticket = match reg.get(&id) {
        Some(t) => t,
        None => return error_response(404, &format!("unknown request id {id}"), false),
    };
    if ticket.cancel() {
        reg.remove(&id);
        drop(reg);
        // a delivered DELETE consumes the parked ticket; its trace
        // follows the same lifetime as the registry entry
        state.ingress.trace().forget(RequestId(id));
        json_response(200, json!({"request": id, "status": "cancelled"}))
    } else {
        // completion/expiry won the race; the result is still pollable
        error_response(409, "request already finished; poll its result", false)
    }
}

fn metrics_response(state: &State) -> Response {
    let snaps: Vec<Value> =
        state.kinds.iter().filter_map(|k| state.ingress.metrics(*k)).map(|m| m.to_json()).collect();
    json_response(
        200,
        json!({
            "time_scale": state.d.cfg().time_scale,
            "open_connections": state.open.load(Ordering::Relaxed),
            "parked": state.registry.lock().unwrap().len(),
            "ingress": snaps
        }),
    )
}

fn prom_response(state: &State) -> Response {
    let snaps: Vec<IngressMetrics> =
        state.kinds.iter().filter_map(|k| state.ingress.metrics(*k)).collect();
    text_response(200, prom_exposition(&snaps))
}

/// Render ingress snapshots as Prometheus text exposition (the
/// `GET /metrics?format=prom` body). Pure function so the format is unit
/// testable without sockets. Counters carry `{workflow,tenant}` labels;
/// stage-latency quantiles carry `{workflow,stage,quantile}` (in seconds,
/// aggregated over tenants — the log-bucketed p50/p95/p99, not a real
/// summary, hence `gauge`).
pub fn prom_exposition(metrics: &[IngressMetrics]) -> String {
    fn family<V: std::fmt::Display>(
        out: &mut String,
        name: &str,
        kind: &str,
        help: &str,
        rows: &[(String, V)],
    ) {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        for (labels, v) in rows {
            out.push_str(&format!("{name}{{{labels}}} {v}\n"));
        }
    }
    let mut out = String::new();
    let tenant_rows = |pick: &dyn Fn(&crate::coordinator::TenantMetrics) -> u64| {
        metrics
            .iter()
            .flat_map(|m| {
                m.tenants.iter().map(move |t| {
                    (format!("workflow=\"{}\",tenant=\"{}\"", m.workflow, t.tenant), pick(t))
                })
            })
            .collect::<Vec<_>>()
    };
    let per_workflow = |pick: &dyn Fn(&IngressMetrics) -> u64| {
        metrics
            .iter()
            .map(|m| (format!("workflow=\"{}\"", m.workflow), pick(m)))
            .collect::<Vec<_>>()
    };
    family(
        &mut out,
        "nalar_ingress_accepted_total",
        "counter",
        "requests past admission",
        &tenant_rows(&|t| t.accepted),
    );
    family(
        &mut out,
        "nalar_ingress_shed_total",
        "counter",
        "requests shed at admission",
        &tenant_rows(&|t| t.shed),
    );
    family(
        &mut out,
        "nalar_ingress_completed_total",
        "counter",
        "requests finished ok",
        &tenant_rows(&|t| t.completed),
    );
    family(
        &mut out,
        "nalar_ingress_failed_total",
        "counter",
        "requests failed after start",
        &tenant_rows(&|t| t.failed),
    );
    family(
        &mut out,
        "nalar_ingress_cancelled_total",
        "counter",
        "requests withdrawn by their caller",
        &tenant_rows(&|t| t.cancelled),
    );
    family(
        &mut out,
        "nalar_ingress_expired_in_queue_total",
        "counter",
        "deadline expiries before start",
        &tenant_rows(&|t| t.expired_in_queue),
    );
    family(
        &mut out,
        "nalar_trace_dropped_total",
        "counter",
        "trace events overwritten by ring overflow",
        &per_workflow(&|m| m.trace_dropped),
    );
    family(
        &mut out,
        "nalar_ingress_queue_depth",
        "gauge",
        "requests waiting in the admission queue",
        &per_workflow(&|m| m.depth as u64),
    );
    family(
        &mut out,
        "nalar_ingress_in_flight",
        "gauge",
        "started-but-unfinished requests",
        &per_workflow(&|m| m.in_flight as u64),
    );
    let mut stage_rows: Vec<(String, f64)> = Vec::new();
    let mut stage_counts: Vec<(String, u64)> = Vec::new();
    for m in metrics {
        for (stage, stat) in m.breakdown.components() {
            for (q, v) in [("0.5", stat.p50), ("0.95", stat.p95), ("0.99", stat.p99)] {
                stage_rows.push((
                    format!("workflow=\"{}\",stage=\"{stage}\",quantile=\"{q}\"", m.workflow),
                    v,
                ));
            }
            stage_counts
                .push((format!("workflow=\"{}\",stage=\"{stage}\"", m.workflow), stat.count));
        }
    }
    family(
        &mut out,
        "nalar_stage_latency_seconds",
        "gauge",
        "per-stage request-latency quantiles (log-bucketed)",
        &stage_rows,
    );
    family(
        &mut out,
        "nalar_stage_latency_count",
        "counter",
        "completions folded per stage",
        &stage_counts,
    );
    out
}

fn register(state: &State, ticket: Ticket) {
    let mut reg = state.registry.lock().unwrap();
    if reg.len() >= REGISTRY_CAP {
        // keep only still-running tickets: finished-but-never-polled
        // results are forfeited rather than held forever
        reg.retain(|_, t| t.latency().is_none());
    }
    reg.insert(ticket.request.0, ticket);
}

// ---------------------------------------------------------------- client

/// One parsed response on the client side.
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpResponse {
    /// Case-insensitive header lookup (names are stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    pub fn json(&self) -> Result<Value> {
        Ok(crate::util::json::parse(&self.body)?)
    }
}

/// Minimal keep-alive HTTP/1.1 client for `loadgen --remote` and the wire
/// tests: one persistent connection, sequential request/response, one
/// transparent reconnect when the server closed a kept-alive socket —
/// for idempotent methods only. Non-idempotent requests (POST) surface
/// the transport error instead: the dead socket may have carried an
/// already-admitted submit, and replaying it would double-submit.
pub struct HttpClient {
    addr: String,
    stream: Option<TcpStream>,
}

impl HttpClient {
    pub fn new(addr: impl Into<String>) -> HttpClient {
        HttpClient { addr: addr.into(), stream: None }
    }

    fn stream(&mut self) -> std::io::Result<&mut TcpStream> {
        if self.stream.is_none() {
            let s = TcpStream::connect(&self.addr)?;
            s.set_nodelay(true)?;
            s.set_read_timeout(Some(Duration::from_secs(120)))?;
            self.stream = Some(s);
        }
        Ok(self.stream.as_mut().unwrap())
    }

    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &str,
    ) -> Result<HttpResponse> {
        let fresh = self.stream.is_none();
        // Only idempotent methods may be replayed transparently. A POST
        // whose pooled connection died after the bytes left the client
        // may already have been admitted server-side — re-sending it
        // would double-submit the workflow. The caller sees the error
        // and decides (poll, resubmit with its own dedup, give up).
        let idempotent = matches!(method, "GET" | "HEAD" | "DELETE");
        match self.request_once(method, path, headers, body) {
            Ok(r) => Ok(r),
            Err(first) => {
                // A kept-alive peer may have idled us out between
                // requests; retry once on a fresh connection. A failure
                // on an already-fresh connection is real.
                self.stream = None;
                if fresh || !idempotent {
                    return Err(Error::Io(first));
                }
                self.request_once(method, path, headers, body).map_err(Error::Io)
            }
        }
    }

    fn request_once(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &str,
    ) -> std::io::Result<HttpResponse> {
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nhost: nalar\r\ncontent-length: {}\r\n",
            body.len()
        );
        for (k, v) in headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        let stream = self.stream()?;
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        stream.flush()?;
        let resp = read_client_response(stream);
        if resp.is_err() {
            self.stream = None;
        }
        resp
    }
}

fn read_client_response(stream: &mut TcpStream) -> std::io::Result<HttpResponse> {
    let invalid = |m: &str| std::io::Error::new(ErrorKind::InvalidData, m.to_string());
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(i) = find(&buf, b"\r\n\r\n") {
            break i;
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(ErrorKind::UnexpectedEof, "peer closed mid-response"));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).map_err(|_| invalid("non-UTF-8 head"))?;
    let mut lines = head.split("\r\n");
    let status = lines
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| invalid("malformed status line"))?;
    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        let (k, v) = line.split_once(':').ok_or_else(|| invalid("malformed header"))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let body_len = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .unwrap_or(0);
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < body_len {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(ErrorKind::UnexpectedEof, "peer closed mid-body"));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(body_len);
    let body = String::from_utf8(body).map_err(|_| invalid("non-UTF-8 body"))?;
    Ok(HttpResponse { status, headers, body })
}

#[cfg(test)]
mod tests {
    use super::*;

    const HDR: usize = 16 << 10;
    const BODY: usize = 1 << 20;

    fn parse(buf: &[u8]) -> Parsed {
        parse_request(buf, HDR, BODY)
    }

    #[test]
    fn prom_exposition_is_well_formed() {
        let m = IngressMetrics {
            workflow: "router".into(),
            depth: 3,
            accepted: 10,
            trace_dropped: 2,
            tenants: vec![crate::coordinator::TenantMetrics {
                tenant: "default".into(),
                accepted: 10,
                completed: 9,
                ..Default::default()
            }],
            ..Default::default()
        };
        let text = prom_exposition(&[m]);
        for line in text.lines() {
            assert!(line.starts_with("# ") || line.starts_with("nalar_"), "bad line: {line}");
        }
        assert!(text
            .contains("nalar_ingress_accepted_total{workflow=\"router\",tenant=\"default\"} 10\n"));
        assert!(text.contains("nalar_ingress_queue_depth{workflow=\"router\"} 3\n"));
        assert!(text.contains("nalar_trace_dropped_total{workflow=\"router\"} 2\n"));
        assert!(text.contains("stage=\"queue_wait\",quantile=\"0.95\""));
        let svc = "nalar_stage_latency_count{workflow=\"router\",stage=\"engine_service\"} 0\n";
        assert!(text.contains(svc));
        // one TYPE header per family, each declared exactly once
        assert_eq!(text.lines().filter(|l| l.starts_with("# TYPE ")).count(), 11);
    }

    #[test]
    fn parses_a_complete_post() {
        let raw = b"POST /v1/workflows/router/requests HTTP/1.1\r\n\
                    X-Nalar-Tenant: meek\r\ncontent-length: 2\r\n\r\n{}";
        match parse(raw) {
            Parsed::Request(req, consumed) => {
                assert_eq!(consumed, raw.len());
                assert_eq!(req.method, "POST");
                assert_eq!(req.path, "/v1/workflows/router/requests");
                assert_eq!(req.header("x-nalar-tenant"), Some("meek"));
                assert_eq!(req.header("X-NALAR-TENANT"), Some("meek"));
                assert_eq!(req.body, b"{}");
                assert!(!req.close);
            }
            p => panic!("expected a request, got {p:?}"),
        }
    }

    #[test]
    fn malformed_request_lines_are_400() {
        for raw in [
            &b"GARBAGE\r\n\r\n"[..],
            &b"GET /x\r\n\r\n"[..],
            &b"GET /x HTTP/1.1 extra\r\n\r\n"[..],
            &b"GET x HTTP/1.1\r\n\r\n"[..],
            &b"GET /x SMTP/1.0\r\n\r\n"[..],
        ] {
            match parse(raw) {
                Parsed::Error(400, _) => {}
                p => panic!("{:?} must be a 400, got {p:?}", String::from_utf8_lossy(raw)),
            }
        }
    }

    #[test]
    fn split_across_reads_is_need_more_then_complete() {
        let raw = b"GET /metrics HTTP/1.1\r\nhost: x\r\n\r\n";
        // every prefix short of the full request just asks for more bytes
        for cut in 0..raw.len() {
            assert!(
                matches!(parse(&raw[..cut]), Parsed::NeedMore),
                "prefix of {cut} bytes must be NeedMore"
            );
        }
        assert!(matches!(parse(raw), Parsed::Request(..)));
        // a body split across reads behaves the same way
        let post = b"POST /x HTTP/1.1\r\ncontent-length: 5\r\n\r\nhel";
        assert!(matches!(parse(post), Parsed::NeedMore));
        let full = b"POST /x HTTP/1.1\r\ncontent-length: 5\r\n\r\nhello";
        match parse(full) {
            Parsed::Request(req, n) => {
                assert_eq!(req.body, b"hello");
                assert_eq!(n, full.len());
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn oversized_headers_are_431_even_unterminated() {
        // terminated but over the cap
        let mut raw = b"GET /x HTTP/1.1\r\nbig: ".to_vec();
        raw.extend_from_slice(&vec![b'a'; HDR + 10]);
        raw.extend_from_slice(b"\r\n\r\n");
        assert!(matches!(parse(&raw), Parsed::Error(431, _)));
        // unterminated: the parser must not buffer forever waiting for
        // a terminator that never comes
        let unterminated = vec![b'a'; HDR + 10];
        assert!(matches!(parse(&unterminated), Parsed::Error(431, _)));
    }

    #[test]
    fn oversized_and_malformed_bodies_are_rejected() {
        let big = format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", BODY + 1);
        assert!(matches!(parse(big.as_bytes()), Parsed::Error(413, _)));
        let bad = b"POST /x HTTP/1.1\r\ncontent-length: nope\r\n\r\n";
        assert!(matches!(parse(bad), Parsed::Error(400, _)));
        let chunked = b"POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n";
        assert!(matches!(parse(chunked), Parsed::Error(501, _)));
    }

    #[test]
    fn pipelined_requests_parse_in_sequence() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\nPOST /x HTTP/1.1\r\ncontent-length: 2\r\n\r\nhi";
        let (first, consumed) = match parse(raw) {
            Parsed::Request(r, n) => (r, n),
            p => panic!("{p:?}"),
        };
        assert_eq!(first.path, "/healthz");
        match parse(&raw[consumed..]) {
            Parsed::Request(second, n) => {
                assert_eq!(second.path, "/x");
                assert_eq!(second.body, b"hi");
                assert_eq!(consumed + n, raw.len());
            }
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn connection_close_is_honored() {
        let raw = b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
        match parse(raw) {
            Parsed::Request(req, _) => assert!(req.close),
            p => panic!("{p:?}"),
        }
    }

    #[test]
    fn malformed_header_lines_are_400() {
        let raw = b"GET /x HTTP/1.1\r\nthis line has no colon\r\n\r\n";
        assert!(matches!(parse(raw), Parsed::Error(400, _)));
    }

    /// A one-request-per-connection server: every accepted socket serves
    /// exactly one request (counting it), answers 200, and closes — the
    /// shape of a keep-alive peer that idles clients out between
    /// requests. Returns the served-request counter.
    fn close_after_serve_server(conns: usize) -> (SocketAddr, Arc<AtomicUsize>, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let served = Arc::new(AtomicUsize::new(0));
        let counter = served.clone();
        let handle = std::thread::spawn(move || {
            for _ in 0..conns {
                let (mut s, _) = match listener.accept() {
                    Ok(x) => x,
                    Err(_) => return,
                };
                let mut buf = Vec::new();
                let mut chunk = [0u8; 1024];
                loop {
                    match parse_request(&buf, HDR, BODY) {
                        Parsed::Request(..) => {
                            counter.fetch_add(1, Ordering::SeqCst);
                            let body = "{\"ok\":true}";
                            let head = format!(
                                "HTTP/1.1 200 OK\r\ncontent-type: application/json\r\n\
                                 content-length: {}\r\n\r\n",
                                body.len()
                            );
                            let _ = s.write_all(head.as_bytes());
                            let _ = s.write_all(body.as_bytes());
                            let _ = s.flush();
                            break; // drop the stream: the socket closes
                        }
                        Parsed::NeedMore => match s.read(&mut chunk) {
                            Ok(0) => break,
                            Ok(n) => buf.extend_from_slice(&chunk[..n]),
                            Err(_) => break,
                        },
                        Parsed::Error(..) => break,
                    }
                }
            }
        });
        (addr, served, handle)
    }

    #[test]
    fn stale_pooled_post_surfaces_the_error_instead_of_resubmitting() {
        let (addr, served, handle) = close_after_serve_server(3);
        let mut client = HttpClient::new(addr.to_string());
        // First POST lands on a fresh connection and succeeds.
        let r = client.request("POST", "/v1/workflows/router/requests", &[], "{}").unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(served.load(Ordering::SeqCst), 1);
        // The server closed that socket after admitting. A second POST
        // reuses the pooled connection, hits the stale socket, and must
        // surface the error: the bytes may already have been admitted
        // server-side, so a transparent replay would double-submit.
        let err = client.request("POST", "/v1/workflows/router/requests", &[], "{}");
        assert!(err.is_err(), "stale-connection POST must error, got {err:?}");
        assert_eq!(served.load(Ordering::SeqCst), 1, "the POST must not be replayed");
        // Idempotent methods still reconnect transparently: this GET
        // lands fresh (the failed POST dropped the pooled stream)...
        let r = client.request("GET", "/healthz", &[], "").unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(served.load(Ordering::SeqCst), 2);
        // ...and the next GET exercises the actual retry path: pooled
        // stream is stale again, the client replays on a fresh socket.
        let r = client.request("GET", "/healthz", &[], "").unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(served.load(Ordering::SeqCst), 3);
        drop(client);
        handle.join().unwrap();
    }
}
