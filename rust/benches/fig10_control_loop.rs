//! Figure 10 reproduction: global control-loop latency vs live futures.
//!
//! Emulates the paper's setup — 64 nodes / 128 agents and 32 nodes / 64
//! agents — then grows the future count 1K -> 131K and measures one
//! global-controller iteration (collect + SRTF-style policy + apply),
//! reporting the breakdown. Paper: 464 ms at 131K futures on 64 nodes,
//! >65% in policy logic, and node-count-independence.

use std::sync::Arc;
use std::time::Duration;

use nalar::coordinator::{GlobalController, InstanceMetrics, LoadMap, Router};
use nalar::coordinator::policy::make_policy;
use nalar::futures::{FutureCell, FutureMeta, FutureTable};
use nalar::ids::*;
use nalar::nodestore::{keys, StoreDirectory};
use nalar::transport::Bus;
use nalar::util::bench::Table;

fn setup(nodes: u32, agents: u32, futures: usize) -> Arc<GlobalController> {
    let node_ids: Vec<NodeId> = (0..nodes).map(NodeId).collect();
    let bus = Bus::new(Duration::ZERO);
    let stores = StoreDirectory::new(&node_ids);
    let loads = LoadMap::new();
    let table = Arc::new(FutureTable::new());
    let router = Arc::new(Router::new(bus.clone(), loads.clone(), 1));

    // agents spread over nodes, with telemetry in their node stores
    for a in 0..agents {
        let id = InstanceId::new("agent", a);
        let node = NodeId(a % nodes);
        let _rx = Box::leak(Box::new(bus.register(id.clone(), node)));
        loads.register(id.clone());
        stores.node(node).put(
            &keys::instance_metrics(&id),
            InstanceMetrics {
                agent: "agent".into(),
                node: node.0,
                queue_len: (a % 7) as usize,
                waiting_sessions: vec![(SessionId(a as u64), 50 + a as u64)],
                oldest_wait_ms: 50 + a as u64,
                ..Default::default()
            },
        );
    }
    // live futures
    for i in 0..futures {
        let mut meta = FutureMeta::new(
            FutureId(i as u64),
            SessionId((i % 1024) as u64),
            RequestId((i % 4096) as u64),
            AgentType::new("agent"),
            "m",
            Location::Driver(RequestId(0)),
        );
        meta.stage = (i % 5) as u32;
        table.insert(FutureCell::new(meta));
    }
    GlobalController::new(
        bus,
        stores,
        router,
        loads,
        table,
        vec![make_policy("srtf").unwrap()],
        Arc::new(|_| None),
    )
}

fn main() {
    println!("=== Fig 10 — global control loop latency vs #futures ===");
    let mut table = Table::new(&[
        "nodes", "agents", "futures", "collect(ms)", "policy(ms)", "apply(ms)", "total(ms)", "policy%",
    ]);
    let sweep: &[usize] = &[1024, 4096, 16384, 65536, 131072];
    for (nodes, agents) in [(32u32, 64u32), (64, 128)] {
        for &futures in sweep {
            let g = setup(nodes, agents, futures);
            // warm + take the median of 3 iterations
            g.tick();
            let mut totals = Vec::new();
            let mut last = None;
            for _ in 0..3 {
                let t = g.tick();
                totals.push(t.total());
                last = Some(t);
            }
            totals.sort();
            let t = last.unwrap();
            let total = totals[1];
            let policy_pct = 100.0 * t.policy.as_secs_f64() / t.total().as_secs_f64().max(1e-12);
            table.row(&[
                nodes.to_string(),
                agents.to_string(),
                futures.to_string(),
                format!("{:.1}", t.collect.as_secs_f64() * 1e3),
                format!("{:.1}", t.policy.as_secs_f64() * 1e3),
                format!("{:.1}", t.apply.as_secs_f64() * 1e3),
                format!("{:.1}", total.as_secs_f64() * 1e3),
                format!("{:.0}%", policy_pct),
            ]);
        }
    }
    table.print();
    println!("\npaper reference: 64 nodes/131K futures => 464ms total, >65% policy; collect 76ms@1K -> 151ms@130K");
}
