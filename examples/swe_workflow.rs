//! Software-engineering workflow (Fig. 9c scenario): recursive retries.
//!
//! Shows the Fig-4 driver in action — planner fan-out, developer/test
//! loops, failures re-entering the graph — and the resulting speedup of
//! NALAR's dynamic reallocation over a static baseline.
//!
//! Run: `cargo run --release --example swe_workflow -- --rps 6`

use std::time::Duration;

use nalar::baselines::SystemUnderTest;
use nalar::server::Deployment;
use nalar::util::cli::Args;
use nalar::workflow::{run_open_loop, RunConfig, WorkflowKind};

fn main() -> nalar::Result<()> {
    let args = Args::from_env();
    let rps = args.f64_or("rps", 6.0);
    let secs = args.u64_or("secs", 6);

    let mut rows = Vec::new();
    for system in [SystemUnderTest::Nalar, SystemUnderTest::AyoLike] {
        let cfg = WorkflowKind::Swe.config();
        let d = Deployment::launch_as(cfg, system)?;
        let rc = RunConfig {
            workflow: WorkflowKind::Swe,
            rps,
            duration: Duration::from_secs(secs),
            session_pool: 48,
            request_timeout: Duration::from_secs(45),
            seed: 33,
        };
        let (stats, rec) = run_open_loop(&d, &rc);
        let paper = rec.summary_scaled(1.0 / stats.time_scale);
        println!(
            "{:<10} avg {:>6.1} p95 {:>7.1} (paper-s) | ok {:>4} fail {:>3} | developer imbalance {:.2}x",
            system.name(),
            paper.avg,
            paper.p95,
            stats.completed,
            stats.failed,
            stats.imbalance
        );
        rows.push((system.name(), paper.avg));
        d.shutdown();
    }
    if rows.len() == 2 && rows[0].1 > 0.0 {
        println!("speedup (baseline avg / NALAR avg): {:.2}x", rows[1].1 / rows[0].1);
    }
    Ok(())
}
