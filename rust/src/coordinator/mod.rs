//! NALAR's two-level control architecture (paper §4).
//!
//! * [`component`] — the event-driven **component-level controller**: one
//!   per agent instance, co-located with its executor. It schedules futures
//!   from its local queue under the policy the global controller installed,
//!   maintains future metadata, propagates readiness, manages the agent's
//!   state/KV, executes migrations (Fig. 8) and pushes telemetry into the
//!   node store.
//! * [`global`] — the periodic **global controller**: aggregates telemetry
//!   through the node stores, runs operator policies over the cluster view,
//!   and pushes decisions (route / set_priority / migrate / kill /
//!   provision — Table 2) back down. Never on the request fast path.
//! * [`policy`] — the policy interface (§4.2): `Policy::tick(view, api)`
//!   with the Table-2 primitives on [`policy::PolicyApi`].
//! * [`policies`] — the paper's three default policies (§6.1) plus the
//!   §6.2 SRTF/LPT studies and baseline orders.
//! * [`router`] — routing state shared by the stubs: session stickiness,
//!   installed weights, least-loaded fallback (late binding happens here).

pub mod component;
pub mod global;
pub mod policies;
pub mod policy;
pub mod router;

pub use component::{ComponentController, InstanceHandle, LocalOrder};
pub use global::{ClusterView, GlobalController, InstanceView};
pub use policy::{make_policy, Policy, PolicyApi, PolicyCmd};
pub use router::{LoadMap, Router};

use crate::ids::SessionId;
use crate::metrics::StageBreakdown;

/// Telemetry one component controller pushes per tick (node store
/// `metrics/{instance}`). This is what the global controller aggregates.
#[derive(Debug, Clone, Default)]
pub struct InstanceMetrics {
    pub agent: String,
    pub node: u32,
    pub queue_len: usize,
    pub active: usize,
    pub completed: u64,
    pub failed: u64,
    pub migrated_in: u64,
    pub migrated_out: u64,
    /// Exponentially-weighted busy fraction (0..1).
    pub busy_ewma: f64,
    /// Longest queue wait among queued futures (ms) — HOL signal.
    pub oldest_wait_ms: u64,
    /// Sessions currently waiting in this instance's queue, with wait ms.
    pub waiting_sessions: Vec<(SessionId, u64)>,
}

/// Telemetry the ingress front door pushes per workflow queue (node store
/// `ingress/{workflow}`). The global controller aggregates these alongside
/// [`InstanceMetrics`], so overload-aware policies see queue depth and shed
/// pressure in the same [`global::ClusterView`] they already consume.
#[derive(Debug, Clone, Default)]
pub struct IngressMetrics {
    pub workflow: String,
    /// Requests waiting in the front-door queue right now (not started).
    pub depth: usize,
    /// Started-but-unfinished requests (stored continuations in the
    /// event-driven scheduler). `in_flight / workers` is the multiplexing
    /// factor — how many requests each scheduler thread is carrying.
    pub in_flight: usize,
    /// Scheduler OS threads serving this front door.
    pub workers: usize,
    /// Bounded-queue capacity (0 = unbounded).
    pub cap: usize,
    /// Admission-policy name ("unbounded" | "bounded" | "token_bucket").
    pub policy: String,
    /// Ready/admission-queue ordering ("fifo" | "deadline_slack" |
    /// "stage") — which front-door scheduling policy produced these
    /// numbers.
    pub schedule: String,
    pub accepted: u64,
    pub shed: u64,
    pub completed: u64,
    /// Execution failures (driver errors, deadline expiry *after* start).
    pub failed: u64,
    /// Requests withdrawn by their caller (`Ticket::cancel`) before
    /// completing — a terminal outcome of its own: not a failure (nothing
    /// broke) and not a shed (the work was admitted and then killed on
    /// purpose).
    pub cancelled: u64,
    /// Deadline expiries before the driver ever started (shed-in-queue) —
    /// kept apart from `failed` so a slow driver and an overloaded queue
    /// are distinguishable in telemetry and the rps_sweep schema.
    pub expired_in_queue: u64,
    /// Per-tenant split of this queue's traffic (weighted-fair DRR
    /// sub-queues + per-tenant token buckets; see `ingress::fairness`).
    /// Always at least one entry — the implicit `default` tenant when the
    /// deployment configures no `ingress.tenants` block. The aggregate
    /// counters above are the sums of these.
    pub tenants: Vec<TenantMetrics>,
    /// Per-stage latency decomposition of completed requests (p50/p95/p99
    /// for queue-wait, sched-delay, poll-time, future-wait and
    /// engine-service, in seconds; DESIGN.md §10). The aggregate over all
    /// tenants — exact, merged bucket-wise from the per-tenant histograms
    /// — so overload policies see *queueing delay*, not just depth.
    pub breakdown: StageBreakdown,
    /// Trace events overwritten by flight-recorder ring overflow (0 when
    /// tracing is disabled or the recorder is keeping up).
    pub trace_dropped: u64,
    /// Routing mode the front door is running ("fixed" when JIT routing
    /// is off, "jit", or "fixed-<variant>" when pinned; DESIGN.md §13).
    pub route: String,
    /// Per-variant dispatch counts `(variant name, calls)` — one entry per
    /// configured model variant, in config order; empty when the engine
    /// declares no variants. Counted at hint consumption, so the sum is
    /// exactly the number of routed engine calls issued.
    pub variants: Vec<(String, u64)>,
}

impl IngressMetrics {
    /// Wire shape for `GET /metrics` on the HTTP serving plane. The node
    /// store holds these as typed values, not JSON, so the serialization
    /// lives here — next to the fields — rather than in the HTTP layer.
    pub fn to_json(&self) -> crate::futures::Value {
        let tenants: Vec<crate::futures::Value> =
            self.tenants.iter().map(TenantMetrics::to_json).collect();
        crate::json!({
            "route": self.route.clone(),
            "variants": variants_json(&self.variants),
            "workflow": self.workflow.clone(),
            "depth": self.depth,
            "in_flight": self.in_flight,
            "workers": self.workers,
            "cap": self.cap,
            "policy": self.policy.clone(),
            "schedule": self.schedule.clone(),
            "accepted": self.accepted,
            "shed": self.shed,
            "completed": self.completed,
            "failed": self.failed,
            "cancelled": self.cancelled,
            "expired_in_queue": self.expired_in_queue,
            "tenants": tenants,
            "breakdown": self.breakdown.to_json(),
            "trace_dropped": self.trace_dropped
        })
    }
}

/// One tenant's slice of a workflow queue's front-door telemetry. The
/// global controller sees these inside [`IngressMetrics`] via the same
/// `ClusterView.ingress` it already consumes, so per-tenant-aware
/// policies (per-tenant SLOs, tenant-weighted provisioning) need no new
/// plumbing.
#[derive(Debug, Clone, Default)]
pub struct TenantMetrics {
    pub tenant: String,
    /// DRR weight (relative service share under backlog).
    pub weight: f64,
    /// Requests of this tenant waiting in its sub-queue right now.
    pub depth: usize,
    pub accepted: u64,
    /// Sheds charged to this tenant — by its own token bucket or by the
    /// shared admission policy while this tenant was submitting.
    pub shed: u64,
    pub completed: u64,
    pub failed: u64,
    pub expired_in_queue: u64,
    pub cancelled: u64,
    /// This tenant's per-variant dispatch counts (same entry order as
    /// [`IngressMetrics::variants`], which is the element-wise sum of
    /// these rows). Empty when no model variants are configured.
    pub variants: Vec<(String, u64)>,
    /// This tenant's own per-stage latency decomposition (same component
    /// set as [`IngressMetrics::breakdown`]).
    pub breakdown: StageBreakdown,
}

impl TenantMetrics {
    /// Wire shape for one tenant entry inside [`IngressMetrics::to_json`].
    pub fn to_json(&self) -> crate::futures::Value {
        crate::json!({
            "tenant": self.tenant.clone(),
            "weight": self.weight,
            "depth": self.depth,
            "accepted": self.accepted,
            "shed": self.shed,
            "completed": self.completed,
            "failed": self.failed,
            "expired_in_queue": self.expired_in_queue,
            "cancelled": self.cancelled,
            "variants": variants_json(&self.variants),
            "breakdown": self.breakdown.to_json()
        })
    }
}

/// Wire shape shared by the aggregate and per-tenant variant counters: a
/// JSON object keyed by variant name (stable, diff-friendly — mirrors how
/// `breakdown` serializes components).
fn variants_json(variants: &[(String, u64)]) -> crate::futures::Value {
    let mut obj = crate::json!({});
    for (name, n) in variants {
        obj.insert(name, *n);
    }
    obj
}
