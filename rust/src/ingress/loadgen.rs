//! `nalar loadgen` — the open-loop saturation sweep (paper §6).
//!
//! For each (offered RPS, system) point this drives the ingress front door
//! with a Poisson arrival process ([`Arrivals::schedule`]): submits never
//! block on completion — exactly the open-loop discipline under which the
//! paper's capacity claim is stated. Each point reports goodput (requests
//! completed *within deadline* per second), shed rate, and latency
//! quantiles; the sweep across RPS produces the §6 saturation curve where
//! NALAR sustains 80 RPS and the baselines' goodput collapses (their
//! unbounded queues turn overload into divergent p99 instead of sheds).
//!
//! Output: `BENCH_rps_sweep.json` in the `nalar-bench/v1` schema
//! (validated by [`crate::bench::validate`]; `latency` is censored at the
//! deadline so baseline p99 divergence is visible, `latency_ok` is
//! completions only).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::baselines::SystemUnderTest;
use crate::bench;
use crate::config::DeploymentConfig;
use crate::error::{Error, Result};
use crate::ids::SessionId;
use crate::ingress::{Ingress, SchedulePolicy};
use crate::json;
use crate::metrics::{goodput, shed_rate, LatencyRecorder};
use crate::server::Deployment;
use crate::util::bench::Table;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::workflow::harness::input_for;
use crate::workflow::WorkflowKind;
use crate::workload::Arrivals;

/// One `nalar loadgen` invocation.
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    pub workflow: WorkflowKind,
    pub systems: Vec<SystemUnderTest>,
    /// Offered load points (wall-clock requests/second).
    pub rates: Vec<f64>,
    /// Measurement window per point (wall-clock seconds).
    pub secs: u64,
    /// CI-smoke profile flag (stamped into the report).
    pub quick: bool,
    pub out_dir: PathBuf,
    /// Sessions drawn Zipf-skewed, as in the Fig-9 harness.
    pub session_pool: usize,
    /// Per-request deadline in paper seconds (scaled by `time_scale`).
    pub timeout_paper_s: f64,
    /// Override the config's `time_scale` (None = keep the config's).
    pub time_scale: Option<f64>,
    pub seed: u64,
    /// Deployment config file (None = the workflow's builtin config).
    pub config: Option<PathBuf>,
    /// Override the config's `ingress.workers` scheduler thread count
    /// (None = keep the config's). The event-driven scheduler multiplexes
    /// in-flight requests over these threads, so a small value with a
    /// large offered load is the thread-decoupling stress test.
    pub workers: Option<usize>,
    /// Override the deployment's policy list (None = keep the config's /
    /// the system's defaults). The hc gate pins this to `load_balance`
    /// only: `resource_realloc` may kill an instance mid-run, failing its
    /// queued futures retryably — legitimate in the saturation sweep,
    /// noise in a must-complete-everything functional gate.
    pub policies: Option<Vec<String>>,
    /// Fail the run if any point completes fewer requests than it
    /// admitted (offered − shed − cancelled) — the CI gate for the
    /// scheduler: with in-flight ≫ threads, every admitted request must
    /// still finish.
    pub expect_admitted_complete: bool,
    /// Probability an admitted request is cancelled (`Ticket::cancel`)
    /// at a seeded uniform point inside its deadline window — the
    /// lifecycle-control knob (`--cancel-rate`): cancelled work must
    /// neither leak scheduler-table entries nor distort the goodput
    /// accounting of the surviving requests.
    pub cancel_rate: f64,
    /// Scheduling-policy axis: run every (rate, system) point once per
    /// listed `ingress.schedule` (None = the config's). Baselines are
    /// forced back to `fifo` by `SystemUnderTest::apply`, so the axis
    /// measures NALAR's front-door SRTF against its own FIFO.
    pub schedules: Option<Vec<String>>,
}

impl LoadgenOpts {
    /// CI-smoke profile: two points, two systems, seconds of wall time.
    pub fn quick(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            workflow,
            systems: vec![SystemUnderTest::Nalar, SystemUnderTest::AutoGenLike],
            rates: vec![40.0, 80.0],
            secs: 1,
            quick: true,
            out_dir: PathBuf::from("."),
            session_pool: 16,
            timeout_paper_s: 30.0,
            time_scale: Some(0.002),
            seed: 0x10AD,
            config: None,
            workers: None,
            policies: None,
            expect_admitted_complete: false,
            cancel_rate: 0.0,
            schedules: None,
        }
    }

    /// The full §6 sweep: all four systems across the saturation range.
    /// `time_scale` 0.1 (only a 10x speedup) puts the workload's capacity
    /// cliff inside the swept range, so 80 RPS is a genuine saturation
    /// point rather than a trivial one.
    pub fn full(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            workflow,
            systems: SystemUnderTest::all().to_vec(),
            rates: vec![20.0, 40.0, 60.0, 80.0, 100.0, 120.0, 160.0],
            secs: 8,
            quick: false,
            out_dir: PathBuf::from("."),
            session_pool: 48,
            timeout_paper_s: 30.0,
            time_scale: Some(0.1),
            seed: 0x10AD,
            config: None,
            workers: None,
            policies: None,
            expect_admitted_complete: false,
            cancel_rate: 0.0,
            schedules: None,
        }
    }

    /// High-concurrency CI gate: one point offering ~640 requests in 2s
    /// onto a 4-thread scheduler (in-flight ≫ threads), failing the run
    /// if any admitted request does not complete. The generous deadline
    /// makes this a functional gate on the event-driven scheduler, not a
    /// latency benchmark.
    pub fn hc_smoke(workflow: WorkflowKind) -> LoadgenOpts {
        LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![320.0],
            secs: 2,
            session_pool: 32,
            timeout_paper_s: 600.0,
            time_scale: Some(0.0005),
            workers: Some(4),
            // `resource_realloc` may kill an instance mid-run, failing its
            // queued futures retryably — legitimate in the saturation
            // sweep, noise in a must-complete-everything gate.
            policies: Some(vec!["load_balance".into()]),
            expect_admitted_complete: true,
            // Run the gate under the non-default ordering: deadline-slack
            // pops must preserve the every-admitted-request-completes and
            // no-table-leak invariants just like FIFO.
            schedules: Some(vec!["deadline_slack".into()]),
            ..Self::quick(workflow)
        }
    }
}

/// Run the sweep and write `BENCH_rps_sweep.json`. Returns the path.
pub fn run(opts: &LoadgenOpts) -> Result<PathBuf> {
    if opts.rates.is_empty() || opts.systems.is_empty() {
        return Err(Error::Config("loadgen needs at least one rate and one system".into()));
    }
    let mut table = Table::new(&[
        "system", "sched", "rps", "offered", "ok", "shed", "expired", "cancel", "fail", "goodput",
        "p50(s)", "p99(s)",
    ]);
    // The scheduling-policy axis: None = keep whatever the config says.
    let schedules: Vec<Option<String>> = match &opts.schedules {
        Some(list) => list.iter().map(|s| Some(s.clone())).collect(),
        None => vec![None],
    };
    let mut points = Vec::new();
    for &rps in &opts.rates {
        for &system in &opts.systems {
            for (si, sched) in schedules.iter().enumerate() {
                // Baselines are forced back to `fifo` by `apply`, so every
                // axis entry would measure the identical configuration —
                // run each baseline cell once instead of once per entry.
                if si > 0 && system != SystemUnderTest::Nalar {
                    continue;
                }
                let t0 = Instant::now();
                let p = run_point(opts, rps, system, sched.as_deref())?;
                println!(
                    "[loadgen] {} {} ({}) @ {:.0} rps done in {:.1?}",
                    opts.workflow.name(),
                    system.name(),
                    p.get("schedule").as_str().unwrap_or("?"),
                    rps,
                    t0.elapsed()
                );
                table.row(&[
                    p.get("system").as_str().unwrap_or("?").to_string(),
                    p.get("schedule").as_str().unwrap_or("?").to_string(),
                    format!("{:.0}", p.get("rps_wall").as_f64().unwrap_or(0.0)),
                    p.get("offered").as_u64().unwrap_or(0).to_string(),
                    p.get("completed").as_u64().unwrap_or(0).to_string(),
                    p.get("shed").as_u64().unwrap_or(0).to_string(),
                    p.get("expired_in_queue").as_u64().unwrap_or(0).to_string(),
                    p.get("cancelled").as_u64().unwrap_or(0).to_string(),
                    p.get("failed").as_u64().unwrap_or(0).to_string(),
                    format!("{:.1}", p.get("goodput_rps").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", p.get("latency").get("p50").as_f64().unwrap_or(0.0)),
                    format!("{:.1}", p.get("latency").get("p99").as_f64().unwrap_or(0.0)),
                ]);
                if opts.expect_admitted_complete {
                    let offered = p.get("offered").as_u64().unwrap_or(0);
                    let shed = p.get("shed").as_u64().unwrap_or(0);
                    let cancelled = p.get("cancelled").as_u64().unwrap_or(0);
                    let completed = p.get("completed").as_u64().unwrap_or(0);
                    if completed < offered.saturating_sub(shed + cancelled) {
                        return Err(Error::Msg(format!(
                            "high-concurrency gate: {} {} @ {:.0} rps completed only \
                             {completed} of {} admitted requests",
                            opts.workflow.name(),
                            system.name(),
                            rps,
                            offered.saturating_sub(shed + cancelled),
                        )));
                    }
                }
                points.push(p);
            }
        }
    }
    println!("\n=== RPS sweep — {} workflow, open loop ===", opts.workflow.name());
    table.print();
    let report = bench::report(bench::RPS_SWEEP, opts.quick, "paper_s", points);
    bench::validate(&report)?;
    std::fs::create_dir_all(&opts.out_dir)?;
    bench::write_report(&opts.out_dir, bench::RPS_SWEEP, &report)
}

/// One (rate, system, schedule) cell of the sweep.
fn run_point(
    opts: &LoadgenOpts,
    rps: f64,
    system: SystemUnderTest,
    schedule: Option<&str>,
) -> Result<Value> {
    let mut cfg = match &opts.config {
        Some(path) => DeploymentConfig::from_json_file(path)?,
        None => opts.workflow.config(),
    };
    if let Some(ts) = opts.time_scale {
        cfg.time_scale = ts;
    }
    if let Some(w) = opts.workers {
        cfg.ingress.workers = w.max(1);
    }
    if let Some(s) = schedule {
        // Validate eagerly: the config was checked before this override.
        if SchedulePolicy::parse(s).is_none() {
            return Err(Error::Config(format!(
                "unknown schedule `{s}` (known: fifo, deadline_slack, stage)"
            )));
        }
        // Set BEFORE the system mode applies, so baselines are forced
        // back to `fifo` (none of them schedules a front door) and the
        // axis compares NALAR-with-SRTF against NALAR-with-FIFO.
        cfg.ingress.schedule = s.to_string();
    }
    // Apply the system's serving mode FIRST (for NALAR this fills the
    // default policy trio when the config declares none — pushing ours
    // earlier would suppress that fill), then add the ingress-aware
    // provisioning loop on top. Baselines get stripped of all policies
    // (and admission control) by the same `apply`, which `launch_as`
    // re-runs idempotently. An explicit `opts.policies` override is
    // authoritative: nothing is appended to it.
    system.apply(&mut cfg);
    if let Some(policies) = &opts.policies {
        cfg.policies = policies.clone();
    } else if system == SystemUnderTest::Nalar
        && !cfg.policies.iter().any(|p| p == "overload_provision")
    {
        cfg.policies.push("overload_provision".into());
    }
    let d = Deployment::launch_as(cfg, system)?;
    let time_scale = d.cfg().time_scale;
    let timeout = Duration::from_secs_f64((opts.timeout_paper_s * time_scale).max(0.001));
    let window = Duration::from_secs(opts.secs.max(1));
    let ingress = Ingress::start(&d, &[opts.workflow]);
    let ingress_policy = ingress.metrics(opts.workflow).map(|m| m.policy).unwrap_or_default();

    let arrivals = Arrivals::new(rps, opts.seed ^ rps.to_bits()).schedule(window);
    let offered = arrivals.len() as u64;
    let sessions: Vec<SessionId> = (0..opts.session_pool.max(1)).map(|_| d.new_session()).collect();
    let mut turns = vec![0u64; sessions.len()];
    let mut rng = Rng::new(opts.seed ^ 0xFEED);

    // Open loop: pace submissions on the arrival schedule; never wait for
    // completions in this loop. With `--cancel-rate`, a seeded fraction
    // of admitted requests is withdrawn at a uniform point inside its
    // deadline window — cancellations fire between arrivals, racing the
    // scheduler exactly like an impatient caller would.
    let mut tickets = Vec::with_capacity(arrivals.len());
    let mut cancels: Vec<(Duration, usize)> = Vec::new(); // (due, ticket index)
    let mut shed = 0u64;
    let start = Instant::now();
    for at in &arrivals {
        let wait = at.saturating_sub(start.elapsed());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        let now = start.elapsed();
        cancels.retain(|(due, i)| {
            if *due <= now {
                let _ = tickets[*i].cancel(); // may lose to completion: fine
                false
            } else {
                true
            }
        });
        let progress = (now.as_secs_f64() / window.as_secs_f64()).min(1.0);
        let sidx = rng.zipf(sessions.len(), 1.1);
        let turn = turns[sidx];
        turns[sidx] += 1;
        let input = input_for(opts.workflow, progress, turn, &mut rng);
        match ingress.submit(opts.workflow, Some(sessions[sidx]), input, timeout) {
            Ok(t) => {
                tickets.push(t);
                if opts.cancel_rate > 0.0 && rng.bool_with(opts.cancel_rate) {
                    let frac = (rng.next_u64() % 1024) as f64 / 1024.0;
                    cancels.push((now + timeout.mul_f64(frac), tickets.len() - 1));
                }
            }
            Err(_) => shed += 1, // fast retryable rejection, already counted
        }
    }
    // Cancels due after the offered window fire at window end (the drain
    // below would otherwise outwait them).
    for (_, i) in cancels {
        let _ = tickets[i].cancel();
    }

    // Drain: every admitted request either completes, hits its deadline
    // (the scheduler's sweep fails expired work fast, so this terminates)
    // or was cancelled above. Cancelled requests are excluded from the
    // latency distributions: they measure caller impatience, not serving.
    let ok_rec = LatencyRecorder::new(); // completions within deadline
    let tail_rec = LatencyRecorder::new(); // + timeouts censored at the deadline
    let mut completed = 0u64;
    let mut failed = 0u64;
    for t in &tickets {
        let outcome = t.wait(timeout + Duration::from_millis(50));
        let lat = t.latency().unwrap_or(timeout);
        match outcome {
            Ok(_) if lat <= timeout => {
                completed += 1;
                ok_rec.record(lat);
                tail_rec.record(lat);
            }
            Err(Error::Cancelled) => {}
            _ => {
                failed += 1;
                tail_rec.record(lat.min(timeout));
            }
        }
    }
    // Everything is drained, so the final snapshot splits the failures:
    // `expired_in_queue` never started a driver (queueing shed the work),
    // `cancelled` was withdrawn by its caller, the remainder failed in
    // execution (slow driver / agent error).
    let m_end = ingress.metrics(opts.workflow).unwrap_or_default();
    let expired_in_queue = m_end.expired_in_queue;
    let cancelled = m_end.cancelled;
    // Table-leak gate: with every ticket fulfilled, both scheduler tables
    // must be empty — a lingering entry is a lifecycle bug (bounded grace
    // for sweep/poll bookkeeping that runs just after fulfilment).
    let drained_at = Instant::now();
    let mut leak = (m_end.in_flight, m_end.depth);
    while leak != (0, 0) && drained_at.elapsed() < Duration::from_secs(2) {
        std::thread::sleep(Duration::from_millis(5));
        let m = ingress.metrics(opts.workflow).unwrap_or_default();
        leak = (m.in_flight, m.depth);
    }
    ingress.stop();
    d.shutdown();
    if leak != (0, 0) {
        return Err(Error::Msg(format!(
            "scheduler table leak after full drain: in_flight {} depth {} ({} {} @ {:.0} rps)",
            leak.0,
            leak.1,
            opts.workflow.name(),
            system.name(),
            rps,
        )));
    }

    let paper = 1.0 / time_scale;
    let gput = goodput(completed, window);
    let mut p = json!({
        "workflow": opts.workflow.name(),
        "system": system.name(),
        "rps_wall": rps,
        "rps_paper": rps * time_scale,
        "duration_s": opts.secs,
        "offered": offered,
        "completed": completed,
        "failed": failed.saturating_sub(expired_in_queue),
        "expired_in_queue": expired_in_queue,
        "shed": shed,
        "cancelled": cancelled,
        "cancel_rate": opts.cancel_rate,
        "schedule": m_end.schedule.as_str(),
        "goodput_rps": gput,
        "goodput_frac": gput / rps,
        "shed_rate": shed_rate(shed, offered),
        "timeout_paper_s": opts.timeout_paper_s,
        "ingress_policy": ingress_policy,
        "ingress_workers": m_end.workers
    });
    p.insert("latency", tail_rec.summary_scaled(paper).to_json());
    p.insert("latency_ok", ok_rec.summary_scaled(paper).to_json());
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_writes_schema_valid_report() {
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![30.0],
            session_pool: 8,
            timeout_paper_s: 60.0,
            time_scale: Some(0.0005),
            out_dir: dir.clone(),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let path = run(&opts).unwrap();
        assert!(path.ends_with("BENCH_rps_sweep.json"));
        bench::check_files(&dir, &[bench::RPS_SWEEP]).unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let pts = report.get("points").as_arr().unwrap();
        assert_eq!(pts.len(), 1);
        let p = &pts[0];
        assert!(p.get("completed").as_u64().unwrap() > 0, "nothing completed");
        assert_eq!(p.get("ingress_policy").as_str(), Some("bounded"));
        assert!(p.get("expired_in_queue").as_u64().is_some(), "new-schema field missing");
        assert_eq!(p.get("cancelled").as_u64(), Some(0), "no --cancel-rate: none cancelled");
        assert_eq!(p.get("schedule").as_str(), Some("fifo"), "config default ordering");
        assert!(p.get("ingress_workers").as_u64().unwrap() >= 1);
        assert!(p.get("latency").get("p99").as_f64().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancel_rate_and_schedule_axis_flow_into_the_report() {
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-cx-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // One slow worker serializes the burst, so queueing delay dwarfs
        // service time and a fair share of the seeded cancels land while
        // their request is still queued or parked.
        let opts = LoadgenOpts {
            systems: vec![SystemUnderTest::Nalar],
            rates: vec![60.0],
            session_pool: 8,
            timeout_paper_s: 120.0,
            time_scale: Some(0.01),
            workers: Some(1),
            out_dir: dir.clone(),
            cancel_rate: 0.5,
            schedules: Some(vec!["fifo".into(), "deadline_slack".into()]),
            ..LoadgenOpts::quick(WorkflowKind::Router)
        };
        let path = run(&opts).unwrap();
        let report = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let pts = report.get("points").as_arr().unwrap();
        assert_eq!(pts.len(), 2, "one point per schedule-axis entry");
        assert_eq!(pts[0].get("schedule").as_str(), Some("fifo"));
        assert_eq!(pts[1].get("schedule").as_str(), Some("deadline_slack"));
        let cancelled: u64 = pts.iter().map(|p| p.get("cancelled").as_u64().unwrap()).sum();
        assert!(cancelled > 0, "a 50% cancel rate against a backed-up queue must land some");
        for p in pts {
            assert_eq!(p.get("cancel_rate").as_f64(), Some(0.5));
            let offered = p.get("offered").as_u64().unwrap();
            let accounted = p.get("completed").as_u64().unwrap()
                + p.get("failed").as_u64().unwrap()
                + p.get("expired_in_queue").as_u64().unwrap()
                + p.get("shed").as_u64().unwrap()
                + p.get("cancelled").as_u64().unwrap();
            assert_eq!(accounted, offered, "every request has exactly one terminal outcome");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hc_gate_fails_when_admitted_work_cannot_complete() {
        // A zero-second deadline guarantees nothing completes; the
        // completion gate must turn that into an error instead of a
        // quietly-degraded report.
        let dir = std::env::temp_dir().join(format!("nalar-loadgen-hc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let opts = LoadgenOpts {
            rates: vec![50.0],
            secs: 1,
            session_pool: 4,
            // 1ms effective deadline against ~12ms of service time:
            // nothing admitted can finish in time.
            timeout_paper_s: 0.0,
            time_scale: Some(0.01),
            out_dir: dir.clone(),
            workers: Some(2),
            expect_admitted_complete: true,
            ..LoadgenOpts::hc_smoke(WorkflowKind::Router)
        };
        let err = run(&opts).unwrap_err();
        assert!(err.to_string().contains("high-concurrency gate"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
