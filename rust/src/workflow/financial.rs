//! Financial-analyst workflow (paper §6, Fig. 9a).
//!
//! An analyst agent fans out to stock / bond / market-research agents and
//! a web/news search, then summarizes for the user. Sessions are stateful
//! — the user issues follow-ups after long delays, and the summary history
//! lives in a `managedList` so NALAR (not the developer) owns its
//! placement; the analyst's KV cache makes session placement matter.
//!
//! Written as a resumable [`Driver`]: the fan-out join is a single
//! `Pending` naming every unresolved specialist, so a scheduler wakes the
//! request once per readiness push instead of a thread sleeping through
//! the join.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::futures::{FutureHandle, Value};
use crate::ids::FutureId;
use crate::json;
use crate::workflow::driver::{drive_blocking, Driver, Step};
use crate::workflow::Env;

const ANALYSTS: [&str; 3] = ["stock_analysis", "bond_market", "market_research"];

/// One user request (initial question or follow-up) through the workflow.
/// Blocking compat shim over [`FinancialDriver`].
pub fn run(env: &Env, input: &Value, timeout: Duration) -> Result<Value> {
    drive_blocking(&mut FinancialDriver::new(input), env, timeout)
}

enum State {
    Start,
    /// Fan-out in flight; the join suspends on every unresolved future.
    Join { specialists: Vec<FutureHandle>, web: FutureHandle },
    /// Summary call in flight. The composed prompt (question + specialist
    /// outputs + web digest) rides along so a journaled snapshot can
    /// re-issue the summary without re-running the fan-out.
    Summarize { summary: FutureHandle, prompt: String },
    /// Journal-replay re-entry point ([`FinancialDriver::restore`]): the
    /// first poll re-issues the summary call afresh.
    Resume { prompt: String },
    Finished,
}

/// See [`run`]; resumable form.
pub struct FinancialDriver {
    question: String,
    /// Generation budget: small in PJRT quickstarts (so multi-turn
    /// sessions fit the model context and KV reuse shows), full-size in
    /// sim runs.
    max_new: usize,
    state: State,
}

impl FinancialDriver {
    pub fn new(input: &Value) -> FinancialDriver {
        FinancialDriver {
            question: input.get("question").as_str().unwrap_or("market update").to_string(),
            max_new: input.get("max_new").as_usize().unwrap_or(128),
            state: State::Start,
        }
    }

    /// Rebuild a driver from a [`Driver::serialize_state`] snapshot. The
    /// fan-out join (or an unrecognized snapshot) restarts from `Start` —
    /// partially resolved specialists died with the node, so the whole
    /// fan-out re-issues; a summarize snapshot re-enters directly with
    /// the already-composed prompt.
    pub fn restore(input: &Value, state: &Value) -> FinancialDriver {
        let mut d = FinancialDriver::new(input);
        if state.str_or("stage", "") == "summarize" {
            d.state = State::Resume { prompt: state.str_or("prompt", "").to_string() };
        }
        d
    }
}

impl Driver for FinancialDriver {
    fn poll(&mut self, env: &Env) -> Step {
        loop {
            match std::mem::replace(&mut self.state, State::Finished) {
                State::Start => {
                    // Fan out to the specialist agents + web search — all
                    // futures, all non-blocking (Op 1); the driver suspends
                    // only at the join.
                    let specialists: Vec<_> = ANALYSTS
                        .iter()
                        .map(|a| {
                            env.ctx.agent(a).call(
                                "analyze",
                                json!({
                                    "prompt": self.question.as_str(),
                                    "max_new_tokens": self.max_new.min(96),
                                }),
                            )
                        })
                        .collect();
                    let web = env
                        .ctx
                        .agent("web_search")
                        .call("search", json!({"query": self.question.as_str()}));
                    self.state = State::Join { specialists, web };
                }
                State::Join { specialists, web } => {
                    // Specialist failures are fatal (retryable by the
                    // caller) and fail the request *fast* — even while
                    // other branches are still in flight; a web failure
                    // degrades gracefully — exactly the "driver decides"
                    // model.
                    let mut waiting: Vec<FutureId> = Vec::new();
                    for f in &specialists {
                        match f.try_value() {
                            None => waiting.push(f.id()),
                            Some(Err(e)) => return Step::Done(Err(e)),
                            Some(Ok(_)) => {}
                        }
                    }
                    if !web.available() {
                        waiting.push(web.id());
                    }
                    if !waiting.is_empty() {
                        self.state = State::Join { specialists, web };
                        return Step::Pending { waiting_on: waiting };
                    }
                    let mut parts: Vec<String> = Vec::new();
                    for f in &specialists {
                        match f.try_value().expect("joined future is terminal") {
                            Ok(v) => {
                                parts.push(v.get("text").as_str().unwrap_or_default().to_string())
                            }
                            Err(e) => return Step::Done(Err(e)),
                        }
                    }
                    let web_part = match web.try_value().expect("joined future is terminal") {
                        Ok(v) => v.to_string(),
                        Err(_) => "[web search unavailable]".to_string(),
                    };

                    // Session history: managed state, not driver-managed
                    // placement (§3.3).
                    let history = env.state_list("history");
                    let history_tokens = 48 * history.len(); // prior summaries in the KV context

                    let deps: Vec<_> = specialists.iter().map(|f| f.id()).collect();
                    let prompt =
                        format!("{}\n{}\n{web_part}", self.question, parts.join("\n"));
                    let summary = env.ctx.deeper().agent("analyst").call_with(
                        "summarize",
                        json!({
                            "prompt": prompt.as_str(),
                            "max_new_tokens": self.max_new,
                            "history_tokens": history_tokens,
                        }),
                        &deps,
                        0,
                    );
                    self.state = State::Summarize { summary, prompt };
                }
                State::Summarize { summary, prompt } => match summary.try_value() {
                    None => {
                        let id = summary.id();
                        self.state = State::Summarize { summary, prompt };
                        return Step::Pending { waiting_on: vec![id] };
                    }
                    Some(Err(e)) => return Step::Done(Err(e)),
                    Some(Ok(out)) => {
                        let history = env.state_list("history");
                        history.push(json!({
                            "question": self.question.as_str(),
                            "summary": out.get("text").as_str().unwrap_or_default(),
                        }));
                        return Step::Done(Ok(json!({
                            "summary": out.get("text").as_str().unwrap_or_default(),
                            "kv": out.get("kv").as_str().unwrap_or(""),
                            "turn": history.len(),
                            "specialists": ANALYSTS.len(),
                        })));
                    }
                },
                State::Resume { prompt } => {
                    // Replay re-issues the summary call afresh: the
                    // specialist outputs are already baked into the
                    // snapshotted prompt, so only the final call reruns
                    // (no deps — the producing futures died in the crash).
                    let history = env.state_list("history");
                    let history_tokens = 48 * history.len();
                    let summary = env.ctx.deeper().agent("analyst").call_with(
                        "summarize",
                        json!({
                            "prompt": prompt.as_str(),
                            "max_new_tokens": self.max_new,
                            "history_tokens": history_tokens,
                        }),
                        &[],
                        0,
                    );
                    self.state = State::Summarize { summary, prompt };
                }
                State::Finished => {
                    return Step::Done(Err(Error::msg("financial driver polled after completion")))
                }
            }
        }
    }

    /// Fan-out join is stage 1, the summary call 2 (front-door SRTF).
    fn stage(&self) -> u32 {
        match self.state {
            State::Start => 0,
            State::Join { .. } => 1,
            State::Summarize { .. } | State::Resume { .. } => 2,
            State::Finished => 3,
        }
    }

    fn serialize_state(&self) -> Value {
        match &self.state {
            // A mid-join crash re-runs the whole fan-out: resolved
            // specialist values lived only in the dead node's memory.
            State::Start | State::Join { .. } => json!({"stage": "join"}),
            State::Summarize { prompt, .. } | State::Resume { prompt } => {
                json!({"stage": "summarize", "prompt": prompt.as_str()})
            }
            State::Finished => Value::Null,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Deployment;
    use crate::workflow::WorkflowKind;

    #[test]
    fn end_to_end_with_followup() {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.time_scale = 0.0005; // fast test
        let d = Deployment::launch(cfg).unwrap();
        let session = d.new_session();
        let timeout = Duration::from_secs(20);

        let env = Env::new(&d, session);
        let out = run(&env, &json!({"question": "How did FCF change?"}), timeout).unwrap();
        assert_eq!(out.get("turn").as_i64(), Some(1));
        assert_eq!(out.get("specialists").as_i64(), Some(3));

        // follow-up in the same session sees the history
        let env2 = Env::new(&d, session);
        let out2 = run(&env2, &json!({"question": "break that down"}), timeout).unwrap();
        assert_eq!(out2.get("turn").as_i64(), Some(2));
        d.shutdown();
    }

    #[test]
    fn sessions_are_sticky_on_analyst() {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let session = d.new_session();
        let timeout = Duration::from_secs(20);
        for _ in 0..2 {
            let env = Env::new(&d, session);
            run(&env, &json!({"question": "q"}), timeout).unwrap();
        }
        // managed-state agent => session pinned to one instance
        assert!(d.router().sticky_of(session, "analyst").is_some());
        d.shutdown();
    }

    #[test]
    fn join_reports_every_unresolved_fanout_future() {
        // Slow specialists (200 paper-s at 0.001 = 200ms) pin the join
        // open: the first poll must suspend on all four fan-out futures.
        let cfg = crate::config::DeploymentConfig::from_json(
            r#"{"time_scale": 0.001, "agents": [
                {"name": "stock_analysis", "kind": "llm", "instances": 1,
                 "profile": {"base_s": 200.0}, "methods": ["analyze"]},
                {"name": "bond_market", "kind": "llm", "instances": 1,
                 "profile": {"base_s": 200.0}, "methods": ["analyze"]},
                {"name": "market_research", "kind": "llm", "instances": 1,
                 "profile": {"base_s": 200.0}, "methods": ["analyze"]},
                {"name": "web_search", "kind": "web_search", "instances": 1,
                 "profile": {"base_s": 200.0}, "methods": ["search"]},
                {"name": "analyst", "kind": "llm", "instances": 1,
                 "profile": {"base_s": 0.1}, "methods": ["summarize"]}]}"#,
        )
        .unwrap();
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let mut drv = FinancialDriver::new(&json!({"question": "q"}));
        let Step::Pending { waiting_on } = drv.poll(&env) else {
            panic!("fan-out cannot be done on the first poll");
        };
        assert_eq!(waiting_on.len(), 4, "3 specialists + web search");
        d.shutdown();
    }

    #[test]
    fn restore_resumes_the_summary_without_refanning_out() {
        let mut cfg = WorkflowKind::Financial.config();
        cfg.time_scale = 0.0005;
        let d = Deployment::launch(cfg).unwrap();
        let env = Env::new(&d, d.new_session());
        let input = json!({"question": "q"});
        // Fan-out snapshots restart from the top...
        assert_eq!(FinancialDriver::restore(&input, &json!({"stage": "join"})).stage(), 0);
        // ...but a summarize snapshot re-enters stage 2 with the composed
        // prompt and completes (history still appends the turn).
        let snap = json!({"stage": "summarize", "prompt": "q\nstocks up\nbonds flat"});
        let mut drv = FinancialDriver::restore(&input, &snap);
        assert_eq!(drv.stage(), 2, "snapshot re-enters the summary stage");
        let out = drive_blocking(&mut drv, &env, Duration::from_secs(20)).unwrap();
        assert_eq!(out.get("turn").as_i64(), Some(1));
        d.shutdown();
    }
}
